# Developer entry points. `make check` is the pre-PR gate (see ROADMAP.md).

.PHONY: check build test test-par test-analysis test-crash test-net test-drift clippy doc bench bench-sim bench-table1 bench-live bench-drift artifacts

# Pre-PR gate: release build + tests (incl. the parallel-determinism
# ladder, the analysis/confluence suites under two lock-shard settings,
# the crash-recovery seed matrix, the networked-belt suites and the
# live-routing-epoch suite) + lint + the rustdoc gate, all from the
# rust crate.
check: build test-par test-analysis test-crash test-net test-drift clippy doc

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Tier-1 suite plus extra rungs of the parallel-determinism suite
# (Conveyor, Cluster and Baseline sims — all on the window engine with
# the persistent worker pool). The plain `test` run exercises the
# suite's default ladder (sequential 1-thread baseline — the pre-pool
# path, no pool is ever constructed there — vs the pool at 2 threads
# and at all cores); the ELIA_PAR_MAX=1 pass pins pure 1-thread
# run-to-run reproducibility, and the ELIA_PAR_MAX=2 pass re-runs just
# the three sims' signature tests so the minimal pool (one worker plus
# the driver) stays byte-identical to the sequential baseline even if
# the default ladder changes (see
# tests/parallel_determinism.rs::alt_thread_counts). The final rung
# re-runs the client-group invariants with the minimal pool: sharding
# the client tier into K groups must stay byte-identical too.
test-par: test
	cd rust && ELIA_PAR_MAX=1 cargo test -q --test parallel_determinism
	cd rust && ELIA_PAR_MAX=2 cargo test -q --test parallel_determinism thread_count_invariant
	cd rust && ELIA_PAR_MAX=2 cargo test -q --test parallel_determinism client_group

# Analysis-pipeline suites: the rwsets/conflict/elim unit + qcheck
# properties (Dnf truth table, coverage/satisfiability soundness,
# components partition), the confluence-pass unit tests, and the
# end-to-end confluent replay soundness suite (tests/confluence.rs).
# The static analysis itself never takes a lock, but the confluence
# suite drives the real storage engine, so both rungs pin that the
# lock-manager shard count cannot change any result.
test-analysis:
	cd rust && ELIA_LOCK_SHARDS=1 cargo test -q --lib analysis::
	cd rust && ELIA_LOCK_SHARDS=1 cargo test -q --test confluence
	cd rust && ELIA_LOCK_SHARDS=32 cargo test -q --lib analysis::
	cd rust && ELIA_LOCK_SHARDS=32 cargo test -q --test confluence

# WAL crash-recovery suite under extra workload seeds. The plain `test`
# run already covers the default seed (0xC4A5); these rungs redrive the
# randomized crash/replay workloads (`ELIA_CRASH_SEED` steers the
# driver in tests/crash_recovery.rs) so torn-tail truncation and
# boundary replay hold beyond one transaction history.
test-crash:
	cd rust && ELIA_CRASH_SEED=1 cargo test -q --release --test crash_recovery
	cd rust && ELIA_CRASH_SEED=2 cargo test -q --release --test crash_recovery

# Served-system suites: frame-codec robustness properties (net_proto),
# the wire-level serializability/retry suite and ring fault injection
# over the deterministic loopback transport, and the real-TCP smoke
# test on 127.0.0.1 ephemeral ports. The loopback suites drive the real
# storage engine through handler threads, so both lock-shard settings
# run, mirroring test-analysis.
test-net:
	cd rust && ELIA_LOCK_SHARDS=1 cargo test -q --test net_proto --test net_serializability --test net_belt_fault
	cd rust && ELIA_LOCK_SHARDS=32 cargo test -q --test net_proto --test net_serializability --test net_belt_fault
	cd rust && cargo test -q --test net_tcp

# Live routing epochs (adaptive operation partitioning under drift):
# the static-vs-adaptive belted-fraction shape, epoch-switch soundness
# (contiguous token seqs, prefix-exact replicas across a switch) and
# the real-threads deployment's controller; release because the sim
# arms execute ~100k real operations each.
test-drift:
	cd rust && cargo test -q --release --test drift_adaptive

clippy:
	cd rust && cargo clippy -- -D warnings

# Rustdoc gate: `-D warnings` turns every rustdoc warning into an error,
# including `missing_docs` — scoped to the `db::` and `simnet::` public
# API via `#![cfg_attr(doc, warn(missing_docs))]` in their mod.rs — and
# broken intra-doc links anywhere. An undocumented public item in those
# modules fails the pre-PR gate.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Hot-path micro-benchmarks; writes BENCH_hotpath.json in rust/.
bench:
	cd rust && cargo bench --bench hotpath

# Single- vs multi-thread simulator benchmark (Conveyor modeled/real,
# Cluster 2PC, Baseline read-only); writes BENCH_sim.json.
bench-sim:
	cd rust && cargo bench --bench sim_parallel

# Table 1 classification summary — confluent vs conflict-only class
# counts for both workloads; writes BENCH_table1.json.
bench-table1:
	cd rust && cargo bench --bench table1_classification

# Live served-cluster counterpart of fig3: a real loopback cluster
# (framed wire protocol, belt token as ring messages) under real client
# threads; writes BENCH_live.json. CI passes --quick via BENCHFLAGS.
bench-live:
	cd rust && cargo bench --bench fig3_live -- $(BENCHFLAGS)

# Static vs adaptive routing under workload drift (the live-routing-
# epoch figure): per-second belted-fraction curves for both arms;
# writes BENCH_drift.json. ELIA_BENCH_QUICK=1 shrinks the scale on CI.
bench-drift:
	cd rust && cargo bench --bench drift_adaptive

# AOT-compile the Pallas partition-cost model to HLO text for the
# (feature-gated) PJRT runtime. Needs jax; see python/compile/aot.py.
artifacts:
	mkdir -p artifacts
	cd python && python3 -m compile.aot --out ../artifacts/partition_cost.hlo.txt
