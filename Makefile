# Developer entry points. `make check` is the pre-PR gate (see ROADMAP.md).

.PHONY: check build test clippy bench artifacts

# Pre-PR gate: release build + tests + lint, all from the rust crate.
check: build test clippy

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

clippy:
	cd rust && cargo clippy -- -D warnings

# Hot-path micro-benchmarks; writes BENCH_hotpath.json in rust/.
bench:
	cd rust && cargo bench --bench hotpath

# AOT-compile the Pallas partition-cost model to HLO text for the
# (feature-gated) PJRT runtime. Needs jax; see python/compile/aot.py.
artifacts:
	mkdir -p artifacts
	cd python && python3 -m compile.aot --out ../artifacts/partition_cost.hlo.txt
