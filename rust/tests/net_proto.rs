//! Protocol robustness (satellite of the served-system PR): property
//! round-trips of the frame codec and the full message set, plus
//! torn-frame / short-read / oversized-length / bit-flip fuzz that must
//! error *cleanly* — a `ProtoError`, never a panic — mirroring the
//! torn-tail discipline of `db::wal` recovery.

use elia::conveyor::{Token, TokenEntry};
use elia::db::{Key, StateUpdate, Value};
use elia::db::update::{ColOp, WriteRecord};
use elia::net::proto::{decode_msg, deframe, encode_msg, frame, read_frame, Msg, Role, WireError};
use elia::net::{ProtoError, FRAME_HEADER, MAX_FRAME};
use elia::util::qcheck::{check, Config};
use elia::util::Rng;
use elia::workload::spec::Reply;
use std::sync::Arc;

fn arb_value(rng: &mut Rng) -> Value {
    match rng.range(0, 4) {
        0 => Value::Int(rng.next_u64() as i64),
        1 => Value::Float((rng.next_u64() as i64 % 10_000) as f64 / 8.0),
        2 => {
            let len = rng.range(0, 12);
            Value::Str((0..len).map(|_| (b'a' + rng.range(0, 26) as u8) as char).collect())
        }
        _ => Value::Null,
    }
}

fn arb_key(rng: &mut Rng) -> Key {
    let cols = 1 + rng.range(0, 2);
    Key((0..cols)
        .map(|_| {
            if rng.chance(0.5) {
                Value::Int(rng.next_u64() as i64)
            } else {
                Value::Str(format!("k{}", rng.range(0, 1000)))
            }
        })
        .collect())
}

fn arb_update(rng: &mut Rng) -> StateUpdate {
    let n = rng.range(0, 4);
    let mut u = StateUpdate::new();
    for _ in 0..n {
        let rec = match rng.range(0, 3) {
            0 => WriteRecord::Insert {
                table: rng.range(0, 8),
                key: arb_key(rng),
                row: Arc::new((0..rng.range(1, 5)).map(|_| arb_value(rng)).collect()),
            },
            1 => WriteRecord::Update {
                table: rng.range(0, 8),
                key: arb_key(rng),
                cols: (0..rng.range(1, 4))
                    .map(|_| {
                        let op = if rng.chance(0.5) {
                            ColOp::Set(arb_value(rng))
                        } else {
                            ColOp::Add(Value::Int(rng.range(0, 100) as i64))
                        };
                        (rng.range(0, 6), op)
                    })
                    .collect(),
            },
            _ => WriteRecord::Delete { table: rng.range(0, 8), key: arb_key(rng) },
        };
        u.push(rec);
    }
    u
}

fn arb_token(rng: &mut Rng) -> Token {
    let n = 1 + rng.range(0, 5);
    let mut t = Token::new(n);
    for _ in 0..rng.range(0, 6) {
        t.append(rng.range(0, n), arb_update(rng));
    }
    // Advance some watermarks / rotation counters through the real API.
    for p in 0..n {
        if rng.chance(0.5) {
            let _ = t.on_receive(p);
        }
    }
    t.rotations = rng.range(0, 40) as u64;
    // Exercise the routing-epoch fields too (live re-partitioning).
    if rng.chance(0.5) {
        t.epoch = rng.range(0, 9) as u64;
        t.epoch_assignment =
            (0..rng.range(0, 5)).map(|_| rng.range(0, 5) as i64 - 1).collect();
        t.obs = (0..rng.range(0, 5)).map(|_| rng.range(0, 1000) as u64).collect();
    }
    t
}

fn arb_msg(rng: &mut Rng) -> Msg {
    match rng.range(0, 7) {
        0 => Msg::Hello {
            role: if rng.chance(0.5) { Role::Client } else { Role::Ring },
            app: format!("app{}", rng.range(0, 10)),
            n_servers: rng.range(1, 16) as u32,
            sender: rng.range(0, 16) as u32,
        },
        1 => Msg::HelloOk {
            server: rng.range(0, 16) as u32,
            epoch: rng.range(0, 9) as u64,
            assignment: (0..rng.range(0, 5)).map(|_| rng.range(0, 5) as i64 - 1).collect(),
        },
        2 => Msg::Request {
            txn: format!("txn{}", rng.range(0, 20)),
            args: (0..rng.range(0, 5))
                .map(|i| (format!("p{i}"), arb_value(rng)))
                .collect(),
            epoch: rng.range(0, 9) as u64,
        },
        3 => {
            let rows: Vec<Vec<Value>> = (0..rng.range(0, 5))
                .map(|_| (0..rng.range(1, 4)).map(|_| arb_value(rng)).collect())
                .collect();
            let affected = rng.range(0, 9);
            Msg::ReplyOk(Reply::from_owned_rows(rows, affected))
        }
        4 => Msg::ReplyErr(WireError {
            retryable: rng.chance(0.5),
            message: format!("err{}", rng.range(0, 1000)),
            epoch: if rng.chance(0.5) { Some(rng.range(0, 9) as u64) } else { None },
        }),
        5 => Msg::TokenPass {
            hop: rng.next_u64() >> 1,
            idle: rng.range(0, 64) as u32,
            token: arb_token(rng),
        },
        _ => Msg::TokenAck { hop: rng.next_u64() >> 1 },
    }
}

#[test]
fn frame_roundtrip_property() {
    check(Config::default().cases(300).name("frame-roundtrip"), |rng| {
        let len = rng.range(0, 2048);
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let framed = frame(&payload);
        assert_eq!(framed.len(), FRAME_HEADER + payload.len());
        let (got, consumed) = deframe(&framed).expect("clean deframe");
        assert_eq!(consumed, framed.len());
        assert_eq!(got, &payload[..]);
        // The streaming reader agrees with the slice reader.
        let mut cursor = std::io::Cursor::new(&framed);
        assert_eq!(read_frame(&mut cursor).expect("read_frame"), payload);
    });
}

#[test]
fn message_roundtrip_property() {
    check(Config::default().cases(300).name("msg-roundtrip"), |rng| {
        let msg = arb_msg(rng);
        let bytes = encode_msg(&msg);
        let back = decode_msg(&bytes).expect("decode of a clean encode");
        assert_eq!(back, msg);
        // And through the frame layer too.
        let (payload, _) = deframe(&frame(&bytes)).unwrap();
        assert_eq!(decode_msg(payload).expect("deframed decode"), msg);
    });
}

/// Any mutation of a valid frame — truncation, bit flips, garbage
/// prefixes — must produce `Err(ProtoError)`, never a panic and never a
/// silently wrong success.
#[test]
fn mutated_frames_error_cleanly() {
    check(Config::default().cases(400).name("frame-fuzz"), |rng| {
        let msg = arb_msg(rng);
        let framed = frame(&encode_msg(&msg));
        let mut bytes = framed.clone();
        match rng.range(0, 3) {
            0 => {
                // Truncate: short read / torn tail.
                let cut = rng.range(0, bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // Flip a bit somewhere.
                let i = rng.range(0, bytes.len());
                bytes[i] ^= 1 << rng.range(0, 8);
            }
            _ => {
                // Random garbage of arbitrary length.
                let len = rng.range(0, 64);
                bytes = (0..len).map(|_| rng.next_u64() as u8).collect();
            }
        }
        if bytes == framed {
            return; // mutation was a no-op (e.g. truncate at full length)
        }
        // deframe over the mutated slice: must be Ok (a valid reframe
        // of *different* bytes is impossible thanks to the checksum —
        // except a benign same-payload parse) or a clean error.
        match deframe(&bytes) {
            Ok((payload, _)) => {
                // Checksum held, so decode must not panic either way.
                let _ = decode_msg(payload);
            }
            Err(e) => {
                assert!(
                    matches!(
                        e,
                        ProtoError::Torn(_)
                            | ProtoError::Checksum
                            | ProtoError::Oversized { .. }
                            | ProtoError::Closed
                    ),
                    "unexpected error class: {e:?}"
                );
            }
        }
        // The streaming path must agree (and also never panic).
        let mut cursor = std::io::Cursor::new(&bytes);
        let _ = read_frame(&mut cursor);
    });
}

/// Checksum-valid payloads with mutated bodies: `decode_msg` must return
/// `ProtoError::Decode` (or a different valid message on a benign
/// mutation), never panic — the "corrupt body is a hard error" half of
/// the WAL taxonomy.
#[test]
fn mutated_payloads_never_panic() {
    check(Config::default().cases(400).name("payload-fuzz"), |rng| {
        let msg = arb_msg(rng);
        let mut payload = encode_msg(&msg);
        for _ in 0..1 + rng.range(0, 4) {
            if payload.is_empty() {
                break;
            }
            let i = rng.range(0, payload.len());
            payload[i] = rng.next_u64() as u8;
        }
        let _ = decode_msg(&payload); // must not panic
        // Truncations of the payload must not panic either.
        let cut = rng.range(0, payload.len() + 1);
        let _ = decode_msg(&payload[..cut]);
    });
}

#[test]
fn oversized_length_is_rejected_before_allocation() {
    // A hostile 4 GiB length prefix must be rejected from the header
    // alone — deframe and read_frame both refuse before any allocation.
    let mut bytes = vec![0u8; FRAME_HEADER];
    bytes[..4].copy_from_slice(&u32::MAX.to_le_bytes());
    match deframe(&bytes) {
        Err(ProtoError::Oversized { len, max }) => {
            assert_eq!(len, u32::MAX as usize);
            assert_eq!(max, MAX_FRAME);
        }
        other => panic!("expected Oversized, got {other:?}"),
    }
    let mut cursor = std::io::Cursor::new(&bytes);
    assert!(matches!(read_frame(&mut cursor), Err(ProtoError::Oversized { .. })));
}

#[test]
fn torn_header_and_torn_payload_are_distinguished_from_clean_eof() {
    let framed = frame(&encode_msg(&Msg::TokenAck { hop: 7 }));
    // Clean EOF at a frame boundary.
    let mut empty = std::io::Cursor::new(&[][..]);
    assert!(matches!(read_frame(&mut empty), Err(ProtoError::Closed)));
    // EOF mid-header.
    let mut torn_header = std::io::Cursor::new(&framed[..FRAME_HEADER / 2]);
    assert!(matches!(read_frame(&mut torn_header), Err(ProtoError::Torn(_))));
    // EOF mid-payload.
    let mut torn_payload = std::io::Cursor::new(&framed[..framed.len() - 1]);
    assert!(matches!(read_frame(&mut torn_payload), Err(ProtoError::Torn(_))));
}
