//! Smoke tests over the paper-figure experiment runners (quick scale):
//! every bench target's code path must run and show the paper's
//! qualitative shape. The full-scale numbers live in bench_output.txt.

use elia::harness::experiments::*;

#[test]
fn fig4_shape_elia_dominates_wan() {
    let scale = ExpScale::quick();
    let curves = fig4(Workload::Rubis, 5, &scale);
    assert_eq!(curves.len(), 4);
    let max_tput = |label_part: &str| {
        curves
            .iter()
            .find(|c| c.label.contains(label_part))
            .and_then(|c| c.peak(5000.0))
            .map(|p| p.point.throughput)
            .unwrap_or(0.0)
    };
    let cen = max_tput("centralized");
    let ro = max_tput("read-only");
    let warp = max_tput("warp");
    let elia = max_tput("elia");
    assert!(ro > cen, "read-only ({ro:.0}) must beat centralized ({cen:.0})");
    // Warp serves single-partition ops locally, so it clears the
    // single-funnel baseline even while paying the acyclic chain for
    // multi-partition commits.
    assert!(warp > cen, "warp ({warp:.0}) must beat centralized ({cen:.0})");
    // At quick scale (client-limited) elia and read-only race closely on
    // the read-heavy RUBiS mix; the full-scale run in bench_output.txt
    // shows the separation. Smoke: elia must at least match read-only and
    // clearly beat centralized.
    assert!(
        elia > ro * 0.85 && elia > cen * 1.5,
        "elia ({elia:.0}) vs read-only ({ro:.0}) / centralized ({cen:.0})"
    );
}

#[test]
fn fig5_shape_saturation_grows_with_local_ratio() {
    let scale = ExpScale::quick();
    let curves = fig5(&[0.3, 0.9], &scale);
    let knee = |i: usize| curves[i].peak(5000.0).map(|p| p.point.throughput).unwrap_or(0.0);
    let k30 = knee(0);
    let k90 = knee(1);
    assert!(
        k90 > k30 * 1.5,
        "saturation must grow with local ratio: 30%={k30:.0} 90%={k90:.0}"
    );
}

#[test]
fn fig6_light_load_flattens_heavy_keeps_falling() {
    let scale = ExpScale::quick();
    let ratios = [0.1, 0.5, 0.9];
    let light = fig6(&ratios, 16, &scale);
    let heavy = fig6(&ratios, 384, &scale);
    // Overall latency falls with more local ops in both regimes.
    assert!(light[0].1 > light[2].1, "light: {light:?}");
    assert!(heavy[0].1 > heavy[2].1, "heavy: {heavy:?}");
    // Global ops cost multiples of local ops at mid ratio.
    let (_, _, local, global) = light[1];
    assert!(global > 1.5 * local, "global {global} vs local {local}");
}

#[test]
fn fig3_elia_beats_cluster_on_both_workloads() {
    // Robust Fig-3 shape: Eliá's peak exceeds the data-partitioning
    // baseline's on both workloads at 4 servers. (The paper's much larger
    // TPC-W gap depends on MySQL Cluster internals our cost model keeps
    // conservative — see EXPERIMENTS.md "Deviations".)
    let scale = ExpScale::quick();
    // TPC-W at small N (clear Eliá win before the token ceiling binds),
    // RUBiS at 4 (Eliá wins across the whole range).
    for (w, n) in [(Workload::Tpcw, 2usize), (Workload::Rubis, 4)] {
        let rows = fig3(w, &[n], &scale);
        let elia = rows[0].2.peak(2000.0).map(|p| p.point.throughput).unwrap_or(0.0);
        let cluster = rows[1].2.peak(2000.0).map(|p| p.point.throughput).unwrap_or(1.0);
        assert!(
            elia > cluster,
            "{}: elia {elia:.0} must beat cluster {cluster:.0}",
            w.name()
        );
    }
}
