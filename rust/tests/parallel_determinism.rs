//! Determinism suite for the window-parallel simulators — Conveyor,
//! Cluster and Baseline all run on `simnet::parallel::run_windows`.
//!
//! The whole point of the parallel execution mode is that it can be
//! *trusted*: an N-thread run must be bit-identical to the 1-thread run
//! — same metrics, same event counts, same token rotations / lock-wait
//! totals, same final DB state on every server — across seeds and
//! topologies. This suite enforces exactly that (the ISSUE's acceptance
//! criterion), plus:
//!
//! * end-to-end coverage of the MAP misroute/redirect path
//!   (`misroute_prob > 0`), previously untested;
//! * a qcheck property: for random operation schedules, the committed
//!   replicated state of every server equals a *serial* replay of the
//!   token's total order of global updates — the Conveyor Belt
//!   serializability witness.
//!
//! The real-execution workloads here use point statements only: the
//! embedded engine's scan iteration order over hash storage is not part
//! of its determinism contract, while point accesses are fully
//! deterministic (see `src/simnet/README.md`, "Engine determinism").

use elia::baselines::{BaselineConfig, BaselineMode, BaselineReport, BaselineSim};
use elia::cluster::{ClusterConfig, ClusterReport, ClusterSim};
use elia::conveyor::{ConveyorConfig, ConveyorReport, ConveyorSim};
use elia::db::{BindSlots, Bindings, Db, Key, Value};
use elia::simnet::clients::ClientsConfig;
use elia::simnet::latency::{LatencyMatrix, Topology};
use elia::simnet::metrics::SimMetrics;
use elia::util::qcheck::{check_vec, Config};
use elia::util::{Rng, VTime};
use elia::workload::generator::{OpGenerator, ServiceModel};
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::spec::{AppSpec, Operation, TxnTemplate};

// ---------------------------------------------------------------- app --

const N_ITEMS: i64 = 8;
const N_CARTS: i64 = 256;
const INIT_LEVEL: i64 = 1000;

/// The Figure-1 store: local `add`, global `order` (derived STOCK key),
/// read-only local `view`. Point statements only.
fn store_app() -> AnalyzedApp {
    use elia::catalog::{Schema, TableSchema, ValueType};
    let schema = Schema::new(vec![
        TableSchema::new(
            "CARTS",
            &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
            &["CID"],
        ),
        TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        ),
    ]);
    let txns = vec![
        TxnTemplate::new(
            "add",
            &["cid"],
            &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        TxnTemplate::new(
            "order",
            &["cid"],
            &[
                ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived_item"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("r", args)?;
            let cid = args.get("cid").and_then(|v| v.as_int()).unwrap_or(0);
            let mut b = args.clone();
            b.insert("derived_item".to_string(), Value::Int(cid.rem_euclid(N_ITEMS)));
            ctx.exec("w", &b)
        }),
        TxnTemplate::new(
            "view",
            &["cid"],
            &[("q", "SELECT QTY FROM CARTS WHERE CID = ?cid")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
    ];
    let app = AnalyzedApp::analyze(AppSpec { name: "store".into(), schema, txns });
    assert_eq!(*app.class(0), elia::analysis::OpClass::Local);
    assert_eq!(*app.class(1), elia::analysis::OpClass::Global);
    app
}

fn seed_store(db: &Db) {
    let cart = db.prepare_sql("INSERT INTO CARTS (CID, QTY) VALUES (?c, 0)").unwrap();
    let stock = db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, ?l)").unwrap();
    for c in 0..N_CARTS {
        db.exec_auto_prepared(&cart, &BindSlots(vec![Value::Int(c)])).unwrap();
    }
    for i in 0..N_ITEMS {
        db.exec_auto_prepared(&stock, &BindSlots(vec![Value::Int(i), Value::Int(INIT_LEVEL)]))
            .unwrap();
    }
}

fn op(txn: usize, cid: i64) -> Operation {
    let args: Bindings = [("cid".to_string(), Value::Int(cid))].into_iter().collect();
    Operation { txn, args }
}

/// Random mixed workload (site-affine locals, derived-key globals).
struct MixGen {
    global_ratio: f64,
}

impl OpGenerator for MixGen {
    fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
        let cid = (rng.range(0, N_CARTS as usize / n.max(1)) * n + site) as i64 % N_CARTS;
        if rng.chance(self.global_ratio) {
            op(1, cid)
        } else {
            op(0, cid)
        }
    }
}

/// Replays a fixed schedule, then issues read-only `view`s (quiesce
/// filler): the sim's closed loop keeps running, but global state stops
/// changing, so the token can distribute every update before the horizon.
struct ScheduleGen {
    ops: Vec<Operation>,
    next: usize,
}

impl OpGenerator for ScheduleGen {
    fn next_op(&mut self, _rng: &mut Rng, site: usize, _n: usize) -> Operation {
        if self.next < self.ops.len() {
            let o = self.ops[self.next].clone();
            self.next += 1;
            o
        } else {
            op(2, site as i64 % N_CARTS)
        }
    }
}

// ------------------------------------------------------------ helpers --

/// The paper-relevant topology shapes: LAN cluster, WAN ring, and WAN
/// with clients at all five sites (exercises `client_matrix` and the
/// nearest-server selection path).
fn topologies() -> Vec<(&'static str, Topology, Option<LatencyMatrix>)> {
    vec![
        ("lan4", Topology::lan(4), None),
        ("wan3", Topology::wan(3), None),
        ("wan3+clients5", Topology::wan(3), Some(Topology::wan_full_client(5))),
    ]
}

struct RunSpec {
    topo: Topology,
    client_matrix: Option<LatencyMatrix>,
    seed: u64,
    threads: usize,
    /// Client groups the tier is sharded into (1 = the classic single
    /// tier; 0 = one group per available core, capped at the client
    /// count).
    groups: usize,
    real: bool,
    misroute: f64,
}

fn run_store(
    spec: RunSpec,
    gen: impl FnMut(usize) -> Box<dyn OpGenerator>,
) -> (ConveyorReport, Vec<Option<Db>>) {
    let app = store_app();
    let cfg = ConveyorConfig {
        execute_real: spec.real,
        record_global_log: spec.real,
        misroute_prob: spec.misroute,
        service: ServiceModel::default(), // jittered: exercises RNG streams
        client_matrix: spec.client_matrix,
        parallel: spec.threads,
        warmup: VTime::from_secs(1),
        horizon: VTime::from_secs(6),
        seed: spec.seed,
        ..Default::default()
    };
    ConveyorSim::new(
        &app,
        spec.topo,
        ClientsConfig {
            n: 24,
            think_ms: 10.0,
            seed: spec.seed,
            groups: spec.groups,
            ..Default::default()
        },
        cfg,
        gen,
        seed_store,
    )
    .run_keep_dbs()
}

/// Bitwise signature of a metrics object: counts plus exact latency
/// statistics (mean, p50, p99 as raw f64 bits — "identical" means
/// identical, not approximately equal).
fn metrics_sig(m: &SimMetrics) -> Vec<u64> {
    let mut lat = m.latency.clone();
    let mut loc = m.local_latency.clone();
    let mut glo = m.global_latency.clone();
    vec![
        m.completed,
        m.aborted,
        lat.count() as u64,
        loc.count() as u64,
        glo.count() as u64,
        lat.mean().to_bits(),
        lat.p50().to_bits(),
        lat.p99().to_bits(),
        loc.mean().to_bits(),
        glo.mean().to_bits(),
    ]
}

/// Client-group-insensitive metrics signature: integer-exact statistics
/// only. The `Summary` means accumulate f64 samples in per-group
/// arrival order, so their bits are *not* comparable across group
/// counts; the bucketed histograms (element-wise u64 counters) and the
/// integer counters are — exactly.
fn ksig_metrics(m: &SimMetrics) -> Vec<u64> {
    let mut v = vec![m.completed, m.aborted];
    for h in [&m.latency_hist, &m.local_hist, &m.global_hist] {
        v.push(h.count());
        v.push(h.sum_us());
        v.push(h.mean_ms().to_bits());
        v.extend(h.buckets().iter().copied());
    }
    v
}

fn assert_identical_k(a: &ConveyorReport, b: &ConveyorReport, ctx: &str) {
    assert_eq!(ksig_metrics(&a.metrics), ksig_metrics(&b.metrics), "metrics differ: {ctx}");
    assert_eq!(a.events, b.events, "event counts differ: {ctx}");
    assert_eq!(a.rotations, b.rotations, "rotations differ: {ctx}");
    assert_eq!(a.aborts, b.aborts, "aborts differ: {ctx}");
    assert_eq!(a.db_hashes, b.db_hashes, "DB digests differ: {ctx}");
    assert_eq!(a.global_log, b.global_log, "token logs differ: {ctx}");
    assert_eq!(a.global_log_seqs, b.global_log_seqs, "token log seqs differ: {ctx}");
    assert_adaptive_identical(a, b, ctx);
    let ua: Vec<u64> = a.utilization.iter().map(|u| u.to_bits()).collect();
    let ub: Vec<u64> = b.utilization.iter().map(|u| u.to_bits()).collect();
    assert_eq!(ua, ub, "utilization differs: {ctx}");
}

fn assert_identical(a: &ConveyorReport, b: &ConveyorReport, ctx: &str) {
    assert_eq!(metrics_sig(&a.metrics), metrics_sig(&b.metrics), "metrics differ: {ctx}");
    assert_eq!(a.events, b.events, "event counts differ: {ctx}");
    assert_eq!(a.rotations, b.rotations, "rotations differ: {ctx}");
    assert_eq!(a.aborts, b.aborts, "aborts differ: {ctx}");
    assert_eq!(a.db_hashes, b.db_hashes, "DB digests differ: {ctx}");
    assert_eq!(a.global_log, b.global_log, "token logs differ: {ctx}");
    assert_eq!(a.global_log_seqs, b.global_log_seqs, "token log seqs differ: {ctx}");
    assert_adaptive_identical(a, b, ctx);
    let ua: Vec<u64> = a.utilization.iter().map(|u| u.to_bits()).collect();
    let ub: Vec<u64> = b.utilization.iter().map(|u| u.to_bits()).collect();
    assert_eq!(ua, ub, "utilization differs: {ctx}");
}

/// The adaptive-routing telemetry must be bit-identical too: same
/// switches, same final epoch, same redirect count, same per-second
/// drift curve.
fn assert_adaptive_identical(a: &ConveyorReport, b: &ConveyorReport, ctx: &str) {
    assert_eq!(a.epoch_switches, b.epoch_switches, "epoch switches differ: {ctx}");
    assert_eq!(a.final_epoch, b.final_epoch, "final epochs differ: {ctx}");
    assert_eq!(a.redirects, b.redirects, "redirect counts differ: {ctx}");
    assert_eq!(a.drift_curve, b.drift_curve, "drift curves differ: {ctx}");
}

/// Thread counts compared against the 1-thread baseline. `ELIA_PAR_MAX`
/// caps the "all cores" rung (the `make test-par` ladder pins it to 1
/// and 2 before an uncapped run).
fn alt_thread_counts() -> Vec<usize> {
    match std::env::var("ELIA_PAR_MAX").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(cap) => vec![cap.max(1)],
        None => vec![2, 0], // 0 = all available cores
    }
}

// -------------------------------------------------------------- tests --

/// Acceptance criterion: ≥3 seeds × ≥2 topologies, modeled execution —
/// N-thread runs match the 1-thread run exactly.
#[test]
fn thread_count_invariant_modeled_execution() {
    for (name, topo, cm) in topologies() {
        for seed in [0x5EEDu64, 1, 42] {
            let mk = |threads| RunSpec {
                topo: topo.clone(),
                client_matrix: cm.clone(),
                seed,
                threads,
                groups: 1,
                real: false,
                misroute: 0.0,
            };
            let (base, _) = run_store(mk(1), |_| Box::new(MixGen { global_ratio: 0.3 }));
            assert!(base.metrics.completed > 100, "{name}/{seed}: too few completions");
            for threads in alt_thread_counts() {
                let (r, _) = run_store(mk(threads), |_| Box::new(MixGen { global_ratio: 0.3 }));
                assert_identical(&base, &r, &format!("{name} seed={seed} threads={threads}"));
            }
        }
    }
}

/// Acceptance criterion, real-execution half: per-server DB state
/// digests (and the token's update log) are identical too.
#[test]
fn thread_count_invariant_real_execution_digests() {
    for (name, topo, cm) in topologies() {
        for seed in [7u64, 0xB5EED, 3030] {
            let mk = |threads| RunSpec {
                topo: topo.clone(),
                client_matrix: cm.clone(),
                seed,
                threads,
                groups: 1,
                real: true,
                misroute: 0.0,
            };
            let (base, _) = run_store(mk(1), |_| Box::new(MixGen { global_ratio: 0.4 }));
            assert!(base.metrics.completed > 100, "{name}/{seed}: too few completions");
            assert!(base.db_hashes.iter().all(|h| h.is_some()));
            for threads in alt_thread_counts() {
                let (r, _) = run_store(mk(threads), |_| Box::new(MixGen { global_ratio: 0.4 }));
                assert_identical(&base, &r, &format!("{name} seed={seed} threads={threads}"));
            }
        }
    }
}

/// Satellite: end-to-end MAP redirect coverage. Misrouted operations
/// still commit (no aborts, completions stay healthy), the metrics count
/// the two extra hops as added latency, and the redirect path is itself
/// thread-count invariant (it draws from the per-client RNG streams).
#[test]
fn misroute_redirect_end_to_end() {
    let spec = |threads, misroute| RunSpec {
        topo: Topology::lan(3),
        client_matrix: None,
        seed: 9,
        threads,
        groups: 1,
        real: true,
        misroute,
    };
    let (clean, _) = run_store(spec(1, 0.0), |_| Box::new(MixGen { global_ratio: 0.2 }));
    let (dirty, _) = run_store(spec(1, 0.25), |_| Box::new(MixGen { global_ratio: 0.2 }));
    // Redirected operations still execute and commit.
    assert_eq!(dirty.aborts, 0, "redirected ops must still commit");
    assert!(
        dirty.metrics.completed as f64 > clean.metrics.completed as f64 * 0.7,
        "redirects must not strand operations: clean={} dirty={}",
        clean.metrics.completed,
        dirty.metrics.completed
    );
    // The extra hops show up in measured latency (~25% of ops pay two
    // extra one-way legs of >= 10 ms each).
    assert!(
        dirty.mean_latency_ms() > clean.mean_latency_ms() + 2.0,
        "clean={} dirty={}",
        clean.mean_latency_ms(),
        dirty.mean_latency_ms()
    );
    // Global updates still replicate: the token log is non-empty and the
    // digests exist on every server.
    assert!(!dirty.global_log.is_empty());
    assert!(dirty.db_hashes.iter().all(|h| h.is_some()));
    // And the redirect path is deterministic under parallelism.
    for threads in alt_thread_counts() {
        let (r, _) = run_store(spec(threads, 0.25), |_| Box::new(MixGen { global_ratio: 0.2 }));
        assert_identical(&dirty, &r, &format!("misroute threads={threads}"));
    }
}

// ---- client-group sharding (tentpole acceptance) ----

/// Thread × group combinations compared against the (1 thread, 1 group)
/// baseline. Groups: 2 and 0 ("one per core", the fan-out default);
/// threads follow the `ELIA_PAR_MAX` ladder.
fn k_combos() -> Vec<(usize, usize)> {
    let mut v = vec![(1usize, 2usize), (1, 0)];
    for t in alt_thread_counts() {
        v.push((t, 2));
        v.push((t, 0));
    }
    v
}

/// Tentpole acceptance: sharding the client tier into K groups changes
/// nothing. K ∈ {1, 2, all-cores} × thread ladder, across seeds and
/// topologies, compared bit-for-bit against the K=1 single-thread run.
/// `MixGen` is rng-pure (it draws only from the per-client streams), so
/// every client sees the identical random sequence at any K.
#[test]
fn client_group_count_invariant_modeled_execution() {
    for (name, topo, cm) in topologies() {
        for seed in [0x5EEDu64, 42] {
            let mk = |threads, groups| RunSpec {
                topo: topo.clone(),
                client_matrix: cm.clone(),
                seed,
                threads,
                groups,
                real: false,
                misroute: 0.0,
            };
            let (base, _) = run_store(mk(1, 1), |_| Box::new(MixGen { global_ratio: 0.3 }));
            assert!(base.metrics.completed > 100, "{name}/{seed}: too few completions");
            for (threads, groups) in k_combos() {
                let (r, _) = run_store(mk(threads, groups), |_| {
                    Box::new(MixGen { global_ratio: 0.3 })
                });
                assert_identical_k(
                    &base,
                    &r,
                    &format!("{name} seed={seed} threads={threads} groups={groups}"),
                );
            }
        }
    }
}

/// Real-execution half of the group invariant: per-server DB digests and
/// the token's total-order log are also unchanged by client sharding —
/// including under misrouting, whose redirect draws come from the
/// per-client streams too.
#[test]
fn client_group_count_invariant_real_execution_digests() {
    let mk = |threads, groups| RunSpec {
        topo: Topology::lan(3),
        client_matrix: None,
        seed: 7,
        threads,
        groups,
        real: true,
        misroute: 0.25,
    };
    let (base, _) = run_store(mk(1, 1), |_| Box::new(MixGen { global_ratio: 0.4 }));
    assert!(base.metrics.completed > 100, "too few completions");
    assert!(!base.global_log.is_empty());
    assert!(base.db_hashes.iter().all(|h| h.is_some()));
    for (threads, groups) in k_combos() {
        let (r, _) = run_store(mk(threads, groups), |_| Box::new(MixGen { global_ratio: 0.4 }));
        assert_identical_k(&base, &r, &format!("real threads={threads} groups={groups}"));
    }
}

// ---- adaptive routing epochs (drift-schedule invariant) ----

/// Satellite: live routing epochs are deterministic by construction —
/// clients issue under the immutable epoch 0 while servers re-route at
/// arrival under the installed epoch, so the *entire* adaptive run
/// (epoch switches, redirects, drift curve, token log, DB digests) must
/// be bit-identical across thread and client-group counts. `DriftGen`
/// is rng- and time-pure, which is what makes the client tier a pure
/// function of its streams.
#[test]
fn adaptive_drift_thread_and_group_invariant() {
    use elia::analysis::drift::{AdaptiveConfig, DriftConfig};
    use elia::workload::micro;
    let run = |threads: usize, groups: usize| {
        let app = micro::drift_analyzed();
        let cfg = ConveyorConfig {
            execute_real: true,
            record_global_log: true,
            service: ServiceModel::fixed(1.0),
            warmup: VTime::from_secs(1),
            horizon: VTime::from_secs(16),
            parallel: threads,
            adaptive: Some(AdaptiveConfig { window_rotations: 32, ..Default::default() }),
            ..Default::default()
        };
        ConveyorSim::new(
            &app,
            Topology::lan(3),
            ClientsConfig {
                n: 24,
                think_ms: 10.0,
                seed: 0xD21F,
                groups,
                ..Default::default()
            },
            cfg,
            |_| Box::new(micro::DriftGen::new(DriftConfig::default())),
            micro::drift_seed,
        )
        .run()
    };
    let base = run(1, 1);
    assert!(base.metrics.completed > 1000, "too few completions");
    assert!(base.epoch_switches >= 1, "the drift must trigger a switch");
    assert!(base.redirects > 0, "the flipped pin must redirect stale-routed ops");
    assert!(!base.global_log.is_empty());
    for (threads, groups) in k_combos() {
        let r = run(threads, groups);
        assert_identical_k(&base, &r, &format!("adaptive threads={threads} groups={groups}"));
    }
}

// ---- ClusterSim / BaselineSim on the window engine (ISSUE 3) ----

/// Mixed cluster workload: local point writes, multi-statement writes
/// with a derived (Zipf-hot) key, and read-only views — exercises the
/// single-shard, 2PC and scatter paths plus the sharded lock table.
struct ClusterMixGen;

impl OpGenerator for ClusterMixGen {
    fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
        let cid = rng.range(0, N_CARTS as usize) as i64;
        match rng.range(0, 4) {
            0 | 1 => op(0, cid),
            2 => op(1, cid),
            _ => op(2, cid),
        }
    }
}

/// Bitwise signature of a cluster run: metrics plus event counts,
/// lock-wait totals, lock-table high-water mark and utilizations.
fn cluster_sig(r: &ClusterReport) -> Vec<u64> {
    let mut v = metrics_sig(&r.metrics);
    v.push(r.events);
    v.push(r.lock_waits);
    v.push(r.lock_entries as u64);
    v.push(r.lock_entries_peak as u64);
    v.extend(r.utilization.iter().map(|u| u.to_bits()));
    v
}

fn baseline_sig(r: &BaselineReport) -> Vec<u64> {
    let mut v = metrics_sig(&r.metrics);
    v.push(r.events);
    v.extend(r.utilization.iter().map(|u| u.to_bits()));
    v
}

/// Acceptance criterion: `ClusterSim` on the window engine — seeds ×
/// {lan4, wan3} × {1, 2, all} threads produce bitwise-equal metrics,
/// event counts and lock-wait totals.
#[test]
fn cluster_thread_count_invariant() {
    for (name, topo) in [("lan4", Topology::lan(4)), ("wan3", Topology::wan(3))] {
        for seed in [0xC1B5u64, 11, 77] {
            let run = |threads: usize| {
                let app = store_app();
                let cfg = ClusterConfig {
                    service: ServiceModel::default(), // jittered: exercises RNG streams
                    warmup: VTime::from_secs(1),
                    horizon: VTime::from_secs(6),
                    seed,
                    parallel: threads,
                    ..Default::default()
                };
                ClusterSim::new(
                    &app,
                    topo.clone(),
                    ClientsConfig { n: 24, think_ms: 10.0, seed, ..Default::default() },
                    cfg,
                    |_| Box::new(ClusterMixGen),
                )
                .run()
            };
            let base = run(1);
            assert!(
                base.metrics.completed > 100,
                "cluster {name}/{seed}: too few completions ({})",
                base.metrics.completed
            );
            assert!(base.lock_waits > 0, "cluster {name}/{seed}: no lock contention seen");
            for threads in alt_thread_counts() {
                let r = run(threads);
                assert_eq!(
                    cluster_sig(&base),
                    cluster_sig(&r),
                    "cluster differs: {name} seed={seed} threads={threads}"
                );
            }
        }
    }
}

/// Acceptance criterion: `BaselineSim` on the window engine — seeds ×
/// topologies × {1, 2, all} threads, both baseline modes (the
/// centralized single group and the read-only replica fan-out).
#[test]
fn baseline_thread_count_invariant() {
    let topos = [
        ("lan4", Topology::lan(4).servers, BaselineMode::ReadOnly { n_servers: 4 }),
        ("wan3", Topology::wan(3).servers, BaselineMode::ReadOnly { n_servers: 3 }),
        ("wan5-central", Topology::wan_full_client(5), BaselineMode::Centralized),
    ];
    for (name, sites, mode) in topos {
        for seed in [0xBA5Eu64, 13] {
            let run = |threads: usize| {
                let app = store_app();
                let cfg = BaselineConfig {
                    mode,
                    service: ServiceModel::default(),
                    warmup: VTime::from_secs(1),
                    horizon: VTime::from_secs(6),
                    seed,
                    parallel: threads,
                    ..BaselineConfig::centralized()
                };
                BaselineSim::new(
                    &app,
                    sites.clone(),
                    ClientsConfig { n: 24, think_ms: 10.0, seed, ..Default::default() },
                    cfg,
                    |_| Box::new(ClusterMixGen),
                )
                .run()
            };
            let base = run(1);
            assert!(
                base.metrics.completed > 100,
                "baseline {name}/{seed}: too few completions ({})",
                base.metrics.completed
            );
            for threads in alt_thread_counts() {
                let r = run(threads);
                assert_eq!(
                    baseline_sig(&base),
                    baseline_sig(&r),
                    "baseline differs: {name} seed={seed} threads={threads}"
                );
            }
        }
    }
}

/// `ClusterSim` on the sharded client tier: 2PC replies land at
/// per-group targets and issues merge by the global client tag, so a
/// grouped run must match the single-tier run exactly (integer-exact
/// signature; `ClusterMixGen` is rng-pure).
#[test]
fn cluster_client_group_invariant() {
    let ksig = |r: &ClusterReport| {
        let mut v = ksig_metrics(&r.metrics);
        v.push(r.events);
        v.push(r.lock_waits);
        v.push(r.lock_entries as u64);
        v.push(r.lock_entries_peak as u64);
        v.extend(r.utilization.iter().map(|u| u.to_bits()));
        v
    };
    for (name, topo) in [("lan4", Topology::lan(4)), ("wan3", Topology::wan(3))] {
        let seed = 0xC1B5u64;
        let run = |threads: usize, groups: usize| {
            let app = store_app();
            let cfg = ClusterConfig {
                service: ServiceModel::default(),
                warmup: VTime::from_secs(1),
                horizon: VTime::from_secs(6),
                seed,
                parallel: threads,
                ..Default::default()
            };
            ClusterSim::new(
                &app,
                topo.clone(),
                ClientsConfig { n: 24, think_ms: 10.0, seed, groups, ..Default::default() },
                cfg,
                |_| Box::new(ClusterMixGen),
            )
            .run()
        };
        let base = run(1, 1);
        assert!(base.metrics.completed > 100, "cluster {name}: too few completions");
        for (threads, groups) in k_combos() {
            let r = run(threads, groups);
            assert_eq!(
                ksig(&base),
                ksig(&r),
                "cluster differs: {name} threads={threads} groups={groups}"
            );
        }
    }
}

/// `BaselineSim` on the sharded client tier, both modes.
#[test]
fn baseline_client_group_invariant() {
    let ksig = |r: &BaselineReport| {
        let mut v = ksig_metrics(&r.metrics);
        v.push(r.events);
        v.extend(r.utilization.iter().map(|u| u.to_bits()));
        v
    };
    let topos = [
        ("wan3", Topology::wan(3).servers, BaselineMode::ReadOnly { n_servers: 3 }),
        ("wan5-central", Topology::wan_full_client(5), BaselineMode::Centralized),
    ];
    for (name, sites, mode) in topos {
        let seed = 0xBA5Eu64;
        let run = |threads: usize, groups: usize| {
            let app = store_app();
            let cfg = BaselineConfig {
                mode,
                service: ServiceModel::default(),
                warmup: VTime::from_secs(1),
                horizon: VTime::from_secs(6),
                seed,
                parallel: threads,
                ..BaselineConfig::centralized()
            };
            BaselineSim::new(
                &app,
                sites.clone(),
                ClientsConfig { n: 24, think_ms: 10.0, seed, groups, ..Default::default() },
                cfg,
                |_| Box::new(ClusterMixGen),
            )
            .run()
        };
        let base = run(1, 1);
        assert!(base.metrics.completed > 100, "baseline {name}: too few completions");
        for (threads, groups) in k_combos() {
            let r = run(threads, groups);
            assert_eq!(
                ksig(&base),
                ksig(&r),
                "baseline differs: {name} threads={threads} groups={groups}"
            );
        }
    }
}

/// Serial replay of a token log over a freshly seeded store.
fn replay_serially(app: &AnalyzedApp, log: &[elia::db::StateUpdate]) -> Db {
    let db = Db::new(app.spec.schema.clone());
    seed_store(&db);
    for u in log {
        db.apply_update(u).unwrap();
    }
    db
}

fn stock_levels(db: &Db) -> Vec<i64> {
    (0..N_ITEMS)
        .map(|i| {
            db.peek("STOCK", &Key::single(Value::Int(i)))
                .expect("stock row")[1]
                .as_int()
                .unwrap()
        })
        .collect()
}

/// Tentpole property (qcheck): for random operation schedules, once the
/// schedule drains and the token quiesces, every server's replicated
/// STOCK table equals a *serial* replay of the token's total order of
/// global updates — the committed state of every server converges to a
/// serial order of the token history. Checked at 1 and 2 threads, which
/// must also agree with each other exactly.
#[test]
fn committed_state_converges_to_serial_token_order() {
    let cases = Config::default().cases(10).name("token-serial-order");
    check_vec(
        cases,
        |rng: &mut Rng| {
            let kind = rng.range(0, 4) as u8; // 2x local, 1x global, 1x view
            let cid = rng.range(0, N_CARTS as usize) as i64;
            (kind, cid)
        },
        40,
        |schedule: &[(u8, i64)]| {
            // Built inside the property: `AnalyzedApp` holds `Arc<dyn Fn>`
            // bodies, which are not `RefUnwindSafe` captures.
            let app = store_app();
            let ops: Vec<Operation> = schedule
                .iter()
                .map(|&(kind, cid)| match kind {
                    0 | 1 => op(0, cid),
                    2 => op(1, cid),
                    _ => op(2, cid),
                })
                .collect();
            let globals = ops.iter().filter(|o| o.txn == 1).count() as i64;
            let mut prev: Option<(ConveyorReport, Vec<i64>)> = None;
            for threads in [1usize, 2] {
                let spec = RunSpec {
                    topo: Topology::lan(3),
                    client_matrix: None,
                    seed: 0xC0FFEE,
                    threads,
                    // ScheduleGen is stateful (a shared cursor), so it is
                    // only deterministic with the single client group.
                    groups: 1,
                    real: true,
                    misroute: 0.0,
                };
                let (r, dbs) =
                    run_store(spec, |_| Box::new(ScheduleGen { ops: ops.clone(), next: 0 }));
                assert_eq!(r.aborts, 0, "schedule must commit cleanly");
                assert_eq!(r.global_log.len() as i64, globals, "every global is ordered once");
                let replay = replay_serially(&app, &r.global_log);
                let serial = stock_levels(&replay);
                // Serial replay sells exactly the ordered units...
                let sold: i64 = serial.iter().map(|l| INIT_LEVEL - l).sum();
                assert_eq!(sold, globals, "serial replay must sell exactly the ordered units");
                // ...and every server's replicated table equals it.
                for (s, db) in dbs.iter().enumerate() {
                    let db = db.as_ref().expect("real-execution db");
                    assert_eq!(
                        stock_levels(db),
                        serial,
                        "server {s} (threads={threads}) diverged from the serial token order"
                    );
                }
                if let Some((base, base_serial)) = &prev {
                    assert_eq!(&serial, base_serial);
                    assert_identical(base, &r, "property threads=1 vs 2");
                }
                prev = Some((r, serial));
            }
            true
        },
    );
}
