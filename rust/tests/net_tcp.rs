//! Real-socket smoke test: the same binary protocol that the loopback
//! suites pin down, over actual 127.0.0.1 TCP sockets — a 3-server
//! TPC-W cluster on ephemeral ports, driven by concurrent clients, with
//! a replica-convergence check at shutdown. This is the CI stand-in for
//! `elia serve` / `elia client`.

use elia::harness::experiments::{replica_hash, replicated_tables, Workload};
use elia::net::{Cluster, NetError, ServeConfig, Tcp, Transport};
use elia::util::Rng;
use std::sync::Arc;

#[test]
fn tpcw_over_real_tcp_sockets_converges() {
    let n = 3;
    let workload = Workload::Tpcw;
    let app = Arc::new(workload.analyzed());
    let transport: Arc<dyn Transport> = Arc::new(Tcp);
    // Port 0: the kernel picks free ports; resolved addresses come back
    // through `client_addrs`, so parallel test runs never collide.
    let cluster = Cluster::start(
        Arc::clone(&app),
        ServeConfig::tcp(n, 0),
        transport,
        |db| workload.seed_db(db),
    )
    .unwrap();
    for addr in cluster.client_addrs() {
        assert!(!addr.ends_with(":0"), "listen address must resolve to a real port: {addr}");
    }

    let cluster = Arc::new(cluster);
    let mut handles = Vec::new();
    for g in 0..2usize {
        let cluster = Arc::clone(&cluster);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            let mut client = cluster.client(Arc::clone(&app)).unwrap();
            let mut generator = workload.generator_for(&app, n, g);
            let mut rng = Rng::stream(0x7C9, g as u64);
            let (mut ok, mut errs) = (0u64, 0u64);
            for _ in 0..60 {
                let op = generator.next_op(&mut rng, g % n, n);
                match client.submit(&op) {
                    Ok(_) => ok += 1,
                    // Semantic rejections (generated-id collisions etc.)
                    // are benign, as in the in-process integration tests.
                    Err(NetError::Server(_)) => errs += 1,
                    Err(NetError::Transport(e)) => panic!("transport failure over TCP: {e}"),
                }
            }
            (ok, errs)
        }));
    }
    let mut ok = 0;
    for h in handles {
        ok += h.join().unwrap().0;
    }
    cluster.shutdown();
    assert!(ok > 0, "at least some TPC-W operations must commit over TCP");

    let tables = replicated_tables(&app);
    assert!(!tables.is_empty(), "TPC-W must have token-replicated tables");
    let h0 = replica_hash(cluster.db(0), &tables);
    for s in 1..n {
        assert_eq!(replica_hash(cluster.db(s), &tables), h0, "server {s} diverged over TCP");
    }
}
