//! Wire-level serializability: the `tests/serializability.rs` witness
//! invariants re-run through the *served* system — real framed messages
//! over the deterministic loopback transport, three servers, a mix of
//! local, global, and confluent operations.
//!
//! Checks:
//! 1. **Runtime equivalence** — a fixed single-client history driven
//!    through the network produces bit-identical per-server
//!    `content_hash` to the same history on the in-process
//!    [`Deployment`] (same routing, same token order, same replay).
//! 2. **Invariants under concurrency** — no oversell, conservation, and
//!    replicated-table convergence with 8 racing wire clients.
//! 3. **Token history oracle** — every replicated update appears in the
//!    belt history exactly once (sequence numbers contiguous).
//! 4. **Retry classification** — lock conflicts come back retryable and
//!    are absorbed by the client stub; invariant violations come back
//!    non-retryable and surface immediately.

mod common;

use common::{op, seed, store_app, INIT_STOCK, N_ITEMS};
use elia::conveyor::{DeployConfig, Deployment};
use elia::db::{Key, Value};
use elia::harness::experiments::{replica_hash, replicated_tables};
use elia::net::{Cluster, Loopback, NetError, ServeConfig, Transport};
use elia::util::Rng;
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::spec::Operation;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// The fixed mixed history used by the equivalence test: local adds and
/// reads, global orders, confluent rates.
fn fixed_history(app: &AnalyzedApp) -> Vec<Operation> {
    let mut ops = Vec::new();
    for c in 0..30i64 {
        ops.push(op(app, "add", &[("c", c), ("t", c % N_ITEMS), ("a", 1 + c % 3)]));
        ops.push(op(app, "rate", &[("t", c % N_ITEMS), ("q", c % 5)]));
        if c % 3 == 0 {
            ops.push(op(app, "add", &[("c", c), ("t", (c + 1) % N_ITEMS), ("a", 2)]));
        }
        ops.push(op(app, "readCart", &[("c", c)]));
        if c % 2 == 0 {
            ops.push(op(app, "order", &[("c", c)]));
        }
    }
    ops
}

/// (1) Runtime equivalence: the served system and the in-process
/// deployment execute a fixed history to bit-identical per-server state
/// — full `content_hash`, every table, every server.
#[test]
fn wire_history_matches_in_process_deployment() {
    let n = 3;
    let app = store_app();
    let history = fixed_history(&app);

    // In-process reference.
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers: n, ..Default::default() },
        seed,
    );
    for o in &history {
        dep.submit(o.clone()).unwrap();
    }
    dep.shutdown();

    // The same history over the wire.
    let transport: Arc<dyn Transport> = Arc::new(Loopback::new());
    let cluster =
        Cluster::start(Arc::clone(&app), ServeConfig::loopback(n), transport, seed).unwrap();
    let mut client = cluster.client(Arc::clone(&app)).unwrap();
    for o in &history {
        client.submit(o).unwrap();
    }
    cluster.shutdown();

    for s in 0..n {
        assert_eq!(
            cluster.db(s).content_hash(),
            dep.db(s).content_hash(),
            "server {s}: served state diverged from in-process deployment"
        );
    }
}

/// (2) + (3) Concurrency invariants and the token-history oracle over
/// the wire: 8 racing clients, then no oversell, conservation,
/// replicated-table convergence, rating-sum accounting, and a
/// no-dup/no-loss check on the recorded belt history.
#[test]
fn wire_invariants_hold_under_concurrent_clients() {
    let n = 3;
    let app = store_app();
    let transport: Arc<dyn Transport> = Arc::new(Loopback::new());
    let cfg = ServeConfig { record_history: true, ..ServeConfig::loopback(n) };
    let cluster = Arc::new(Cluster::start(Arc::clone(&app), cfg, transport, seed).unwrap());

    let mut handles = Vec::new();
    for t in 0..8u64 {
        let cluster = Arc::clone(&cluster);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            let mut client = cluster.client(Arc::clone(&app)).unwrap();
            let mut rng = Rng::new(t + 1);
            let mut rated = 0i64;
            for i in 0..40 {
                let cart = (t * 1000 + i) as i64;
                let item = rng.range(0, N_ITEMS as usize) as i64;
                let qty = 1 + rng.range(0, 3) as i64;
                client.submit(&op(&app, "add", &[("c", cart), ("t", item), ("a", qty)])).unwrap();
                let q = rng.range(0, 4) as i64;
                client.submit(&op(&app, "rate", &[("t", item), ("q", q)])).unwrap();
                rated += q;
                client.submit(&op(&app, "order", &[("c", cart)])).unwrap();
            }
            rated
        }));
    }
    let total_rated: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    cluster.shutdown();

    // Replicated tables converge (and are exactly the ones the analysis
    // says ride the token: STOCK via global orders, RATING via confluent
    // rates; CARTS has local writers, so it may diverge).
    let tables = replicated_tables(&app);
    assert_eq!(tables, ["STOCK", "RATING"], "schema-order names of token-replicated tables");
    let h0 = replica_hash(cluster.db(0), &tables);
    for s in 1..n {
        assert_eq!(replica_hash(cluster.db(s), &tables), h0, "server {s} replica digest");
    }

    // No oversell + conservation at every server; rating sums match the
    // client-side account at every server.
    for s in 0..n {
        let mut score_sum = 0i64;
        for i in 0..N_ITEMS {
            let r = cluster.db(s).peek("STOCK", &Key::single(Value::Int(i))).unwrap();
            let (level, sold) = (r[1].as_int().unwrap(), r[2].as_int().unwrap());
            assert!(level >= 0, "item {i} oversold at server {s}: level={level}");
            assert_eq!(level + sold, INIT_STOCK, "conservation broken for item {i}");
            let rr = cluster.db(s).peek("RATING", &Key::single(Value::Int(i))).unwrap();
            score_sum += rr[1].as_int().unwrap();
        }
        assert_eq!(score_sum, total_rated, "server {s} rating mass");
    }

    // History oracle: the belt saw every replicated update exactly once.
    let history = cluster.global_history();
    let expected: u64 = (0..n)
        .map(|s| {
            cluster.node(s).ops_global.load(Ordering::Relaxed)
                + cluster.node(s).ops_confluent.load(Ordering::Relaxed)
        })
        .sum();
    assert_eq!(history.len() as u64, expected, "token entries vs executed replicated ops");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.seq, i as u64 + 1, "belt history has a gap or duplicate");
    }
}

/// (4a) Lock conflicts are retryable over the wire and the client stub
/// absorbs them: with server-side wait-die retries disabled, racing
/// writers on one hot row must still all complete, via client retries.
#[test]
fn lock_conflicts_are_retried_by_the_client_stub() {
    let app = store_app();
    let transport: Arc<dyn Transport> = Arc::new(Loopback::new());
    let cfg = ServeConfig { max_retries: 0, ..ServeConfig::loopback(1) };
    let cluster = Arc::new(Cluster::start(Arc::clone(&app), cfg, transport, seed).unwrap());

    // Materialize the hot row first so every racing add takes the pure
    // UPDATE path (write-lock conflicts, not insert races).
    let mut seeder = cluster.client(Arc::clone(&app)).unwrap();
    seeder.submit(&op(&app, "add", &[("c", 1), ("t", 1), ("a", 1)])).unwrap();

    let mut handles = Vec::new();
    for _ in 0..4u64 {
        let cluster = Arc::clone(&cluster);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            let mut client = cluster.client(Arc::clone(&app)).unwrap();
            for _ in 0..150 {
                // Everyone updates the same (cart, item) row.
                client.submit(&op(&app, "add", &[("c", 1), ("t", 1), ("a", 1)])).unwrap();
            }
            client.retries
        }));
    }
    let retries: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    cluster.shutdown();

    assert!(retries > 0, "4 x 150 same-row updates with wait-die disabled must conflict");
    let r = cluster.db(0).peek("CARTS", &Key(vec![Value::Int(1), Value::Int(1)])).unwrap();
    assert_eq!(r[2], Value::Int(601), "every conflicted op must have landed exactly once");
}

/// (4b) Invariant violations are non-retryable: they surface immediately
/// as `NetError::Server { retryable: false }`, with zero client retries.
#[test]
fn invariant_violations_surface_as_non_retryable() {
    let app = store_app();
    let transport: Arc<dyn Transport> = Arc::new(Loopback::new());
    let cluster =
        Cluster::start(Arc::clone(&app), ServeConfig::loopback(2), transport, seed).unwrap();
    let mut client = cluster.client(Arc::clone(&app)).unwrap();

    // A lying non-negative param: SCORE starts at 0, so a negative delta
    // violates RATING's declared non-negativity at execution time.
    match client.submit(&op(&app, "rate", &[("t", 2), ("q", -100)])) {
        Err(NetError::Server(e)) => {
            assert!(!e.retryable, "invariant violations must not be retried: {e}");
        }
        other => panic!("expected a server-side invariant error, got {other:?}"),
    }
    assert_eq!(client.retries, 0, "non-retryable errors must not burn retries");

    // The cluster is still healthy afterwards.
    client.submit(&op(&app, "rate", &[("t", 2), ("q", 5)])).unwrap();
    cluster.shutdown();
    for s in 0..2 {
        let r = cluster.db(s).peek("RATING", &Key::single(Value::Int(2))).unwrap();
        assert_eq!(r[1], Value::Int(5), "server {s}: only the valid delta may survive");
    }
}
