//! Serializability validation of the Conveyor Belt protocol on the
//! real-threads runtime: concurrent clients, real 2PL DBMS instances,
//! real token rotation — then check witness invariants that would be
//! violated by any non-serializable interleaving.
//!
//! The checks mirror the paper's correctness argument (appendix):
//! 1. **Replica convergence** — after quiescing, state written by global
//!    operations is identical at every server (total order of the token).
//! 2. **Conservation under conflicts** — counter invariants survive
//!    arbitrary interleavings of local and global operations.
//! 3. **Read-your-partition** — a local read after a local write at the
//!    same partition observes it (strict 2PL + single-server execution).
//! 4. **No negative stock** — the stock-check/decrement pair of the
//!    Figure-1 store never oversells when orders are globals.

use elia::analysis::OpClass;
use elia::catalog::{Schema, TableSchema, ValueType};
use elia::conveyor::{DeployConfig, Deployment};
use elia::db::{Bindings, Db, Value};
use elia::sqlir::parse_statement;
use elia::util::Rng;
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::spec::{AppSpec, Operation, TxnTemplate};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Figure-1 store with a guarded (stock-checked) order.
fn store_app() -> Arc<AnalyzedApp> {
    let schema = Schema::new(vec![
        TableSchema::new(
            "CARTS",
            &[("CID", ValueType::Int), ("ITEM", ValueType::Int), ("QTY", ValueType::Int)],
            &["CID", "ITEM"],
        ),
        TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int), ("SOLD", ValueType::Int)],
            &["ITEM"],
        ),
    ]);
    let txns = vec![
        TxnTemplate::new(
            "add",
            &["c", "t", "a"],
            &[
                ("upd", "UPDATE CARTS SET QTY = QTY + ?a WHERE CID = ?c AND ITEM = ?t"),
                ("ins", "INSERT INTO CARTS (CID, ITEM, QTY) VALUES (?c, ?t, ?a)"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            let r = ctx.exec("upd", args)?;
            if r.affected == 0 {
                return ctx.exec("ins", args);
            }
            Ok(r)
        }),
        TxnTemplate::new(
            "order",
            &["c"],
            &[
                ("read", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c"),
                ("check", "SELECT LEVEL FROM STOCK WHERE ITEM = ?derived_item"),
                ("dec", "UPDATE STOCK SET LEVEL = LEVEL - ?q, SOLD = SOLD + ?q WHERE ITEM = ?derived_item"),
                ("clear", "DELETE FROM CARTS WHERE CID = ?c"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            let lines = ctx.exec("read", args)?;
            for line in &lines {
                let qty = line[1].as_int().unwrap_or(0);
                let mut b = args.clone();
                b.insert("derived_item".into(), line[0].clone());
                b.insert("q".into(), Value::Int(qty));
                // Guard: only sell what is in stock (the serializable
                // check-then-act the paper's example relies on).
                let level = ctx
                    .exec("check", &b)?
                    .scalar()
                    .and_then(|v| v.as_int())
                    .unwrap_or(0);
                if level >= qty {
                    ctx.exec("dec", &b)?;
                }
            }
            ctx.exec("clear", args)
        }),
        TxnTemplate::new(
            "readCart",
            &["c"],
            &[("q", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
    ];
    let app = AnalyzedApp::analyze(AppSpec { name: "store".into(), schema, txns });
    assert_eq!(*app.class(0), OpClass::Local);
    assert_eq!(*app.class(1), OpClass::Global);
    assert_eq!(*app.class(2), OpClass::Local);
    Arc::new(app)
}

const N_ITEMS: i64 = 6;
const INIT_STOCK: i64 = 50;

fn seed(db: &Db) {
    let ins =
        parse_statement("INSERT INTO STOCK (ITEM, LEVEL, SOLD) VALUES (?i, ?l, 0)").unwrap();
    for i in 0..N_ITEMS {
        let b: Bindings =
            [("i".to_string(), Value::Int(i)), ("l".to_string(), Value::Int(INIT_STOCK))]
                .into_iter()
                .collect();
        db.exec_auto(&ins, &b).unwrap();
    }
}

fn op(app: &AnalyzedApp, name: &str, pairs: &[(&str, i64)]) -> Operation {
    Operation {
        txn: app.spec.txn_index(name).unwrap(),
        args: pairs.iter().map(|(k, v)| (k.to_string(), Value::Int(*v))).collect(),
    }
}

#[test]
fn stock_never_oversold_and_replicas_converge() {
    let app = store_app();
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers: 4, ..Default::default() },
        seed,
    );

    // Many clients race add+order cycles against a small shared stock.
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let dep = Arc::clone(&dep);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t + 1);
            for i in 0..40 {
                let cart = (t * 1000 + i) as i64;
                let item = rng.range(0, N_ITEMS as usize) as i64;
                let qty = 1 + rng.range(0, 3) as i64;
                dep.submit(op(&app, "add", &[("c", cart), ("t", item), ("a", qty)])).unwrap();
                dep.submit(op(&app, "order", &[("c", cart)])).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    dep.shutdown();

    // (1) STOCK identical at every server.
    let stock0: Vec<Value> = (0..N_ITEMS)
        .map(|i| {
            dep.db(0)
                .peek("STOCK", &elia::db::Key::single(Value::Int(i)))
                .map(|r| r[1].clone())
                .unwrap()
        })
        .collect();
    for s in 1..dep.n_servers() {
        for i in 0..N_ITEMS {
            let r = dep.db(s).peek("STOCK", &elia::db::Key::single(Value::Int(i))).unwrap();
            assert_eq!(r[1], stock0[i as usize], "server {s} item {i} diverged");
        }
    }

    // (2) Conservation + no overselling at every server.
    for s in 0..dep.n_servers() {
        for i in 0..N_ITEMS {
            let r = dep.db(s).peek("STOCK", &elia::db::Key::single(Value::Int(i))).unwrap();
            let level = r[1].as_int().unwrap();
            let sold = r[2].as_int().unwrap();
            assert!(level >= 0, "item {i} oversold at server {s}: level={level}");
            assert_eq!(level + sold, INIT_STOCK, "conservation broken for item {i}");
        }
    }
}

#[test]
fn local_reads_observe_local_writes() {
    let app = store_app();
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers: 3, ..Default::default() },
        seed,
    );
    for cart in 0..50i64 {
        dep.submit(op(&app, "add", &[("c", cart), ("t", 1), ("a", 2)])).unwrap();
        let r = dep.submit(op(&app, "readCart", &[("c", cart)])).unwrap();
        assert_eq!(r.len(), 1, "cart {cart} must see its own add");
        assert_eq!(r.row(0)[1], Value::Int(2));
    }
    dep.shutdown();
}

#[test]
fn global_total_order_is_observed_by_all_servers() {
    // Orders from many threads: the SOLD counters at all servers must
    // agree exactly (token total order), and equal the number of sold
    // units (stock is ample, so nothing is rejected).
    let app = store_app();
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers: 3, ..Default::default() },
        seed,
    );
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let dep = Arc::clone(&dep);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            for i in 0..30 {
                let cart = (t * 500 + i) as i64;
                dep.submit(op(&app, "add", &[("c", cart), ("t", (i % 6) as i64), ("a", 1)]))
                    .unwrap();
                dep.submit(op(&app, "order", &[("c", cart)])).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(dep.ops_global.load(Ordering::Relaxed), 180);
    dep.shutdown();

    let q = parse_statement("SELECT SUM(SOLD) FROM STOCK").unwrap();
    let sold0 =
        dep.db(0).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
    for s in 1..3 {
        let sold =
            dep.db(s).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        assert_eq!(sold, sold0, "server {s}");
    }
    // 6 items x 50 stock = 300 units >= 180 orders of one unit each.
    assert_eq!(sold0, 180);
}
