//! Prepared-execution equivalence: prepare-once/execute-many must behave
//! exactly like the name-keyed convenience path (which itself compiles
//! per call) across point, index-equality and scan predicates, including
//! NULL and type-coercion binds — and both must produce the semantics
//! the interpreted engine had before the prepared pipeline landed
//! (golden results asserted literally below).

use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{BindSlots, Bindings, Db, Key, Value};
use elia::sqlir::parse_statement;

fn test_db() -> Db {
    Db::new(Schema::new(vec![TableSchema::new(
        "ITEMS",
        &[
            ("ID", ValueType::Int),
            ("TITLE", ValueType::Str),
            ("STOCK", ValueType::Int),
            ("COST", ValueType::Float),
        ],
        &["ID"],
    )
    .with_index("TITLE")]))
}

fn seed(db: &Db, n: i64) {
    let ins = db
        .prepare_sql("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)")
        .unwrap();
    for i in 0..n {
        db.exec_auto_prepared(
            &ins,
            &ins.bind_pairs(&[
                ("id", Value::Int(i)),
                ("t", Value::Str(format!("book{}", i % 4))),
                ("s", Value::Int(10 * i)),
                ("c", Value::Float(1.5 * i as f64)),
            ])
            .unwrap(),
        )
        .unwrap();
    }
}

fn named(pairs: &[(&str, Value)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

/// Run the same SQL through the prepared path and the name-keyed compat
/// path against identically-seeded databases; results must agree.
fn both_paths(sql: &str, pairs: &[(&str, Value)], rows: i64) -> elia::db::QueryResult {
    let db_a = test_db();
    let db_b = test_db();
    seed(&db_a, rows);
    seed(&db_b, rows);

    let prepared = db_a.prepare_sql(sql).unwrap();
    let slots = prepared.bind_pairs(pairs).unwrap();
    let via_prepared = db_a.exec_auto_prepared(&prepared, &slots).unwrap();

    let stmt = parse_statement(sql).unwrap();
    let via_named = db_b.exec_auto(&stmt, &named(pairs)).unwrap();

    assert_eq!(via_prepared, via_named, "paths diverged for {sql}");
    assert_eq!(db_a.content_hash(), db_b.content_hash(), "state diverged for {sql}");
    via_prepared
}

#[test]
fn point_select_equivalence() {
    let r = both_paths(
        "SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Int(2))],
        6,
    );
    assert_eq!(r.rows, vec![vec![Value::Str("book2".into()), Value::Int(20)]]);
}

#[test]
fn point_select_with_float_coercion_bind() {
    // A Float bind on an Int PK column must coerce and still hit the
    // point path (value-level coercion happens per execution).
    let r = both_paths(
        "SELECT STOCK FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Float(3.0))],
        6,
    );
    assert_eq!(r.rows, vec![vec![Value::Int(30)]]);
}

#[test]
fn index_eq_select_equivalence() {
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Str("book1".into()))],
        8,
    );
    // ids 1 and 5 carry title book1; output is deterministically sorted.
    assert_eq!(r.rows, vec![vec![Value::Int(1)], vec![Value::Int(5)]]);
}

#[test]
fn scan_select_equivalence() {
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE STOCK >= ?s ORDER BY COST DESC LIMIT 3",
        &[("s", Value::Int(20))],
        8,
    );
    assert_eq!(
        r.rows,
        vec![vec![Value::Int(7)], vec![Value::Int(6)], vec![Value::Int(5)]]
    );
}

#[test]
fn null_bind_matches_nothing() {
    // SQL comparison semantics: NULL never compares equal, on every path.
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Null)],
        4,
    );
    assert!(r.rows.is_empty());
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Null)],
        4,
    );
    assert!(r.rows.is_empty());
    let r = both_paths(
        "SELECT COUNT(*) FROM ITEMS WHERE STOCK > ?s",
        &[("s", Value::Null)],
        4,
    );
    assert_eq!(r.scalar(), Some(&Value::Int(0)));
}

#[test]
fn point_update_delta_equivalence() {
    let r = both_paths(
        "UPDATE ITEMS SET STOCK = STOCK - ?q WHERE ID = ?id",
        &[("q", Value::Int(7)), ("id", Value::Int(1))],
        4,
    );
    assert_eq!(r.affected, 1);
}

#[test]
fn scan_update_and_delete_equivalence() {
    let r = both_paths(
        "UPDATE ITEMS SET COST = COST * ?f WHERE STOCK >= ?s",
        &[("f", Value::Float(2.0)), ("s", Value::Int(20))],
        6,
    );
    assert_eq!(r.affected, 4);
    let r = both_paths("DELETE FROM ITEMS WHERE ID >= ?id", &[("id", Value::Int(3))], 6);
    assert_eq!(r.affected, 3);
}

#[test]
fn aggregate_equivalence() {
    let r = both_paths(
        "SELECT COUNT(*), MAX(STOCK), MIN(COST), SUM(STOCK) FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Str("book0".into()))],
        8,
    );
    assert_eq!(
        r.rows,
        vec![vec![
            Value::Int(2),
            Value::Int(40),
            Value::Float(0.0),
            Value::Int(40),
        ]]
    );
}

#[test]
fn prepare_once_execute_many_matches_per_call_compile() {
    let db = test_db();
    seed(&db, 16);
    let prepared = db.prepare_sql("SELECT STOCK FROM ITEMS WHERE ID = ?id").unwrap();
    let stmt = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = ?id").unwrap();
    for i in (0..16).rev() {
        let a = db
            .exec_auto_prepared(&prepared, &BindSlots(vec![Value::Int(i)]))
            .unwrap();
        let b = db.exec_auto(&stmt, &named(&[("id", Value::Int(i))])).unwrap();
        assert_eq!(a, b, "id {i}");
        assert_eq!(a.scalar(), Some(&Value::Int(10 * i)));
    }
}

#[test]
fn prepared_statements_shared_across_replicas() {
    // One compiled statement drives many identically-schema'd DBs (what
    // the conveyor simulator does with per-server instances).
    let dbs: Vec<Db> = (0..3).map(|_| test_db()).collect();
    for db in &dbs {
        seed(db, 4);
    }
    let upd = dbs[0].prepare_sql("UPDATE ITEMS SET STOCK = STOCK + ?d WHERE ID = ?id").unwrap();
    for db in &dbs {
        db.exec_auto_prepared(
            &upd,
            &upd.bind_pairs(&[("d", Value::Int(5)), ("id", Value::Int(2))]).unwrap(),
        )
        .unwrap();
    }
    let h0 = dbs[0].content_hash();
    for db in &dbs[1..] {
        assert_eq!(db.content_hash(), h0);
    }
}

#[test]
fn state_updates_replicate_identically_across_paths() {
    // The WriteRecord stream (logical redo) must be byte-identical
    // between paths so replication is unaffected by how the statement
    // was executed.
    let db_a = test_db();
    let db_b = test_db();
    seed(&db_a, 3);
    seed(&db_b, 3);
    let sql = "UPDATE ITEMS SET STOCK = STOCK - ?q, COST = ?c WHERE ID = ?id";
    let pairs =
        [("q", Value::Int(4)), ("c", Value::Float(9.0)), ("id", Value::Int(1))];

    let p = db_a.prepare_sql(sql).unwrap();
    let mut txn = db_a.begin();
    txn.exec_prepared(&p, &p.bind_pairs(&pairs).unwrap()).unwrap();
    let ua = txn.commit().unwrap();

    let stmt = parse_statement(sql).unwrap();
    let mut txn = db_b.begin();
    txn.exec(&stmt, &named(&pairs)).unwrap();
    let ub = txn.commit().unwrap();

    assert_eq!(ua, ub);

    // And applying either update to a third replica converges it.
    let db_c = test_db();
    seed(&db_c, 3);
    db_c.apply_update(&ua).unwrap();
    assert_eq!(db_c.content_hash(), db_a.content_hash());
}

#[test]
fn peek_sees_prepared_writes() {
    let db = test_db();
    seed(&db, 2);
    let upd = db.prepare_sql("UPDATE ITEMS SET TITLE = ?t WHERE ID = ?id").unwrap();
    db.exec_auto_prepared(
        &upd,
        &upd.bind_pairs(&[("t", Value::Str("zzz".into())), ("id", Value::Int(0))]).unwrap(),
    )
    .unwrap();
    let row = db.peek("ITEMS", &Key::single(Value::Int(0))).unwrap();
    assert_eq!(row[1], Value::Str("zzz".into()));
}
