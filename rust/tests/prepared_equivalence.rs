//! Prepared-execution equivalence: prepare-once/execute-many must behave
//! exactly like the name-keyed convenience path (which itself compiles
//! per call) across point, index-equality and scan predicates, including
//! NULL and type-coercion binds — and both must produce the semantics
//! the interpreted engine had before the prepared pipeline landed
//! (golden results asserted literally below).
//!
//! This file also pins the borrowed result path (PR 4):
//! * the lazy [`ResultSet`](elia::db::ResultSet) accessors must agree
//!   with the `to_owned()` materialization on every path,
//! * a held `ResultSet` must keep reading the snapshot it matched, across
//!   the transaction's own later writes (overlay/COW interaction — the
//!   IndexEq-overlay class of bug PR 1 fixed) and across commit,
//! * the read path must perform **zero `Value` clones per row returned**
//!   (asserted with the debug-build clone counter, not eyeballed).

use elia::db::{value_clone_count, BindSlots, Bindings, Db, Key, ResultSet, Value};
use elia::sqlir::parse_statement;
use elia::catalog::{Schema, TableSchema, ValueType};

fn test_db() -> Db {
    Db::new(Schema::new(vec![TableSchema::new(
        "ITEMS",
        &[
            ("ID", ValueType::Int),
            ("TITLE", ValueType::Str),
            ("STOCK", ValueType::Int),
            ("COST", ValueType::Float),
        ],
        &["ID"],
    )
    .with_index("TITLE")]))
}

fn seed(db: &Db, n: i64) {
    let ins = db
        .prepare_sql("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)")
        .unwrap();
    for i in 0..n {
        db.exec_auto_prepared(
            &ins,
            &ins.bind_pairs(&[
                ("id", Value::Int(i)),
                ("t", Value::Str(format!("book{}", i % 4))),
                ("s", Value::Int(10 * i)),
                ("c", Value::Float(1.5 * i as f64)),
            ])
            .unwrap(),
        )
        .unwrap();
    }
}

fn named(pairs: &[(&str, Value)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

/// Run the same SQL through the prepared path and the name-keyed compat
/// path against identically-seeded databases; results must agree.
fn both_paths(sql: &str, pairs: &[(&str, Value)], rows: i64) -> ResultSet {
    let db_a = test_db();
    let db_b = test_db();
    seed(&db_a, rows);
    seed(&db_b, rows);

    let prepared = db_a.prepare_sql(sql).unwrap();
    let slots = prepared.bind_pairs(pairs).unwrap();
    let via_prepared = db_a.exec_auto_prepared(&prepared, &slots).unwrap();

    let stmt = parse_statement(sql).unwrap();
    let via_named = db_b.exec_auto(&stmt, &named(pairs)).unwrap();

    assert_eq!(via_prepared, via_named, "paths diverged for {sql}");
    assert_eq!(db_a.content_hash(), db_b.content_hash(), "state diverged for {sql}");
    via_prepared
}

#[test]
fn point_select_equivalence() {
    let r = both_paths(
        "SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Int(2))],
        6,
    );
    assert_eq!(r.to_owned(), vec![vec![Value::Str("book2".into()), Value::Int(20)]]);
}

#[test]
fn point_select_with_float_coercion_bind() {
    // A Float bind on an Int PK column must coerce and still hit the
    // point path (value-level coercion happens per execution).
    let r = both_paths(
        "SELECT STOCK FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Float(3.0))],
        6,
    );
    assert_eq!(r.to_owned(), vec![vec![Value::Int(30)]]);
}

#[test]
fn index_eq_select_equivalence() {
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Str("book1".into()))],
        8,
    );
    // ids 1 and 5 carry title book1; output is deterministically sorted.
    assert_eq!(r.to_owned(), vec![vec![Value::Int(1)], vec![Value::Int(5)]]);
}

#[test]
fn scan_select_equivalence() {
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE STOCK >= ?s ORDER BY COST DESC LIMIT 3",
        &[("s", Value::Int(20))],
        8,
    );
    assert_eq!(
        r.to_owned(),
        vec![vec![Value::Int(7)], vec![Value::Int(6)], vec![Value::Int(5)]]
    );
}

#[test]
fn null_bind_matches_nothing() {
    // SQL comparison semantics: NULL never compares equal, on every path.
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE ID = ?id",
        &[("id", Value::Null)],
        4,
    );
    assert!(r.is_empty());
    let r = both_paths(
        "SELECT ID FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Null)],
        4,
    );
    assert!(r.is_empty());
    let r = both_paths(
        "SELECT COUNT(*) FROM ITEMS WHERE STOCK > ?s",
        &[("s", Value::Null)],
        4,
    );
    assert_eq!(r.scalar(), Some(&Value::Int(0)));
}

#[test]
fn point_update_delta_equivalence() {
    let r = both_paths(
        "UPDATE ITEMS SET STOCK = STOCK - ?q WHERE ID = ?id",
        &[("q", Value::Int(7)), ("id", Value::Int(1))],
        4,
    );
    assert_eq!(r.affected, 1);
}

#[test]
fn scan_update_and_delete_equivalence() {
    let r = both_paths(
        "UPDATE ITEMS SET COST = COST * ?f WHERE STOCK >= ?s",
        &[("f", Value::Float(2.0)), ("s", Value::Int(20))],
        6,
    );
    assert_eq!(r.affected, 4);
    let r = both_paths("DELETE FROM ITEMS WHERE ID >= ?id", &[("id", Value::Int(3))], 6);
    assert_eq!(r.affected, 3);
}

#[test]
fn aggregate_equivalence() {
    let r = both_paths(
        "SELECT COUNT(*), MAX(STOCK), MIN(COST), SUM(STOCK) FROM ITEMS WHERE TITLE = ?t",
        &[("t", Value::Str("book0".into()))],
        8,
    );
    assert_eq!(
        r.to_owned(),
        vec![vec![
            Value::Int(2),
            Value::Int(40),
            Value::Float(0.0),
            Value::Int(40),
        ]]
    );
}

#[test]
fn prepare_once_execute_many_matches_per_call_compile() {
    let db = test_db();
    seed(&db, 16);
    let prepared = db.prepare_sql("SELECT STOCK FROM ITEMS WHERE ID = ?id").unwrap();
    let stmt = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = ?id").unwrap();
    for i in (0..16).rev() {
        let a = db
            .exec_auto_prepared(&prepared, &BindSlots(vec![Value::Int(i)]))
            .unwrap();
        let b = db.exec_auto(&stmt, &named(&[("id", Value::Int(i))])).unwrap();
        assert_eq!(a, b, "id {i}");
        assert_eq!(a.scalar(), Some(&Value::Int(10 * i)));
    }
}

#[test]
fn prepared_statements_shared_across_replicas() {
    // One compiled statement drives many identically-schema'd DBs (what
    // the conveyor simulator does with per-server instances).
    let dbs: Vec<Db> = (0..3).map(|_| test_db()).collect();
    for db in &dbs {
        seed(db, 4);
    }
    let upd = dbs[0].prepare_sql("UPDATE ITEMS SET STOCK = STOCK + ?d WHERE ID = ?id").unwrap();
    for db in &dbs {
        db.exec_auto_prepared(
            &upd,
            &upd.bind_pairs(&[("d", Value::Int(5)), ("id", Value::Int(2))]).unwrap(),
        )
        .unwrap();
    }
    let h0 = dbs[0].content_hash();
    for db in &dbs[1..] {
        assert_eq!(db.content_hash(), h0);
    }
}

#[test]
fn state_updates_replicate_identically_across_paths() {
    // The WriteRecord stream (logical redo) must be byte-identical
    // between paths so replication is unaffected by how the statement
    // was executed.
    let db_a = test_db();
    let db_b = test_db();
    seed(&db_a, 3);
    seed(&db_b, 3);
    let sql = "UPDATE ITEMS SET STOCK = STOCK - ?q, COST = ?c WHERE ID = ?id";
    let pairs =
        [("q", Value::Int(4)), ("c", Value::Float(9.0)), ("id", Value::Int(1))];

    let p = db_a.prepare_sql(sql).unwrap();
    let mut txn = db_a.begin();
    txn.exec_prepared(&p, &p.bind_pairs(&pairs).unwrap()).unwrap();
    let ua = txn.commit().unwrap();

    let stmt = parse_statement(sql).unwrap();
    let mut txn = db_b.begin();
    txn.exec(&stmt, &named(&pairs)).unwrap();
    let ub = txn.commit().unwrap();

    assert_eq!(ua, ub);

    // And applying either update to a third replica converges it.
    let db_c = test_db();
    seed(&db_c, 3);
    db_c.apply_update(&ua).unwrap();
    assert_eq!(db_c.content_hash(), db_a.content_hash());
}

#[test]
fn peek_sees_prepared_writes() {
    let db = test_db();
    seed(&db, 2);
    let upd = db.prepare_sql("UPDATE ITEMS SET TITLE = ?t WHERE ID = ?id").unwrap();
    db.exec_auto_prepared(
        &upd,
        &upd.bind_pairs(&[("t", Value::Str("zzz".into())), ("id", Value::Int(0))]).unwrap(),
    )
    .unwrap();
    let row = db.peek("ITEMS", &Key::single(Value::Int(0))).unwrap();
    assert_eq!(row[1], Value::Str("zzz".into()));
}

// ---------------------------------------------------------------------------
// Borrowed result materialization (PR 4): lazy accessors vs to_owned().
// ---------------------------------------------------------------------------

/// Every lazy read of the borrowed result must agree with the owned
/// materialization — per value, per row, and in the convenience views.
fn borrowed_agrees_with_owned(sql: &str, pairs: &[(&str, Value)], rows: i64) {
    let db = test_db();
    seed(&db, rows);
    let p = db.prepare_sql(sql).unwrap();
    let r = db.exec_auto_prepared(&p, &p.bind_pairs(pairs).unwrap()).unwrap();
    let owned = r.to_owned();

    assert_eq!(owned.len(), r.len(), "{sql}: len");
    assert_eq!(owned.is_empty(), r.is_empty(), "{sql}: is_empty");
    for (i, row) in r.iter().enumerate() {
        assert_eq!(row.len(), owned[i].len(), "{sql}: width of row {i}");
        for j in 0..row.len() {
            assert_eq!(row[j], owned[i][j], "{sql}: value [{i}][{j}]");
            assert_eq!(row.get(j), Some(&owned[i][j]), "{sql}: get [{i}][{j}]");
        }
        assert!(row.get(row.len()).is_none(), "{sql}: get past width");
        assert_eq!(row.to_vec(), owned[i], "{sql}: to_vec of row {i}");
        assert_eq!(r.row(i).to_vec(), owned[i], "{sql}: row({i})");
    }
    assert!(r.get(r.len()).is_none(), "{sql}: get past len");
    assert_eq!(r.first().map(|row| row.to_vec()), owned.first().cloned(), "{sql}: first");
    assert_eq!(r.scalar(), owned.first().and_then(|row| row.first()), "{sql}: scalar");
}

#[test]
fn borrowed_and_owned_agree_on_every_access_path() {
    let cases: &[(&str, &[(&str, Value)])] = &[
        // Point, exact and missing, plus a coercion bind.
        ("SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id", &[("id", Value::Int(2))]),
        ("SELECT TITLE FROM ITEMS WHERE ID = ?id", &[("id", Value::Int(999))]),
        ("SELECT STOCK FROM ITEMS WHERE ID = ?id", &[("id", Value::Float(3.0))]),
        ("SELECT STOCK FROM ITEMS WHERE ID = ?id", &[("id", Value::Null)]),
        // Index equality.
        ("SELECT ID, COST FROM ITEMS WHERE TITLE = ?t", &[("t", Value::Str("book1".into()))]),
        ("SELECT ID FROM ITEMS WHERE TITLE = ?t", &[("t", Value::Null)]),
        // Scans, SELECT *, ORDER BY + LIMIT, reordered projection.
        ("SELECT ID FROM ITEMS WHERE STOCK >= ?s", &[("s", Value::Int(20))]),
        ("SELECT * FROM ITEMS WHERE STOCK >= ?s", &[("s", Value::Int(30))]),
        ("SELECT COST, ID FROM ITEMS ORDER BY COST DESC LIMIT 3", &[]),
        // Aggregates (the computed-row shape).
        ("SELECT COUNT(*), SUM(STOCK) FROM ITEMS WHERE TITLE = ?t", &[("t", Value::Str("book0".into()))]),
    ];
    for (sql, pairs) in cases {
        borrowed_agrees_with_owned(sql, pairs, 8);
    }
}

// ---------------------------------------------------------------------------
// Snapshot stability: a held ResultSet across later writes (overlay/COW).
// ---------------------------------------------------------------------------

#[test]
fn result_set_snapshot_survives_subsequent_txn_writes() {
    use elia::util::qcheck::{check, Config};
    check(
        Config::default().cases(40).name("resultset-snapshot"),
        |rng| {
            let db = test_db();
            let n = 4 + rng.range(0, 10) as i64;
            seed(&db, n);

            // Pick one of the three read paths.
            let (sql, pairs): (&str, Vec<(&str, Value)>) = match rng.range(0, 3) {
                0 => (
                    "SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id",
                    vec![("id", Value::Int(rng.range(0, n as usize) as i64))],
                ),
                1 => (
                    "SELECT ID, STOCK FROM ITEMS WHERE TITLE = ?t",
                    vec![("t", Value::Str(format!("book{}", rng.range(0, 4))))],
                ),
                _ => ("SELECT ID, TITLE, STOCK FROM ITEMS WHERE STOCK >= ?s",
                    vec![("s", Value::Int(rng.range(0, 40) as i64))]),
            };
            let sel = db.prepare_sql(sql).unwrap();
            let slots = sel.bind_pairs(&pairs).unwrap();

            let upd_stock = db
                .prepare_sql("UPDATE ITEMS SET STOCK = STOCK + ?d WHERE ID = ?id")
                .unwrap();
            // Updating the *indexed* column exercises the IndexEq-overlay
            // interaction (rows leave/enter the probed bucket in-txn).
            let upd_title =
                db.prepare_sql("UPDATE ITEMS SET TITLE = ?t WHERE ID = ?id").unwrap();
            let del = db.prepare_sql("DELETE FROM ITEMS WHERE ID = ?id").unwrap();
            let ins = db
                .prepare_sql(
                    "INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, 0, 0.0)",
                )
                .unwrap();

            let mut txn = db.begin();
            let held = txn.exec_prepared(&sel, &slots).unwrap();
            let snapshot = held.to_owned();

            // Hammer the same table (often the same rows) inside the txn.
            for w in 0..rng.range(1, 6) {
                let id = Value::Int(rng.range(0, n as usize) as i64);
                match rng.range(0, 4) {
                    0 => {
                        txn.exec_prepared(
                            &upd_stock,
                            &upd_stock
                                .bind_pairs(&[("d", Value::Int(100)), ("id", id)])
                                .unwrap(),
                        )
                        .unwrap();
                    }
                    1 => {
                        txn.exec_prepared(
                            &upd_title,
                            &upd_title
                                .bind_pairs(&[
                                    ("t", Value::Str(format!("renamed{w}"))),
                                    ("id", id),
                                ])
                                .unwrap(),
                        )
                        .unwrap();
                    }
                    2 => {
                        txn.exec_prepared(&del, &del.bind_pairs(&[("id", id)]).unwrap())
                            .unwrap();
                    }
                    _ => {
                        // Fresh id: may collide with an earlier insert of
                        // this loop — ignore the duplicate-key error.
                        let fresh = Value::Int(n + rng.range(0, 8) as i64);
                        let _ = txn.exec_prepared(
                            &ins,
                            &ins.bind_pairs(&[
                                ("id", fresh),
                                ("t", Value::Str("fresh".into())),
                            ])
                            .unwrap(),
                        );
                    }
                }
                // The held result still reads the values it matched.
                assert_eq!(held.to_owned(), snapshot, "snapshot drifted mid-txn");
            }

            // ... and commit does not disturb it either (storage swaps
            // in new Arcs; held handles keep the old images).
            txn.commit().unwrap();
            assert_eq!(held.to_owned(), snapshot, "snapshot drifted across commit");
        },
    );
}

// ---------------------------------------------------------------------------
// Zero value clones per row returned (debug-build clone counter).
// ---------------------------------------------------------------------------

/// Clones performed while running `f` on this thread. `None` in release
/// builds (counter compiled out) — the callers skip their assertions.
fn clones_during(f: impl FnOnce()) -> Option<u64> {
    let before = value_clone_count()?;
    f();
    Some(value_clone_count().unwrap() - before)
}

#[test]
fn scan_read_clones_no_values_at_all() {
    if value_clone_count().is_none() {
        return; // release build: counter compiled out
    }
    let db = test_db();
    seed(&db, 32);
    let sel = db.prepare_sql("SELECT TITLE, STOCK FROM ITEMS WHERE STOCK >= ?s").unwrap();
    let slots = sel.bind_pairs(&[("s", Value::Int(0))]).unwrap();

    let mut r = None;
    let during_exec = clones_during(|| r = Some(db.exec_auto_prepared(&sel, &slots).unwrap()));
    let r = r.unwrap();
    assert_eq!(r.len(), 32, "all rows matched");
    assert_eq!(
        during_exec,
        Some(0),
        "a scan read must clone zero Values no matter how many rows match"
    );

    // Reading every projected value through the accessors clones nothing.
    let mut values_seen = 0;
    let during_read = clones_during(|| {
        for row in &r {
            for v in row.iter() {
                values_seen += std::hint::black_box(v).type_name().len().min(1);
            }
        }
    });
    assert_eq!(values_seen, 64);
    assert_eq!(during_read, Some(0), "accessor reads must clone zero Values");

    // The explicit escape hatch is where clones happen: one per value.
    let during_owned = clones_during(|| {
        std::hint::black_box(r.to_owned());
    });
    assert_eq!(during_owned, Some(64), "to_owned clones exactly rows x width");
}

#[test]
fn point_and_index_reads_clone_only_the_probe_key() {
    if value_clone_count().is_none() {
        return; // release build: counter compiled out
    }
    let db = test_db();
    seed(&db, 16);

    // Point: the only clone is the bind value copied into the lookup key
    // (one per PK column, per execution — independent of rows returned).
    let sel = db.prepare_sql("SELECT TITLE, STOCK, COST FROM ITEMS WHERE ID = ?id").unwrap();
    let slots = sel.bind_pairs(&[("id", Value::Int(7))]).unwrap();
    let mut r = None;
    let d = clones_during(|| r = Some(db.exec_auto_prepared(&sel, &slots).unwrap()));
    assert_eq!(r.as_ref().unwrap().len(), 1);
    assert_eq!(d, Some(1), "point read: exactly the key-build clone");
    let d = clones_during(|| {
        assert_eq!(r.as_ref().unwrap().row(0)[1], Value::Int(70));
    });
    assert_eq!(d, Some(0), "value access is clone-free");

    // Index-eq: the one clone is the probe value; matched rows add none.
    let sel = db.prepare_sql("SELECT ID, STOCK FROM ITEMS WHERE TITLE = ?t").unwrap();
    let slots = sel.bind_pairs(&[("t", Value::Str("book1".into()))]).unwrap();
    let mut r = None;
    let d = clones_during(|| r = Some(db.exec_auto_prepared(&sel, &slots).unwrap()));
    assert_eq!(r.as_ref().unwrap().len(), 4, "ids 1, 5, 9, 13");
    assert_eq!(d, Some(1), "index-eq read: exactly the probe clone");
}
