//! Shared fixture for the wire-level (`net_*`) integration tests: the
//! Figure-1 store app extended with a confluent `rate` template, its
//! seed data, and an operation builder. Not itself a test crate —
//! `cargo` only builds files directly under `tests/`.
#![allow(dead_code)] // each test crate uses a subset of the fixture

use elia::analysis::OpClass;
use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{Bindings, Db, Value};
use elia::sqlir::parse_statement;
use elia::workload::analyzed::AnalyzedApp;
use elia::workload::spec::{AppSpec, Operation, TxnTemplate};
use std::sync::Arc;

pub const N_ITEMS: i64 = 6;
pub const INIT_STOCK: i64 = 50;

/// The Figure-1 store (guarded global order, local cart ops) plus a
/// confluent `rate` template: a non-negative score delta whose target
/// row is only known inside the body, so conflict analysis makes it
/// global and the invariant-confluence pass promotes it to
/// coordination-free.
pub fn store_app() -> Arc<AnalyzedApp> {
    let schema = Schema::new(vec![
        TableSchema::new(
            "CARTS",
            &[("CID", ValueType::Int), ("ITEM", ValueType::Int), ("QTY", ValueType::Int)],
            &["CID", "ITEM"],
        ),
        TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int), ("SOLD", ValueType::Int)],
            &["ITEM"],
        ),
        TableSchema::new(
            "RATING",
            &[("ITEM", ValueType::Int), ("SCORE", ValueType::Int)],
            &["ITEM"],
        )
        .with_nonnegative("SCORE"),
    ]);
    let txns = vec![
        TxnTemplate::new(
            "add",
            &["c", "t", "a"],
            &[
                ("upd", "UPDATE CARTS SET QTY = QTY + ?a WHERE CID = ?c AND ITEM = ?t"),
                ("ins", "INSERT INTO CARTS (CID, ITEM, QTY) VALUES (?c, ?t, ?a)"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            let r = ctx.exec("upd", args)?;
            if r.affected == 0 {
                return ctx.exec("ins", args);
            }
            Ok(r)
        }),
        TxnTemplate::new(
            "order",
            &["c"],
            &[
                ("read", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c"),
                ("check", "SELECT LEVEL FROM STOCK WHERE ITEM = ?derived_item"),
                ("dec", "UPDATE STOCK SET LEVEL = LEVEL - ?q, SOLD = SOLD + ?q WHERE ITEM = ?derived_item"),
                ("clear", "DELETE FROM CARTS WHERE CID = ?c"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            let lines = ctx.exec("read", args)?;
            for line in &lines {
                let qty = line[1].as_int().unwrap_or(0);
                let mut b = args.clone();
                b.insert("derived_item".into(), line[0].clone());
                b.insert("q".into(), Value::Int(qty));
                // Guard: only sell what is in stock (the serializable
                // check-then-act the paper's example relies on).
                let level = ctx
                    .exec("check", &b)?
                    .scalar()
                    .and_then(|v| v.as_int())
                    .unwrap_or(0);
                if level >= qty {
                    ctx.exec("dec", &b)?;
                }
            }
            ctx.exec("clear", args)
        }),
        TxnTemplate::new(
            "readCart",
            &["c"],
            &[("q", "SELECT ITEM, QTY FROM CARTS WHERE CID = ?c")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "rate",
            &["t", "q"],
            &[("u", "UPDATE RATING SET SCORE = SCORE + ?q WHERE ITEM = ?derived_t")],
            1.0,
        )
        .with_nonneg_param("q")
        .with_body(|ctx, args| {
            let mut b = args.clone();
            b.insert("derived_t".into(), args["t"].clone());
            ctx.exec("u", &b)
        }),
    ];
    let app = AnalyzedApp::analyze_confluent(AppSpec { name: "store".into(), schema, txns });
    assert_eq!(*app.class(0), OpClass::Local);
    assert_eq!(*app.class(1), OpClass::Global);
    assert_eq!(*app.class(2), OpClass::Local);
    assert_eq!(*app.class(3), OpClass::Confluent);
    Arc::new(app)
}

/// Seed `N_ITEMS` stock rows (level `INIT_STOCK`) and zeroed ratings.
pub fn seed(db: &Db) {
    let stock =
        parse_statement("INSERT INTO STOCK (ITEM, LEVEL, SOLD) VALUES (?i, ?l, 0)").unwrap();
    let rating = parse_statement("INSERT INTO RATING (ITEM, SCORE) VALUES (?i, 0)").unwrap();
    for i in 0..N_ITEMS {
        let b: Bindings =
            [("i".to_string(), Value::Int(i)), ("l".to_string(), Value::Int(INIT_STOCK))]
                .into_iter()
                .collect();
        db.exec_auto(&stock, &b).unwrap();
        db.exec_auto(&rating, &b).unwrap();
    }
}

/// Build a concrete operation with integer-bound params.
pub fn op(app: &AnalyzedApp, name: &str, pairs: &[(&str, i64)]) -> Operation {
    Operation {
        txn: app.spec.txn_index(name).unwrap(),
        args: pairs.iter().map(|(k, v)| (k.to_string(), Value::Int(*v))).collect(),
    }
}
