//! Soundness of the confluent (coordination-free) commit path.
//!
//! The invariant-confluence pass only admits operation shapes whose
//! captured state updates merge: non-negative counter deltas guarded by
//! a declared `NonNegative` invariant, and inserts pinned on a declared
//! `Unique` key. This suite checks the runtime contract those shapes
//! rely on, end to end over the real storage engine:
//!
//! * executing confluent ops at their origin replicas and replaying the
//!   captured [`StateUpdate`]s at every other replica in ANY
//!   cross-origin interleaving (per-origin order preserved, as the
//!   token guarantees) converges every replica to the same
//!   `content_hash` as a serial token-order reference;
//! * no declared invariant is ever violated, at the origin or at any
//!   replica, under any interleaving;
//! * an op that would break a declared invariant aborts locally with
//!   [`TxnError::Invariant`] — no coordination, no state change.

use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{Bindings, Db, StateUpdate, TxnError, Value};
use elia::sqlir::parse_statement;
use elia::util::qcheck::{check, Config};
use elia::util::Rng;

const N_SERVERS: usize = 3;
const N_ITEMS: i64 = 8;
const SEED_LEVEL: i64 = 5;

fn schema() -> Schema {
    Schema::new(vec![
        TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        )
        .with_nonnegative("LEVEL"),
        TableSchema::new(
            "EVENTS",
            &[("E_ID", ValueType::Int), ("VAL", ValueType::Int)],
            &["E_ID"],
        )
        .with_unique("E_ID"),
    ])
}

fn binds(pairs: &[(&str, i64)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), Value::Int(*v))).collect()
}

fn seeded_db() -> Db {
    let db = Db::new(schema());
    let ins = parse_statement("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, ?l)").unwrap();
    for i in 0..N_ITEMS {
        db.exec_auto(&ins, &binds(&[("i", i), ("l", SEED_LEVEL)])).unwrap();
    }
    db
}

/// One classifier-admitted confluent operation (plus the rejected
/// decrement shape, which must abort locally).
#[derive(Clone, Debug)]
enum Op {
    /// `LEVEL = LEVEL + q` with `q >= 0` — safe delta under NonNegative.
    Restock { item: i64, q: i64 },
    /// Insert pinned on the declared-unique `E_ID`.
    Event { id: i64, val: i64 },
    /// A decrement far past the floor: must abort with `Invariant`.
    BadRestock { item: i64 },
}

/// Execute `op` at `db`, returning the captured update on commit.
fn execute(db: &Db, op: &Op) -> Result<StateUpdate, TxnError> {
    let (sql, b) = match op {
        Op::Restock { item, q } => (
            "UPDATE STOCK SET LEVEL = LEVEL + ?q WHERE ITEM = ?i",
            binds(&[("q", *q), ("i", *item)]),
        ),
        Op::Event { id, val } => (
            "INSERT INTO EVENTS (E_ID, VAL) VALUES (?id, ?val)",
            binds(&[("id", *id), ("val", *val)]),
        ),
        Op::BadRestock { item } => (
            "UPDATE STOCK SET LEVEL = LEVEL - ?q WHERE ITEM = ?i",
            binds(&[("q", 1_000), ("i", *item)]),
        ),
    };
    let stmt = parse_statement(sql).unwrap();
    let mut txn = db.begin();
    txn.exec(&stmt, &b)?;
    let (u, ()) = txn.commit_with(|_| ())?;
    Ok(u)
}

/// Every `STOCK.LEVEL` must satisfy the declared NonNegative invariant.
fn assert_invariant_holds(db: &Db, who: &str) {
    let q = parse_statement("SELECT LEVEL FROM STOCK WHERE ITEM = ?i").unwrap();
    for i in 0..N_ITEMS {
        let v = db.exec_auto(&q, &binds(&[("i", i)])).unwrap().scalar().unwrap().clone();
        match v {
            Value::Int(l) => assert!(l >= 0, "{who}: STOCK[{i}].LEVEL = {l} < 0"),
            other => panic!("{who}: unexpected LEVEL value {other:?}"),
        }
    }
}

/// Merge the remote origins' update queues into one random interleaving
/// that preserves each origin's internal order — exactly the set of
/// orders a destination replica can observe across token rotations.
fn random_interleave(
    rng: &mut Rng,
    queues: &[Vec<StateUpdate>],
    skip: Option<usize>,
) -> Vec<StateUpdate> {
    let mut cursors = vec![0usize; queues.len()];
    let mut out = Vec::new();
    loop {
        let live: Vec<usize> = (0..queues.len())
            .filter(|&o| Some(o) != skip && cursors[o] < queues[o].len())
            .collect();
        if live.is_empty() {
            return out;
        }
        let o = *rng.choose(&live);
        out.push(queues[o][cursors[o]].clone());
        cursors[o] += 1;
    }
}

#[test]
fn confluent_replay_is_order_independent_and_invariant_safe() {
    check(
        Config::default().cases(40).name("confluent-replay-soundness"),
        |rng| {
            // Generate a random multi-origin history of admitted ops
            // (and a few local-abort attempts).
            let n_ops = rng.range(5, 40);
            let mut next_event = 0i64;
            let history: Vec<(usize, Op)> = (0..n_ops)
                .map(|_| {
                    let origin = rng.range(0, N_SERVERS);
                    let op = match rng.range(0, 10) {
                        0 => Op::BadRestock { item: rng.range(0, N_ITEMS as usize) as i64 },
                        1..=5 => Op::Restock {
                            item: rng.range(0, N_ITEMS as usize) as i64,
                            q: rng.range(0, 4) as i64,
                        },
                        _ => {
                            next_event += 1;
                            Op::Event { id: next_event, val: rng.range(0, 100) as i64 }
                        }
                    };
                    (origin, op)
                })
                .collect();

            // Execute each op at its origin replica, capturing the
            // committed updates per origin (in commit order).
            let dbs: Vec<Db> = (0..N_SERVERS).map(|_| seeded_db()).collect();
            let mut queues: Vec<Vec<StateUpdate>> = vec![Vec::new(); N_SERVERS];
            for (origin, op) in &history {
                match execute(&dbs[*origin], op) {
                    Ok(u) => {
                        assert!(
                            !matches!(op, Op::BadRestock { .. }),
                            "invariant-breaking op committed at origin {origin}"
                        );
                        queues[*origin].push(u);
                    }
                    Err(e) => {
                        assert!(
                            matches!(op, Op::BadRestock { .. }),
                            "admitted confluent op aborted at origin {origin}: {e}"
                        );
                        assert!(
                            matches!(e, TxnError::Invariant { .. }),
                            "local abort must be TxnError::Invariant, got {e}"
                        );
                    }
                }
                assert_invariant_holds(&dbs[*origin], "origin");
            }

            // Replicate: each destination applies the other origins'
            // updates in its own random interleaving.
            for (d, db) in dbs.iter().enumerate() {
                for u in random_interleave(rng, &queues, Some(d)) {
                    db.apply_update(&u).unwrap();
                }
            }

            // Serial token-order reference: a fresh replica applying
            // every update in one fixed origin-major order.
            let reference = seeded_db();
            for u in queues.iter().flatten() {
                reference.apply_update(u).unwrap();
            }

            let want = reference.content_hash();
            assert_invariant_holds(&reference, "reference");
            for (s, db) in dbs.iter().enumerate() {
                assert_invariant_holds(db, "replica");
                assert_eq!(
                    db.content_hash(),
                    want,
                    "replica {s} diverged from the serial token-order reference"
                );
            }
        },
    );
}

#[test]
fn invariant_violation_aborts_locally_without_state_change() {
    let db = seeded_db();
    let before = db.content_hash();
    let err = execute(&db, &Op::BadRestock { item: 2 }).unwrap_err();
    match err {
        TxnError::Invariant { ref table, ref column, ref value } => {
            assert_eq!(table, "STOCK");
            assert_eq!(column, "LEVEL");
            assert!(value.starts_with('-'), "reported post-image must be negative, got {value}");
        }
        other => panic!("expected TxnError::Invariant, got {other}"),
    }
    assert!(!err.is_retryable(), "an invariant abort must not be retried");
    assert_eq!(db.content_hash(), before, "aborted op must leave no trace");
    assert_invariant_holds(&db, "after-abort");
}
