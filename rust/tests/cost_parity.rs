//! Cross-layer parity: the AOT-compiled Pallas artifact (executed from
//! Rust via PJRT) must produce the identical Algorithm-1 costs as the
//! scalar Rust reference scorer — on the real TPC-W-style conflict
//! structures, not just toys.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) if the
//! artifact has not been built.

use elia::analysis::conflict::ConflictMatrix;
use elia::analysis::elim::EliminationTensor;
use elia::analysis::partition::{optimize, PartitionOptions};
use elia::analysis::rwsets::{extract_rwsets, ExtractOptions};
use elia::analysis::score::{cost_batch, Assignment, BatchScorer, ScalarScorer};
use elia::catalog::{Schema, TableSchema, ValueType};
use elia::runtime::CostEvaluator;
use elia::util::Rng;
use elia::workload::spec::TxnTemplate;
use std::sync::Arc;

fn evaluator() -> Option<CostEvaluator> {
    let e = CostEvaluator::try_default();
    if e.is_none() {
        eprintln!("SKIP: artifacts/partition_cost.hlo.txt not built (run `make artifacts`)");
    }
    e
}

fn cart_tensor() -> EliminationTensor {
    let schema = Schema::new(vec![TableSchema::new(
        "SC",
        &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
        &["ID", "I_ID"],
    )]);
    let templates = vec![
        TxnTemplate::new(
            "createCart",
            &["sid"],
            &[("i", "INSERT INTO SC (ID, I_ID, QTY) VALUES (?sid, 0, 0)")],
            1.0,
        ),
        TxnTemplate::new(
            "doCart",
            &["iid", "sid", "q"],
            &[("u", "UPDATE SC SET QTY = ?q WHERE ID = ?sid AND I_ID = ?iid")],
            2.0,
        ),
        TxnTemplate::new(
            "getCart",
            &["sid"],
            &[("q", "SELECT QTY FROM SC WHERE ID = ?sid")],
            4.0,
        ),
    ];
    let rws: Vec<_> = templates
        .iter()
        .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
        .collect();
    EliminationTensor::build(&templates, &ConflictMatrix::detect(&rws))
}

fn random_assignments(tensor: &EliminationTensor, n: usize, seed: u64) -> Vec<Assignment> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            tensor
                .kdims
                .iter()
                .map(|&k| {
                    if k == 0 || rng.chance(0.2) {
                        None
                    } else {
                        Some(rng.range(0, k))
                    }
                })
                .collect()
        })
        .collect()
}

#[test]
fn artifact_matches_scalar_on_cart_app() {
    let Some(eval) = evaluator() else { return };
    let tensor = cart_tensor();
    let batch = random_assignments(&tensor, 300, 0xA11CE);
    let scalar = cost_batch(&tensor, &batch);
    let accel = eval.score(&tensor, &batch);
    assert_eq!(scalar.len(), accel.len());
    for (i, (s, a)) in scalar.iter().zip(&accel).enumerate() {
        assert!(
            (s - a).abs() < 1e-3,
            "case {i}: scalar={s} artifact={a} assignment={:?}",
            batch[i]
        );
    }
}

#[test]
fn artifact_scorer_plugs_into_optimizer() {
    let Some(eval) = evaluator() else { return };
    let tensor = cart_tensor();
    let scalar_opt = optimize(&tensor, &PartitionOptions::default());
    let accel_opt = optimize(
        &tensor,
        &PartitionOptions { scorer: Arc::new(eval), ..Default::default() },
    );
    assert_eq!(scalar_opt.cost, accel_opt.cost);
    assert_eq!(scalar_opt.choice, accel_opt.choice);
}

#[test]
fn artifact_handles_odd_batch_sizes() {
    let Some(eval) = evaluator() else { return };
    let tensor = cart_tensor();
    for n in [1usize, 7, 255, 256, 257, 513] {
        let batch = random_assignments(&tensor, n, n as u64);
        let scalar = cost_batch(&tensor, &batch);
        let accel = eval.score(&tensor, &batch);
        assert_eq!(scalar.len(), accel.len(), "n={n}");
        for (s, a) in scalar.iter().zip(&accel) {
            assert!((s - a).abs() < 1e-3, "n={n}: {s} vs {a}");
        }
    }
}

#[test]
fn artifact_matches_scalar_property() {
    // Random synthetic tensors exercised through the same public surface:
    // build random templates, run the full pipeline, compare scorers.
    let Some(eval) = evaluator() else { return };
    let schema = Schema::new(vec![TableSchema::new(
        "T",
        &[("A", ValueType::Int), ("B", ValueType::Int), ("V", ValueType::Int)],
        &["A", "B"],
    )]);
    let mut rng = Rng::new(0xBEEF);
    for case in 0..10 {
        let nt = rng.range(2, 7);
        let templates: Vec<TxnTemplate> = (0..nt)
            .map(|i| {
                let cond = match rng.range(0, 4) {
                    0 => "A = ?p0",
                    1 => "B = ?p1",
                    2 => "A = ?p0 AND B = ?p1",
                    _ => "A = ?p1 AND B = ?p0",
                };
                TxnTemplate::new(
                    Box::leak(format!("t{i}").into_boxed_str()),
                    &["p0", "p1"],
                    &[(
                        "u",
                        Box::leak(
                            format!("UPDATE T SET V = {i} WHERE {cond}").into_boxed_str(),
                        ),
                    )],
                    1.0 + rng.range(0, 4) as f64,
                )
            })
            .collect();
        let rws: Vec<_> = templates
            .iter()
            .map(|t| extract_rwsets(t, &schema, ExtractOptions::default()))
            .collect();
        let tensor = EliminationTensor::build(&templates, &ConflictMatrix::detect(&rws));
        let batch = random_assignments(&tensor, 64, case);
        let scalar = ScalarScorer.score(&tensor, &batch);
        let accel = eval.score(&tensor, &batch);
        for (i, (s, a)) in scalar.iter().zip(&accel).enumerate() {
            assert!((s - a).abs() < 1e-3, "case {case}.{i}: {s} vs {a}");
        }
    }
}
