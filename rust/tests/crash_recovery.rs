//! Kill-and-recover tests for the write-ahead log (`db::wal`), plus the
//! qcheck replay property behind it.
//!
//! The crash model: the engine is in-process, so "kill" means dropping
//! the `Db` (losing all in-memory state, plus any user-space WAL buffer
//! under `SyncPolicy::Batch`) and "power loss mid-write" means
//! truncating a copy of the log file at an arbitrary byte offset.
//! Recovery must replay to a state bit-identical (`content_hash`) to
//! the committed state at the surviving record boundary — at *every*
//! boundary, and at torn offsets in between.
//!
//! `ELIA_CRASH_SEED` reseeds the random workload (the `make test-crash`
//! seed matrix); `QCHECK_SEED`/`QCHECK_CASES` drive the property test.

use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{Bindings, Db, DurabilityConfig, Key, StateUpdate, SyncPolicy, Value, WriteRecord};
use elia::sqlir::parse_statement;
use elia::util::qcheck::{check, Config};
use elia::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

fn schema() -> Schema {
    Schema::new(vec![TableSchema::new(
        "ITEMS",
        &[
            ("ID", ValueType::Int),
            ("TITLE", ValueType::Str),
            ("STOCK", ValueType::Int),
            ("COST", ValueType::Float),
        ],
        &["ID"],
    )])
}

fn seed(db: &Db) {
    let ins = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)")
        .unwrap();
    for i in 0..8i64 {
        db.exec_auto(&ins, &b(&[
            ("id", Value::Int(i)),
            ("t", Value::Str(format!("seed{i}"))),
            ("s", Value::Int(100)),
            ("c", Value::Float(1.5 * i as f64)),
        ]))
        .unwrap();
    }
}

fn b(pairs: &[(&str, Value)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
}

/// A fresh per-test scratch file path (no tempfile crate in the
/// zero-dependency build).
fn scratch(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "elia_crash_{}_{tag}_{n}.wal",
        std::process::id()
    ))
}

fn crash_seed() -> u64 {
    std::env::var("ELIA_CRASH_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC4A5)
}

/// Deterministic random workload: single- and multi-statement
/// transactions over inserts, Set updates, Add deltas (Int and Float
/// columns) and deletes. Every committed transaction writes at least
/// one record. Returns the recorded `StateUpdate`s in commit order.
struct Driver {
    live: Vec<i64>,
    next_id: i64,
}

impl Driver {
    fn new() -> Driver {
        // Fresh ids start above the seeded 0..8 range.
        Driver { live: Vec::new(), next_id: 1000 }
    }

    fn run(&mut self, db: &Db, rng: &mut Rng, n_txns: usize) -> Vec<StateUpdate> {
        let mut updates = Vec::with_capacity(n_txns);
        for _ in 0..n_txns {
            let mut txn = db.begin();
            for _ in 0..1 + rng.range(0, 3) {
                self.step(&mut txn, rng);
            }
            let u = txn.commit().unwrap();
            if u.is_empty() {
                // Every statement hit a row deleted earlier in the same
                // txn; force one insert so the stream stays non-empty.
                let mut txn = db.begin();
                self.insert(&mut txn, rng);
                let u = txn.commit().unwrap();
                assert!(!u.is_empty());
                updates.push(u);
            } else {
                updates.push(u);
            }
        }
        updates
    }

    fn step(&mut self, txn: &mut elia::db::TxnHandle<'_>, rng: &mut Rng) {
        match rng.range(0, 10) {
            0..=2 => self.insert(txn, rng),
            3..=5 => self.with_live(rng, |id, rng| {
                let d = rng.range(0, 40) as i64 - 20;
                let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + ?d WHERE ID = ?id")
                    .unwrap();
                txn.exec(&u, &b(&[("d", Value::Int(d)), ("id", Value::Int(id))])).unwrap();
            }),
            6 => self.with_live(rng, |id, rng| {
                let d = rng.f64() * 4.0 - 2.0;
                let u = parse_statement("UPDATE ITEMS SET COST = COST + ?d WHERE ID = ?id")
                    .unwrap();
                txn.exec(&u, &b(&[("d", Value::Float(d)), ("id", Value::Int(id))])).unwrap();
            }),
            7..=8 => self.with_live(rng, |id, rng| {
                let t = format!("t{}", rng.range(0, 1_000_000));
                let u = parse_statement("UPDATE ITEMS SET TITLE = ?t WHERE ID = ?id").unwrap();
                txn.exec(&u, &b(&[("t", Value::Str(t)), ("id", Value::Int(id))])).unwrap();
            }),
            _ => {
                if self.live.is_empty() {
                    self.insert(txn, rng);
                } else {
                    let i = rng.range(0, self.live.len());
                    let id = self.live.swap_remove(i);
                    let u = parse_statement("DELETE FROM ITEMS WHERE ID = ?id").unwrap();
                    txn.exec(&u, &b(&[("id", Value::Int(id))])).unwrap();
                }
            }
        }
    }

    /// Run `f` with a random live id, inserting one first if none exist.
    fn with_live(&mut self, rng: &mut Rng, f: impl FnOnce(i64, &mut Rng)) {
        if self.live.is_empty() {
            // No live row to mutate: mutate a seeded row instead.
            f(rng.range(0, 8) as i64, rng);
        } else {
            let id = self.live[rng.range(0, self.live.len())];
            f(id, rng);
        }
    }

    fn insert(&mut self, txn: &mut elia::db::TxnHandle<'_>, rng: &mut Rng) {
        let id = self.next_id;
        self.next_id += 1;
        self.live.push(id);
        let u = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)")
            .unwrap();
        txn.exec(&u, &b(&[
            ("id", Value::Int(id)),
            ("t", Value::Str(format!("row{id}"))),
            ("s", Value::Int(rng.range(0, 500) as i64)),
            ("c", Value::Float(rng.f64() * 100.0)),
        ]))
        .unwrap();
    }
}

/// Run `n_txns` against a WAL-attached Db and record, after each commit,
/// the log length and the committed `content_hash` — the oracle for
/// every crash point.
fn committed_boundaries(path: &Path, policy: SyncPolicy, n_txns: usize) -> Vec<(u64, u64)> {
    let cfg = DurabilityConfig::new(path).with_policy(policy);
    let mut db = Db::new(schema());
    seed(&db);
    db = db.with_durability(&cfg).unwrap();
    let mut rng = Rng::new(crash_seed());
    let mut driver = Driver::new();
    let mut boundaries = vec![(std::fs::metadata(path).unwrap().len(), db.content_hash())];
    for _ in 0..n_txns {
        driver.run(&db, &mut rng, 1);
        boundaries.push((std::fs::metadata(path).unwrap().len(), db.content_hash()));
    }
    boundaries
}

#[test]
fn recovery_replays_to_identical_state_at_every_record_boundary() {
    let path = scratch("boundary");
    let boundaries = committed_boundaries(&path, SyncPolicy::Always, 24);

    // Under Always every commit is on disk when acknowledged: simulate
    // a crash at each record boundary by truncating a copy there.
    let copy = scratch("boundary_copy");
    for (i, (len, hash)) in boundaries.iter().enumerate() {
        std::fs::copy(&path, &copy).unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&copy).unwrap();
        f.set_len(*len).unwrap();
        drop(f);
        let cfg = DurabilityConfig::new(&copy).with_policy(SyncPolicy::Always);
        let (db, report) = Db::recover(schema(), &cfg, seed).unwrap();
        assert_eq!(report.replayed, i, "boundary {i}: wrong record count");
        assert_eq!(report.truncated_bytes, 0, "boundary {i}: clean log has no torn tail");
        assert_eq!(db.content_hash(), *hash, "boundary {i}: recovered state diverges");
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&copy);
}

#[test]
fn torn_tail_is_truncated_to_the_last_committed_record() {
    let path = scratch("torn");
    let boundaries = committed_boundaries(&path, SyncPolicy::Always, 12);

    let copy = scratch("torn_copy");
    for i in 1..boundaries.len() {
        let (prev_len, prev_hash) = boundaries[i - 1];
        let (len, _) = boundaries[i];
        // A torn offset strictly inside record i: part of its frame or
        // payload made it to disk, the rest did not.
        for torn in [prev_len + 1, prev_len + (len - prev_len) / 2, len - 1] {
            if torn <= prev_len || torn >= len {
                continue;
            }
            std::fs::copy(&path, &copy).unwrap();
            let f = std::fs::OpenOptions::new().write(true).open(&copy).unwrap();
            f.set_len(torn).unwrap();
            drop(f);
            let cfg = DurabilityConfig::new(&copy).with_policy(SyncPolicy::Always);
            let (db, report) = Db::recover(schema(), &cfg, seed).unwrap();
            assert_eq!(report.replayed, i - 1, "torn at {torn}: wrong record count");
            assert_eq!(report.truncated_bytes, torn - prev_len, "torn at {torn}");
            assert_eq!(db.content_hash(), prev_hash, "torn at {torn}: state diverges");
            // The tail is gone from the file itself, so the next append
            // starts at a clean boundary...
            assert_eq!(std::fs::metadata(&copy).unwrap().len(), prev_len);
            // ...and the recovered db keeps committing durably.
            let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + 1 WHERE ID = 0").unwrap();
            db.exec_auto(&u, &Bindings::new()).unwrap();
            let after = db.content_hash();
            drop(db);
            let (db2, r2) = Db::recover(schema(), &cfg, seed).unwrap();
            assert_eq!(r2.replayed, i, "resume: the new commit must be in the log");
            assert_eq!(db2.content_hash(), after, "resume: state diverges");
        }
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&copy);
}

#[test]
fn batch_policy_loses_only_the_unflushed_tail() {
    let path = scratch("batch");
    // 10 commits under Batch(4): flushes after commits 4 and 8; 9 and
    // 10 live only in the user-space buffer.
    let boundaries = committed_boundaries(&path, SyncPolicy::Batch(4), 10);
    // committed_boundaries dropped the Db without flush: the in-process
    // crash. Only the 8 flushed records survive.
    let cfg = DurabilityConfig::new(&path).with_policy(SyncPolicy::Batch(4));
    let (db, report) = Db::recover(schema(), &cfg, seed).unwrap();
    assert_eq!(report.replayed, 8, "Batch(4) after 10 commits must have flushed 8");
    assert_eq!(db.content_hash(), boundaries[8].1, "state must match flush boundary");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn batch_policy_flush_makes_the_tail_durable() {
    let path = scratch("flush");
    let cfg = DurabilityConfig::new(&path).with_policy(SyncPolicy::Batch(64));
    let mut db = Db::new(schema());
    seed(&db);
    db = db.with_durability(&cfg).unwrap();
    let mut rng = Rng::new(crash_seed());
    Driver::new().run(&db, &mut rng, 7);
    let wal = db.wal().unwrap();
    assert_eq!(wal.appended(), 7);
    assert_eq!(wal.durable(), 0, "Batch(64): nothing flushed after 7 commits");
    wal.flush().unwrap();
    assert_eq!(wal.durable(), 7, "flush covers the whole tail");
    let hash = db.content_hash();
    drop(db);
    let (db2, report) = Db::recover(schema(), &cfg, seed).unwrap();
    assert_eq!(report.replayed, 7);
    assert_eq!(db2.content_hash(), hash);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn group_commit_survives_concurrent_committers() {
    let path = scratch("group");
    let cfg = DurabilityConfig::new(&path).with_policy(SyncPolicy::Always);
    let mut db = Db::new(schema());
    seed(&db);
    db = db.with_durability(&cfg).unwrap();
    let db = std::sync::Arc::new(db);

    let threads: i64 = 8;
    let per_thread: i64 = 25;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let db = std::sync::Arc::clone(&db);
            std::thread::spawn(move || {
                let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + 1 WHERE ID = ?id")
                    .unwrap();
                for i in 0..per_thread {
                    let binds = b(&[("id", Value::Int((t + i) % 8))]);
                    loop {
                        let mut txn = db.begin();
                        match txn.exec(&u, &binds) {
                            Ok(_) => {
                                txn.commit().unwrap();
                                break;
                            }
                            Err(e) if e.is_retryable() => continue,
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let wal = db.wal().unwrap();
    assert_eq!(wal.appended(), (threads * per_thread) as u64);
    assert_eq!(wal.durable(), wal.appended(), "Always: every ack'd commit is on disk");
    let hash = db.content_hash();
    // Total increments conserved regardless of interleaving.
    let total: i64 = (0..8)
        .map(|i| db.peek("ITEMS", &Key::single(Value::Int(i))).unwrap()[2].as_int().unwrap())
        .sum();
    assert_eq!(total, 8 * 100 + threads * per_thread);
    drop(db);

    let (db2, report) = Db::recover(schema(), &cfg, seed).unwrap();
    assert_eq!(report.replayed, (threads * per_thread) as usize);
    assert_eq!(db2.content_hash(), hash, "recovery must replay the 2PL commit order");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn recover_on_a_missing_log_starts_fresh() {
    let path = scratch("fresh");
    let cfg = DurabilityConfig::new(&path).with_policy(SyncPolicy::Always);
    let (db, report) = Db::recover(schema(), &cfg, seed).unwrap();
    assert_eq!(report, elia::db::RecoveryReport::default());
    let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + 1 WHERE ID = 0").unwrap();
    db.exec_auto(&u, &Bindings::new()).unwrap();
    assert_eq!(db.wal().unwrap().appended(), 1, "the fresh log accepts appends");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn qcheck_replay_stream_reproduces_content_hash() {
    // The recovery invariant, with the file taken out of the picture:
    // replaying a recorded StateUpdate stream over the seed snapshot —
    // in full, or a partial prefix followed by a resume — reproduces
    // the primary's committed content_hash exactly.
    check(Config::default().cases(40).name("wal-replay"), |rng| {
        let db1 = Db::new(schema());
        seed(&db1);
        let mut driver = Driver::new();
        let updates = driver.run(&db1, rng, 12);
        let want = db1.content_hash();

        // Full replay.
        let db2 = Db::new(schema());
        seed(&db2);
        for u in &updates {
            db2.apply_update(u).unwrap();
        }
        assert_eq!(db2.content_hash(), want, "full replay diverged");

        // Partial replay, then resume from the cut point.
        let cut = rng.range(0, updates.len() + 1);
        let db3 = Db::new(schema());
        seed(&db3);
        for u in &updates[..cut] {
            db3.apply_update(u).unwrap();
        }
        for u in &updates[cut..] {
            db3.apply_update(u).unwrap();
        }
        assert_eq!(db3.content_hash(), want, "partial-then-resume replay diverged at {cut}");
    });
}

#[test]
fn workload_exercises_all_three_record_kinds() {
    // Guard for the property above: the generated streams must actually
    // contain Insert, Update and Delete records, or the replay property
    // silently weakens.
    let db = Db::new(schema());
    seed(&db);
    let mut rng = Rng::new(crash_seed());
    let updates = Driver::new().run(&db, &mut rng, 40);
    let (mut ins, mut upd, mut del) = (0, 0, 0);
    for u in &updates {
        for r in &u.records {
            match r {
                WriteRecord::Insert { .. } => ins += 1,
                WriteRecord::Update { .. } => upd += 1,
                WriteRecord::Delete { .. } => del += 1,
            }
        }
    }
    assert!(ins > 0 && upd > 0 && del > 0, "kinds: ins={ins} upd={upd} del={del}");
}
