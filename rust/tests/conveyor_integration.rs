//! Integration: full TPC-W / RUBiS applications on both runtimes — the
//! real-threads deployment (real concurrency) and the virtual-time
//! simulator with real execution enabled — checking cross-layer
//! consistency between analysis, routing, execution and replication.

use elia::conveyor::{ConveyorConfig, ConveyorSim, DeployConfig, Deployment};
use elia::db::Bindings;
use elia::simnet::clients::ClientsConfig;
use elia::simnet::latency::Topology;
use elia::sqlir::parse_statement;
use elia::util::{Rng, VTime};
use elia::workload::generator::{OpGenerator, ServiceModel};
use elia::workload::{rubis, tpcw};
use std::sync::Arc;

#[test]
fn tpcw_on_real_threads_converges() {
    let app = Arc::new(tpcw::analyzed());
    let scale = tpcw::TpcwScale { items: 100, customers: 100, ..Default::default() };
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig { n_servers: 3, ..Default::default() },
        |db| tpcw::seed(db, scale),
    );
    let mut handles = Vec::new();
    for client in 0..6u64 {
        let dep = Arc::clone(&dep);
        let app = Arc::clone(&app);
        handles.push(std::thread::spawn(move || {
            let mut gen = tpcw::TpcwGenerator::new(&app, scale, 3).with_stream(client);
            let mut rng = Rng::new(client);
            for _ in 0..80 {
                let op = gen.next_op(&mut rng, client as usize % 3, 3);
                let _ = dep.submit(op); // benign semantic errors allowed
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    dep.shutdown();
    // The replicated ITEM table must be identical everywhere.
    let q = parse_statement("SELECT SUM(I_STOCK) FROM ITEM").unwrap();
    let v0 = dep.db(0).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().clone();
    for s in 1..3 {
        let v = dep.db(s).exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().clone();
        assert_eq!(v, v0, "server {s} ITEM stock diverged");
    }
}

#[test]
fn rubis_on_simulator_with_real_execution() {
    let app = rubis::analyzed();
    let scale = rubis::RubisScale { users: 200, items: 400, ..Default::default() };
    let cfg = ConveyorConfig {
        execute_real: true,
        service: ServiceModel::fixed(5.0),
        warmup: VTime::from_secs(1),
        horizon: VTime::from_secs(6),
        ..Default::default()
    };
    let report = ConveyorSim::new(
        &app,
        Topology::lan(3),
        ClientsConfig { n: 24, think_ms: 20.0, seed: 5, ..Default::default() },
        cfg,
        |_| Box::new(rubis::RubisGenerator::new(&app, scale)),
        |db| rubis::seed(db, scale),
    )
    .run();
    assert!(report.metrics.completed > 300, "completed={}", report.metrics.completed);
    // Sim executions are sequential per event; aborts should be rare
    // (only duplicate-key collisions on generated ids).
    assert!(
        (report.aborts as f64) < report.metrics.completed as f64 * 0.05,
        "aborts={} completed={}",
        report.aborts,
        report.metrics.completed
    );
}

#[test]
fn runtime_global_fraction_matches_static_frequencies() {
    // The routed global share of generated TPC-W ops must track Table 1's
    // 39% within tolerance at several deployment sizes.
    let app = tpcw::analyzed();
    for n in [2usize, 4, 8] {
        let mut gen = tpcw::TpcwGenerator::new(&app, tpcw::TpcwScale::default(), n);
        let mut rng = Rng::new(n as u64);
        let mut global = 0usize;
        let total = 3000;
        for i in 0..total {
            let op = gen.next_op(&mut rng, i % n, n);
            if app.route(&op, n).is_global() {
                global += 1;
            }
        }
        let frac = global as f64 / total as f64;
        assert!((frac - 0.39).abs() < 0.05, "n={n}: global frac {frac}");
    }
}

#[test]
fn wan_deployment_with_injected_hop_latency() {
    // Real threads with a real 5ms token hop: global ops must still
    // complete and replicate correctly (slower, but correct).
    let app = Arc::new(tpcw::analyzed());
    let scale = tpcw::TpcwScale { items: 50, customers: 50, ..Default::default() };
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig {
            n_servers: 3,
            hop_delay: std::time::Duration::from_millis(5),
            ..Default::default()
        },
        |db| tpcw::seed(db, scale),
    );
    let mut gen = tpcw::TpcwGenerator::new(&app, scale, 3);
    let mut rng = Rng::new(1);
    let t0 = std::time::Instant::now();
    let mut globals = 0;
    for i in 0..60 {
        let op = gen.next_op(&mut rng, i % 3, 3);
        if app.route(&op, 3).is_global() {
            globals += 1;
        }
        let _ = dep.submit(op);
    }
    assert!(globals > 5, "need some globals, got {globals}");
    // Each global waits for at least one hop (5ms+); the run must take
    // visibly longer than a zero-latency run but still finish promptly.
    let elapsed = t0.elapsed();
    assert!(elapsed >= std::time::Duration::from_millis(15), "{elapsed:?}");
    dep.shutdown();
}
