//! Fault injection on the networked conveyor belt: sever live ring
//! connections mid-rotation and verify the token's exactly-once custody
//! — after reconnection the belt resumes with **no duplicated and no
//! lost** `StateUpdate`, and all replicas still converge.
//!
//! The loopback transport's `cut` closes both pipe ends of a live link
//! and drops any in-flight frames, which exercises both halves of the
//! custody protocol: a token frame lost *before* receipt (no ack — the
//! sender retransmits over a fresh connection) and an ack lost *after*
//! receipt (the receiver dedupes the retransmitted hop).

mod common;

use common::{op, seed, store_app, INIT_STOCK, N_ITEMS};
use elia::harness::experiments::{replica_hash, replicated_tables};
use elia::net::{Cluster, Loopback, NetClient, ServeConfig, Transport};
use elia::workload::analyzed::AnalyzedApp;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Drive a burst of replicated work: confluent rates plus global orders
/// (each order preceded by a local add so it has something to clear).
/// Returns the rating mass submitted.
fn burst(client: &mut NetClient, app: &AnalyzedApp, base: i64, rounds: i64) -> i64 {
    let mut rated = 0;
    for i in 0..rounds {
        let cart = base + i;
        let item = i % N_ITEMS;
        client.submit(&op(app, "add", &[("c", cart), ("t", item), ("a", 1)])).unwrap();
        let q = i % 4;
        client.submit(&op(app, "rate", &[("t", item), ("q", q)])).unwrap();
        rated += q;
        client.submit(&op(app, "order", &[("c", cart)])).unwrap();
    }
    rated
}

#[test]
fn token_survives_ring_cuts_without_duplication_or_loss() {
    let n = 3;
    let app = store_app();
    let loopback = Arc::new(Loopback::new());
    let transport: Arc<dyn Transport> = Arc::clone(&loopback) as Arc<dyn Transport>;
    let cfg = ServeConfig {
        record_history: true,
        // Tight ack deadline so retransmission after a cut is quick.
        ack_timeout: Duration::from_millis(5),
        ..ServeConfig::loopback(n)
    };
    let cluster = Cluster::start(Arc::clone(&app), cfg, transport, seed).unwrap();
    let mut client = cluster.client(Arc::clone(&app)).unwrap();

    // Phase 1: put real entries on the belt. Each global op completes a
    // rotation, so by the end the ring links are live and the token is
    // circulating.
    let mut rated = burst(&mut client, &app, 0, 20);

    // Sever ring links while entries from phase 1 may still be in
    // flight. Both pipe directions close and queued frames vanish; the
    // unacked sender must redial and retransmit.
    let severed: usize =
        cluster.ring_addrs().iter().skip(1).map(|a| loopback.cut(a)).sum();
    assert!(severed >= 1, "expected at least one live ring connection to sever");

    // Phase 2: the belt must recover — globals park until the token
    // resumes, so every successful submit below proves liveness.
    rated += burst(&mut client, &app, 10_000, 20);

    // A second cut, then a final burst, to hit a reconnected link too.
    let severed2 = loopback.cut(&cluster.ring_addrs()[1]);
    assert!(severed2 >= 1, "reconnected ring link should be live again");
    rated += burst(&mut client, &app, 20_000, 10);

    cluster.shutdown();

    // Replicated state converges despite the cuts.
    let tables = replicated_tables(&app);
    let h0 = replica_hash(cluster.db(0), &tables);
    for s in 1..n {
        assert_eq!(replica_hash(cluster.db(s), &tables), h0, "server {s} replica digest");
    }
    // Conservation and rating mass: a duplicated StateUpdate would
    // overshoot these sums, a lost one would undershoot.
    for s in 0..n {
        let mut score_sum = 0;
        for i in 0..N_ITEMS {
            let r = cluster
                .db(s)
                .peek("STOCK", &elia::db::Key::single(elia::db::Value::Int(i)))
                .unwrap();
            let (level, sold) = (r[1].as_int().unwrap(), r[2].as_int().unwrap());
            assert!(level >= 0, "item {i} oversold at server {s}");
            assert_eq!(level + sold, INIT_STOCK, "conservation broken for item {i} at {s}");
            let rr = cluster
                .db(s)
                .peek("RATING", &elia::db::Key::single(elia::db::Value::Int(i)))
                .unwrap();
            score_sum += rr[1].as_int().unwrap();
        }
        assert_eq!(score_sum, rated, "server {s}: rating mass lost or duplicated");
    }

    // No-dup/no-loss oracle on the belt history: one entry per executed
    // replicated op, sequence numbers contiguous from 1.
    let history = cluster.global_history();
    let executed: u64 = (0..n)
        .map(|s| {
            cluster.node(s).ops_global.load(Ordering::Relaxed)
                + cluster.node(s).ops_confluent.load(Ordering::Relaxed)
        })
        .sum();
    assert_eq!(history.len() as u64, executed, "belt history vs executed replicated ops");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.seq, i as u64 + 1, "belt history has a gap or duplicate after cuts");
    }
    // 50 orders + 50 rates total; the counters must account for all.
    assert_eq!(executed, 100);
}

/// Sustained fault load: eight deterministic rounds of burst-then-sever,
/// each round cutting *every* ring link (including server 0's and links
/// freshly redialed after the previous round). This is the regression
/// shape for token loss under repeated crashes: a single custody bug —
/// one retransmission dropped or double-applied anywhere in the run —
/// shows up as a gap or duplicate in the belt history, a broken
/// conservation sum, or diverged replicas.
#[test]
fn token_survives_sustained_multi_cut_load() {
    let n = 3;
    let app = store_app();
    let loopback = Arc::new(Loopback::new());
    let transport: Arc<dyn Transport> = Arc::clone(&loopback) as Arc<dyn Transport>;
    let cfg = ServeConfig {
        record_history: true,
        ack_timeout: Duration::from_millis(5),
        ..ServeConfig::loopback(n)
    };
    let cluster = Cluster::start(Arc::clone(&app), cfg, transport, seed).unwrap();
    let mut client = cluster.client(Arc::clone(&app)).unwrap();

    let rounds = 8;
    let per_round = 8i64;
    let mut rated = 0;
    for round in 0..rounds {
        // Distinct cart ids per round so every order clears a fresh cart.
        rated += burst(&mut client, &app, (round as i64) * 1000, per_round);
        // Sever everything that is live; later rounds hit reconnected
        // links, exercising retransmission over fresh connections again
        // and again.
        let severed: usize = cluster.ring_addrs().iter().map(|a| loopback.cut(a)).sum();
        if round == 0 {
            assert!(severed >= 1, "expected live ring connections to sever");
        }
    }
    cluster.shutdown();

    // Replicas converge despite eight generations of cuts.
    let tables = replicated_tables(&app);
    let h0 = replica_hash(cluster.db(0), &tables);
    for s in 1..n {
        assert_eq!(replica_hash(cluster.db(s), &tables), h0, "server {s} replica digest");
    }
    // Conservation and rating mass at every server.
    for s in 0..n {
        let mut score_sum = 0;
        for i in 0..N_ITEMS {
            let r = cluster
                .db(s)
                .peek("STOCK", &elia::db::Key::single(elia::db::Value::Int(i)))
                .unwrap();
            let (level, sold) = (r[1].as_int().unwrap(), r[2].as_int().unwrap());
            assert!(level >= 0, "item {i} oversold at server {s}");
            assert_eq!(level + sold, INIT_STOCK, "conservation broken for item {i} at {s}");
            let rr = cluster
                .db(s)
                .peek("RATING", &elia::db::Key::single(elia::db::Value::Int(i)))
                .unwrap();
            score_sum += rr[1].as_int().unwrap();
        }
        assert_eq!(score_sum, rated, "server {s}: rating mass lost or duplicated");
    }
    // Belt history: one entry per executed replicated op (an order and a
    // rate per burst iteration), seqs contiguous from 1 across all cuts.
    let history = cluster.global_history();
    let executed: u64 = (0..n)
        .map(|s| {
            cluster.node(s).ops_global.load(Ordering::Relaxed)
                + cluster.node(s).ops_confluent.load(Ordering::Relaxed)
        })
        .sum();
    assert_eq!(executed, (rounds as u64) * (per_round as u64) * 2);
    assert_eq!(history.len() as u64, executed, "belt history vs executed replicated ops");
    for (i, e) in history.iter().enumerate() {
        assert_eq!(e.seq, i as u64 + 1, "belt history has a gap or duplicate");
    }
}

/// Cutting a *client* connection surfaces a transport error on the stub
/// (at-most-once: the client does not silently re-execute), and a fresh
/// connection works — the server side survives the disconnect.
#[test]
fn client_cut_surfaces_transport_error_and_server_survives() {
    let app = store_app();
    let loopback = Arc::new(Loopback::new());
    let transport: Arc<dyn Transport> = Arc::clone(&loopback) as Arc<dyn Transport>;
    let cluster =
        Cluster::start(Arc::clone(&app), ServeConfig::loopback(2), transport, seed).unwrap();

    let mut client = cluster.client(Arc::clone(&app)).unwrap();
    client.submit(&op(&app, "add", &[("c", 7), ("t", 1), ("a", 2)])).unwrap();

    // Kill every client connection.
    let severed: usize =
        cluster.client_addrs().iter().map(|a| loopback.cut(a)).sum();
    assert!(severed >= 1, "client connections should have been live");

    // The stub reports the failure instead of retrying blindly...
    let err = client.submit(&op(&app, "readCart", &[("c", 7)]));
    assert!(
        matches!(err, Err(elia::net::NetError::Transport(_))),
        "expected a transport error after the cut, got {err:?}"
    );

    // ...and a new client (or the same stub, which redials lazily on the
    // next call) keeps working against the same servers.
    let r = client.submit(&op(&app, "readCart", &[("c", 7)])).unwrap();
    assert_eq!(r.len(), 1, "state must have survived the client disconnect");
    cluster.shutdown();
}
