//! Live routing epochs (tentpole): static vs adaptive operation
//! partitioning under workload drift, across both runtimes.
//!
//! * **Shape** — on the flash-crowd drift workload the adaptive arm's
//!   steady-state belted fraction returns to the pre-drift level while
//!   the static arm's stays high ([`fig_drift`]).
//! * **Soundness** — an epoch switch must not lose or duplicate a
//!   single replicated `StateUpdate`: the token-log sequence numbers
//!   stay contiguous from 1 across the switch, and every server's
//!   witness table (`C_TAB`, written only by the always-global `move`)
//!   is a bit-identical prefix of the serial token history.
//! * **Real threads** — the in-process deployment's token thread
//!   observes the drifted mix, installs a new epoch, and the drained
//!   replicas still converge.

use elia::analysis::drift::{AdaptiveConfig, DriftConfig};
use elia::conveyor::{ConveyorConfig, ConveyorSim, DeployConfig, Deployment};
use elia::db::Db;
use elia::harness::experiments::{fig_drift, ExpScale};
use elia::simnet::clients::ClientsConfig;
use elia::simnet::latency::Topology;
use elia::util::{Rng, VTime};
use elia::workload::generator::{OpGenerator, ServiceModel};
use elia::workload::micro;
use std::sync::Arc;

/// The drift figure's reproduction target: identical arms before the
/// drift point, a strictly lower belted fraction for the adaptive arm
/// after it (the controller made the newly-hot template local again).
#[test]
fn adaptive_belted_fraction_drops_below_static_after_drift() {
    let scale = ExpScale::quick();
    let (fixed, adaptive) = fig_drift(&scale);
    assert_eq!(fixed.epoch_switches, 0, "frozen controller must never switch");
    assert_eq!(fixed.final_epoch, 0);
    assert!(adaptive.epoch_switches >= 1, "controller must react to the drift");
    assert!(adaptive.final_epoch >= 1);
    // Pre-drift both arms run epoch 0 on the same deterministic
    // workload: identical curves.
    assert!(
        (fixed.belted_pre - adaptive.belted_pre).abs() < 1e-12,
        "pre-drift arms must agree: {} vs {}",
        fixed.belted_pre,
        adaptive.belted_pre
    );
    assert!(
        adaptive.belted_post < fixed.belted_post,
        "adaptive post-drift belted fraction {} must be strictly below static {}",
        adaptive.belted_post,
        fixed.belted_post
    );
    // And not marginally: re-partitioning should roughly restore the
    // pre-drift coordination profile.
    assert!(
        adaptive.belted_post < fixed.belted_post * 0.7,
        "adaptive {} vs static {}: expected a decisive drop",
        adaptive.belted_post,
        fixed.belted_post
    );
}

/// Epoch installation rides the conveyor-belt token, so it must
/// serialize cleanly with the replicated updates around it: sequence
/// numbers contiguous from 1 (nothing lost, nothing applied twice) and
/// every server's witness table explainable as a prefix of the one
/// serial history — including across the switch.
#[test]
fn epoch_switch_loses_and_duplicates_nothing() {
    let app = micro::drift_analyzed();
    let cfg = ConveyorConfig {
        execute_real: true,
        record_global_log: true,
        service: ServiceModel::fixed(1.0),
        warmup: VTime::from_secs(1),
        horizon: VTime::from_secs(20),
        adaptive: Some(AdaptiveConfig { window_rotations: 32, ..Default::default() }),
        ..Default::default()
    };
    let (r, dbs) = ConveyorSim::new(
        &app,
        Topology::lan(3),
        ClientsConfig { n: 24, think_ms: 10.0, seed: 7, ..Default::default() },
        cfg,
        |_| Box::new(micro::DriftGen::new(DriftConfig::default())),
        micro::drift_seed,
    )
    .run_keep_dbs();
    assert!(r.epoch_switches >= 1, "the drift must trigger a switch");
    assert!(r.metrics.completed > 1000);
    assert!(!r.global_log.is_empty());

    // Token seqs: exactly 1..=len, no gap, no duplicate.
    assert_eq!(r.global_log_seqs.len(), r.global_log.len());
    for (i, &seq) in r.global_log_seqs.iter().enumerate() {
        assert_eq!(seq, i as u64 + 1, "token history must be gap- and duplicate-free");
    }

    // Serial replay: hash the witness table after every log entry. A
    // server that lost or double-applied an update across the switch
    // could not match any prefix.
    let replica = Db::new(app.spec.schema.clone());
    micro::drift_seed(&replica);
    let mut prefix_hashes = vec![replica.table_hash("C_TAB")];
    for u in &r.global_log {
        replica.apply_update(u).unwrap();
        prefix_hashes.push(replica.table_hash("C_TAB"));
    }
    for (s, db) in dbs.iter().enumerate() {
        let h = db.as_ref().expect("real-execution db").table_hash("C_TAB");
        assert!(
            prefix_hashes.contains(&h),
            "server {s}: C_TAB state is not a prefix of the token history"
        );
    }
}

/// The real-threads deployment: drive the drift schedule through
/// [`Deployment::submit`] (virtual timestamps, wall-clock token
/// thread), require at least one installed epoch, and check the drained
/// replicas converge on the witness table.
#[test]
fn deployment_installs_epochs_and_converges() {
    let app = Arc::new(micro::drift_analyzed());
    let dep = Deployment::start(
        Arc::clone(&app),
        DeployConfig {
            n_servers: 3,
            adaptive: Some(AdaptiveConfig { window_rotations: 8, ..Default::default() }),
            ..Default::default()
        },
        micro::drift_seed,
    );
    assert_eq!(dep.epoch_version(), 0);
    let drift = DriftConfig::default();
    let mut gen = micro::DriftGen::new(drift);
    let mut rng = Rng::new(42);
    let submit_at = |gen: &mut micro::DriftGen, rng: &mut Rng, t_s: f64| {
        let op = gen.next_op_at(rng, 0, 3, VTime::from_millis_f64(t_s * 1000.0));
        dep.submit(op).expect("drift ops update existing keys");
    };
    // Pre-drift phase: the mix matches epoch 0's pin, so the controller
    // has no reason to move.
    for i in 0..1200 {
        submit_at(&mut gen, &mut rng, 9.0 * (i as f64) / 1200.0);
    }
    // Post-drift phase: keep offering the flipped mix until the token
    // thread's controller reacts (wall-clock bounded).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while dep.epoch_switches() == 0 && std::time::Instant::now() < deadline {
        for i in 0..400 {
            submit_at(&mut gen, &mut rng, 11.0 + (i as f64) / 400.0);
        }
    }
    assert!(dep.epoch_switches() >= 1, "deployment controller never switched");
    assert!(dep.epoch_version() >= 1);
    let token = dep.shutdown();
    assert_eq!(token.epoch, dep.epoch_version(), "token must carry the installed epoch");
    // After the shutdown drain every server has applied the full token
    // history: the witness table converges bit-identically even though
    // an epoch switched mid-run.
    let h0 = dep.db(0).table_hash("C_TAB");
    for s in 1..3 {
        assert_eq!(dep.db(s).table_hash("C_TAB"), h0, "server {s} diverged on C_TAB");
    }
}
