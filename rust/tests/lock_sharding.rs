//! Lock-shard addressing correctness (ROADMAP "lock-shard tuning" item's
//! safety half).
//!
//! Row locks are addressed by `(table, Key::lock_hash)` — a 64-bit hash,
//! not the key value — and the lock table is sharded by target hash. Two
//! *distinct* keys may therefore collide at either level. Collisions must
//! only ever be *coarsening*: they may add blocking, but must never
//!
//! 1. merge two Eq-equal keys into different targets (a txn could then
//!    hold "its own" row while another writes the same row), nor
//! 2. let two transactions both hold X on one target (false sharing of a
//!    *grant*), nor
//! 3. confuse lock identity with row identity (colliding lock targets
//!    still address distinct rows).
//!
//! The property test hammers the real engine with adversarial low-entropy
//! keys (the kind that stress cheap hashes) and checks the outcome is
//! conflict-serializable: no increment is ever lost, no row aliased.

use elia::catalog::{Schema, TableSchema, ValueType};
use elia::db::{BindSlots, Db, Key, LockManager, LockMode, Value};
use elia::db::lockmgr::LockTarget;
use elia::util::qcheck::{check_vec, Config};
use elia::util::Rng;

// ------------------------------------------------------- hash identity --

/// Keys that are Eq-equal must produce the same lock hash — otherwise a
/// single logical row could be locked under two different targets and
/// writers would stop excluding each other. The tricky cases are the
/// cross-type equalities `Value` defines (Int 3 == Float 3.0, 0.0 == -0.0).
#[test]
fn eq_keys_share_lock_hash() {
    let cases: Vec<(Key, Key)> = vec![
        (Key::single(Value::Int(3)), Key::single(Value::Float(3.0))),
        (Key::single(Value::Float(0.0)), Key::single(Value::Float(-0.0))),
        (Key::single(Value::Int(0)), Key::single(Value::Float(0.0))),
        (
            Key(vec![Value::Int(1), Value::Float(2.0)]),
            Key(vec![Value::Float(1.0), Value::Int(2)]),
        ),
        (Key::single(Value::Str(String::new())), Key::single(Value::Str(String::new()))),
    ];
    for (a, b) in cases {
        assert_eq!(a, b, "test precondition: keys must be Eq-equal");
        assert_eq!(a.lock_hash(), b.lock_hash(), "Eq keys with different lock hashes: {a} vs {b}");
    }
}

/// ...and keys that differ only in tuple arity must not collide by
/// accident of flattening (the length is hashed in).
#[test]
fn arity_is_part_of_the_hash() {
    let a = Key(vec![Value::Int(7)]);
    let b = Key(vec![Value::Int(7), Value::Int(7)]);
    assert_ne!(a, b);
    assert_ne!(a.lock_hash(), b.lock_hash());
}

// ----------------------------------------------------- shard semantics --

/// A single-shard lock table (maximum shard-collision pressure): locks on
/// distinct keys must still be granted independently — sharding protects
/// the lock *table*, it must not coarsen lock *granularity*.
#[test]
fn one_shard_does_not_falsely_share_distinct_keys() {
    let lm = LockManager::new(1);
    let k1 = LockTarget::row(0, &Key::single(Value::Int(1)));
    let k2 = LockTarget::row(0, &Key::single(Value::Int(2)));
    lm.acquire(1, k1, LockMode::X).unwrap();
    // Distinct key, same (only) shard: must be granted, not blocked.
    lm.acquire(2, k2, LockMode::X).unwrap();
    // Real conflict on k1 is still a conflict (younger txn dies).
    assert!(lm.acquire(3, k1, LockMode::X).is_err());
    lm.release_all(1);
    lm.release_all(2);
    assert_eq!(lm.entry_count(), 0);
}

/// A *target*-level collision (two logical keys mapping to one
/// `LockTarget::Row`) may only add blocking: the second writer conflicts;
/// it is never co-granted X on the merged target.
#[test]
fn colliding_targets_only_add_blocking() {
    let lm = LockManager::default();
    // Simulate a 64-bit hash collision by addressing the same target
    // from two "different keys" (indistinguishable to the manager).
    let shared = LockTarget::Row(0, 0xDEADBEEF);
    lm.acquire(1, shared, LockMode::X).unwrap();
    let err = lm.acquire(2, shared, LockMode::X).unwrap_err();
    assert!(matches!(err, elia::db::lockmgr::LockError::Aborted { txn: 2, .. }));
    lm.release_all(1);
}

// ------------------------------------------------- shard addressing --

/// ROADMAP lock-shard-tuning (perf half): shard choice now derives from
/// the *stored* `Key::lock_hash` with an FNV-style mix, instead of
/// re-running SipHash over the whole target per acquire/release. The
/// semantics that must survive the swap: Eq-equal keys (which share a
/// lock hash, see above) land on the same shard — a txn's acquire and
/// release for one logical row always talk to one mutex.
#[test]
fn eq_keys_share_lock_shard() {
    let lm = LockManager::default();
    let cases: Vec<(Key, Key)> = vec![
        (Key::single(Value::Int(3)), Key::single(Value::Float(3.0))),
        (Key::single(Value::Float(0.0)), Key::single(Value::Float(-0.0))),
        (
            Key(vec![Value::Int(1), Value::Float(2.0)]),
            Key(vec![Value::Float(1.0), Value::Int(2)]),
        ),
    ];
    for (a, b) in cases {
        let (ta, tb) = (LockTarget::row(4, &a), LockTarget::row(4, &b));
        assert_eq!(ta, tb, "Eq keys must address one target: {a} vs {b}");
        assert_eq!(lm.shard_index(&ta), lm.shard_index(&tb));
    }
}

/// The derived addressing must still *spread*: sequential row keys fill
/// every shard roughly evenly, and the table id contributes (the same
/// key hash in different tables is not pinned to one shard).
#[test]
fn shard_addressing_spreads_targets() {
    let lm = LockManager::default();
    let n = lm.shard_count();
    assert_eq!(n, 32, "default shard count assumed by the distribution bounds");
    let mut counts = vec![0usize; n];
    let total = 10_000;
    for k in 0..total as i64 {
        let t = LockTarget::row(0, &Key::single(Value::Int(k)));
        counts[lm.shard_index(&t)] += 1;
    }
    let avg = total / n;
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            c > avg / 4 && c < avg * 4,
            "shard {i} holds {c} of {total} targets (avg {avg}) — degenerate spread"
        );
    }
    // Same row hash across table ids must not collapse onto few shards.
    let spread: std::collections::HashSet<usize> = (0..64)
        .map(|t| lm.shard_index(&LockTarget::row(t, &Key::single(Value::Int(1)))))
        .collect();
    assert!(spread.len() > 8, "table id must contribute to the shard: {}", spread.len());
    // Table-level intent locks distribute too.
    let tables: std::collections::HashSet<usize> =
        (0..64).map(|t| lm.shard_index(&LockTarget::Table(t))).collect();
    assert!(tables.len() > 8, "table targets collapse: {}", tables.len());
}

// ------------------------------------------------ end-to-end property --

fn kv_db() -> Db {
    let schema = Schema::new(vec![TableSchema::new(
        "KV",
        &[("K", ValueType::Str), ("V", ValueType::Int)],
        &["K"],
    )]);
    Db::new(schema)
}

/// Adversarial low-entropy key pool: empty-ish strings, shared prefixes,
/// numeric look-alikes — everything a weak hash would pile into a few
/// buckets (and `DefaultHasher` into a few of the 32 shards).
fn key_pool() -> Vec<String> {
    vec![
        String::new(),
        "0".into(),
        "00".into(),
        "1".into(),
        "a".into(),
        "aa".into(),
        "aaa".into(),
        "\u{0}".into(),
    ]
}

/// Conflict-serializability witness under collisions: concurrent
/// auto-committed increments on adversarial keys never lose an update —
/// each key's final value equals the number of successful increments on
/// exactly that key — and rows are never aliased across distinct keys.
#[test]
fn adversarial_keys_keep_conflict_serializable_outcomes() {
    // Keep thread spawns bounded: few qcheck cases, each a real
    // multi-threaded run against the engine.
    let cases = Config::default().cases(5).name("lock-shard-conflict-semantics");
    let pool = key_pool();
    let pool_len = pool.len();
    check_vec(
        cases,
        move |rng: &mut Rng| rng.range(0, pool_len),
        64,
        |schedule: &[usize]| {
            let pool = key_pool();
            let db = kv_db();
            let ins = db.prepare_sql("INSERT INTO KV (K, V) VALUES (?k, 0)").unwrap();
            for k in &pool {
                db.exec_auto_prepared(&ins, &BindSlots(vec![Value::Str(k.clone())])).unwrap();
            }
            // No aliasing at seed time: every distinct key is its own row.
            assert_eq!(db.row_count("KV"), pool.len());

            let upd = db.prepare_sql("UPDATE KV SET V = V + 1 WHERE K = ?k").unwrap();
            let n_threads = 4;
            let mut success = vec![0u64; pool.len()];
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for t in 0..n_threads {
                    let db = &db;
                    let upd = &upd;
                    let pool = &pool;
                    let shard: Vec<usize> = schedule
                        .iter()
                        .copied()
                        .skip(t)
                        .step_by(n_threads)
                        .collect();
                    handles.push(scope.spawn(move || {
                        let mut ok = vec![0u64; pool.len()];
                        for key_idx in shard {
                            let slots = BindSlots(vec![Value::Str(pool[key_idx].clone())]);
                            let mut attempts = 0;
                            loop {
                                match db.exec_auto_prepared(upd, &slots) {
                                    Ok(r) => {
                                        assert_eq!(r.affected, 1, "exactly one row updated");
                                        ok[key_idx] += 1;
                                        break;
                                    }
                                    Err(_) => {
                                        attempts += 1;
                                        assert!(attempts < 100_000, "livelock on {key_idx}");
                                        std::thread::yield_now();
                                    }
                                }
                            }
                        }
                        ok
                    }));
                }
                for h in handles {
                    let ok = h.join().unwrap();
                    for (i, n) in ok.into_iter().enumerate() {
                        success[i] += n;
                    }
                }
            });

            // Every increment that committed is visible: per-key counter
            // equals the per-key success count (no lost updates through
            // colliding lock targets/shards), and no rows were aliased.
            assert_eq!(db.row_count("KV"), pool.len());
            for (i, k) in pool.iter().enumerate() {
                let row = db
                    .peek("KV", &Key::single(Value::Str(k.clone())))
                    .unwrap_or_else(|| panic!("row for key {i} vanished"));
                assert_eq!(
                    row[1],
                    Value::Int(success[i] as i64),
                    "lost/phantom update on key {i:?}",
                );
            }
            true
        },
    );
}
