//! Pluggable byte transports under the wire protocol.
//!
//! The server and client stacks are written against three object-safe
//! traits — [`Transport`] (dial/listen), [`Listener`] (accept), and
//! [`Conn`] (framed send/recv) — with two families of implementations:
//!
//! * [`Tcp`] and [`Uds`] carry frames over real sockets
//!   (`elia serve` / `elia client`);
//! * [`Loopback`] is a deterministic in-memory transport for tests: each
//!   connection is a pair of mutex+condvar pipes carrying *fully framed*
//!   byte vectors, so the frame codec is exercised end-to-end without a
//!   kernel in the loop. [`Loopback::cut`] severs live connections and
//!   drops their in-flight frames — the fault-injection tests use it to
//!   exercise the belt's retransmit path.
//!
//! `Conn::send`/`recv` speak *payloads*: framing happens inside the
//! transport (buffer [`frame`]/[`deframe`] for loopback, streaming
//! [`write_frame`]/[`read_frame`] for sockets), so every byte crosses
//! the same codec regardless of carrier.

use super::proto::{deframe, frame, read_frame, write_frame, ProtoError};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A way to dial and listen. Implementations are cheap to clone/share
/// (`Arc<dyn Transport>` throughout the stack).
pub trait Transport: Send + Sync {
    /// Bind a listener at `addr`. For TCP, `addr` may use port `0`; the
    /// resolved address is available from [`Listener::addr`].
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, ProtoError>;
    /// Open a connection to a listener.
    fn connect(&self, addr: &str) -> Result<Box<dyn Conn>, ProtoError>;
    /// Human-readable transport name (diagnostics).
    fn name(&self) -> &'static str;
}

/// An accepting endpoint.
pub trait Listener: Send {
    /// Block until the next inbound connection.
    fn accept(&mut self) -> Result<Box<dyn Conn>, ProtoError>;
    /// The resolved listen address (differs from the bind address when
    /// an ephemeral port was requested).
    fn addr(&self) -> &str;
}

/// One bidirectional, framed connection.
pub trait Conn: Send {
    /// Send one message payload (the transport frames it).
    fn send(&mut self, payload: &[u8]) -> Result<(), ProtoError>;
    /// Receive one message payload (blocking, subject to the receive
    /// deadline).
    fn recv(&mut self) -> Result<Vec<u8>, ProtoError>;
    /// Set or clear the receive deadline; `recv` returns
    /// [`ProtoError::Timeout`] when it elapses.
    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<(), ProtoError>;
    /// The peer's address (diagnostics).
    fn peer(&self) -> &str;
}

// ---------------------------------------------------------------------
// Loopback: deterministic in-memory transport.
// ---------------------------------------------------------------------

/// One direction of a loopback connection: a bounded-by-usage queue of
/// framed byte vectors. Closing clears queued frames — like a cut wire,
/// bytes in flight are lost, which is exactly what the belt's retransmit
/// logic must survive.
struct Pipe {
    st: Mutex<PipeState>,
    cv: Condvar,
}

#[derive(Default)]
struct PipeState {
    q: VecDeque<Vec<u8>>,
    closed: bool,
}

impl Pipe {
    fn new() -> Arc<Pipe> {
        Arc::new(Pipe { st: Mutex::new(PipeState::default()), cv: Condvar::new() })
    }

    fn push(&self, frame: Vec<u8>) -> Result<(), ProtoError> {
        let mut st = self.st.lock().unwrap();
        if st.closed {
            return Err(ProtoError::Closed);
        }
        st.q.push_back(frame);
        self.cv.notify_all();
        Ok(())
    }

    fn pop(&self, timeout: Option<Duration>) -> Result<Vec<u8>, ProtoError> {
        let mut st = self.st.lock().unwrap();
        loop {
            if let Some(f) = st.q.pop_front() {
                return Ok(f);
            }
            if st.closed {
                return Err(ProtoError::Closed);
            }
            match timeout {
                Some(t) => {
                    let (next, res) = self.cv.wait_timeout(st, t).unwrap();
                    st = next;
                    if res.timed_out() && st.q.is_empty() {
                        if st.closed {
                            return Err(ProtoError::Closed);
                        }
                        return Err(ProtoError::Timeout);
                    }
                }
                None => st = self.cv.wait(st).unwrap(),
            }
        }
    }

    fn close(&self) {
        let mut st = self.st.lock().unwrap();
        st.closed = true;
        // A cut wire loses bytes in flight.
        st.q.clear();
        self.cv.notify_all();
    }
}

/// A live loopback link, remembered for [`Loopback::cut`].
struct Link {
    /// The listener address this link was accepted at.
    addr: String,
    a: Arc<Pipe>,
    b: Arc<Pipe>,
}

#[derive(Default)]
struct LoopInner {
    listeners: Mutex<HashMap<String, Arc<AcceptQ>>>,
    links: Mutex<Vec<Link>>,
}

/// Pending server-side connection ends awaiting `accept`.
#[derive(Default)]
struct AcceptQ {
    q: Mutex<VecDeque<LoopConn>>,
    cv: Condvar,
}

/// The in-memory transport. Clones share the same address space; use one
/// instance per test cluster.
#[derive(Clone, Default)]
pub struct Loopback {
    inner: Arc<LoopInner>,
}

impl Loopback {
    /// A fresh, empty address space.
    pub fn new() -> Loopback {
        Loopback::default()
    }

    /// Sever every connection that was accepted at `addr`, dropping any
    /// frames in flight (both directions). Endpoints see
    /// [`ProtoError::Closed`] on their next operation and may reconnect —
    /// the listener itself stays up.
    pub fn cut(&self, addr: &str) -> usize {
        let mut links = self.inner.links.lock().unwrap();
        let mut n = 0;
        links.retain(|l| {
            if l.addr == addr {
                l.a.close();
                l.b.close();
                n += 1;
                false
            } else {
                true
            }
        });
        n
    }
}

impl Transport for Loopback {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, ProtoError> {
        let mut listeners = self.inner.listeners.lock().unwrap();
        if listeners.contains_key(addr) {
            return Err(ProtoError::Io(format!("loopback address {addr} already bound")));
        }
        let q = Arc::new(AcceptQ::default());
        listeners.insert(addr.to_string(), Arc::clone(&q));
        Ok(Box::new(LoopListener { addr: addr.to_string(), q }))
    }

    fn connect(&self, addr: &str) -> Result<Box<dyn Conn>, ProtoError> {
        let q = self
            .inner
            .listeners
            .lock()
            .unwrap()
            .get(addr)
            .cloned()
            .ok_or_else(|| ProtoError::Io(format!("loopback connection refused: {addr}")))?;
        // Two pipes: a carries client→server frames, b server→client.
        let a = Pipe::new();
        let b = Pipe::new();
        let client = LoopConn {
            out: Arc::clone(&a),
            inn: Arc::clone(&b),
            timeout: None,
            peer: addr.to_string(),
        };
        let server = LoopConn {
            out: Arc::clone(&b),
            inn: Arc::clone(&a),
            timeout: None,
            peer: format!("{addr}#peer"),
        };
        self.inner.links.lock().unwrap().push(Link {
            addr: addr.to_string(),
            a,
            b,
        });
        let mut pending = q.q.lock().unwrap();
        pending.push_back(server);
        q.cv.notify_all();
        drop(pending);
        Ok(Box::new(client))
    }

    fn name(&self) -> &'static str {
        "loopback"
    }
}

struct LoopListener {
    addr: String,
    q: Arc<AcceptQ>,
}

impl Listener for LoopListener {
    fn accept(&mut self) -> Result<Box<dyn Conn>, ProtoError> {
        let mut pending = self.q.q.lock().unwrap();
        loop {
            if let Some(conn) = pending.pop_front() {
                return Ok(Box::new(conn));
            }
            pending = self.q.cv.wait(pending).unwrap();
        }
    }

    fn addr(&self) -> &str {
        &self.addr
    }
}

struct LoopConn {
    out: Arc<Pipe>,
    inn: Arc<Pipe>,
    timeout: Option<Duration>,
    peer: String,
}

impl Conn for LoopConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), ProtoError> {
        // Full frames round-trip through the pipes so the codec is
        // exercised even without a socket.
        self.out.push(frame(payload))
    }

    fn recv(&mut self) -> Result<Vec<u8>, ProtoError> {
        let framed = self.inn.pop(self.timeout)?;
        let (payload, consumed) = deframe(&framed)?;
        if consumed != framed.len() {
            return Err(ProtoError::Decode(format!(
                "{} trailing bytes after frame",
                framed.len() - consumed
            )));
        }
        Ok(payload.to_vec())
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<(), ProtoError> {
        self.timeout = t;
        Ok(())
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

impl Drop for LoopConn {
    fn drop(&mut self) {
        // Like a socket close: both directions go down, and the peer's
        // next recv sees Closed.
        self.out.close();
        self.inn.close();
    }
}

// ---------------------------------------------------------------------
// TCP.
// ---------------------------------------------------------------------

/// Real TCP sockets (`elia serve` / `elia client`, and the CI smoke test
/// over 127.0.0.1). Supports port `0` binds: the resolved ephemeral
/// address comes back from [`Listener::addr`].
#[derive(Clone, Copy, Default)]
pub struct Tcp;

impl Transport for Tcp {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, ProtoError> {
        let listener = std::net::TcpListener::bind(addr)?;
        let resolved = listener.local_addr()?.to_string();
        Ok(Box::new(TcpListenerWrap { listener, addr: resolved }))
    }

    fn connect(&self, addr: &str) -> Result<Box<dyn Conn>, ProtoError> {
        let stream = std::net::TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Box::new(TcpConn { stream, peer: addr.to_string() }))
    }

    fn name(&self) -> &'static str {
        "tcp"
    }
}

struct TcpListenerWrap {
    listener: std::net::TcpListener,
    addr: String,
}

impl Listener for TcpListenerWrap {
    fn accept(&mut self) -> Result<Box<dyn Conn>, ProtoError> {
        let (stream, peer) = self.listener.accept()?;
        stream.set_nodelay(true)?;
        Ok(Box::new(TcpConn { stream, peer: peer.to_string() }))
    }

    fn addr(&self) -> &str {
        &self.addr
    }
}

struct TcpConn {
    stream: std::net::TcpStream,
    peer: String,
}

impl Conn for TcpConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), ProtoError> {
        write_frame(&mut self.stream, payload)
    }

    fn recv(&mut self) -> Result<Vec<u8>, ProtoError> {
        read_frame(&mut self.stream)
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<(), ProtoError> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

// ---------------------------------------------------------------------
// Unix domain sockets.
// ---------------------------------------------------------------------

/// Unix domain sockets — same wire format as [`Tcp`], for single-host
/// deployments where the address is a filesystem path.
#[cfg(unix)]
#[derive(Clone, Copy, Default)]
pub struct Uds;

#[cfg(unix)]
impl Transport for Uds {
    fn listen(&self, addr: &str) -> Result<Box<dyn Listener>, ProtoError> {
        // Re-binding a path left behind by a previous run fails with
        // AddrInUse; remove the stale socket file first.
        let _ = std::fs::remove_file(addr);
        let listener = std::os::unix::net::UnixListener::bind(addr)?;
        Ok(Box::new(UdsListenerWrap { listener, addr: addr.to_string() }))
    }

    fn connect(&self, addr: &str) -> Result<Box<dyn Conn>, ProtoError> {
        let stream = std::os::unix::net::UnixStream::connect(addr)?;
        Ok(Box::new(UdsConn { stream, peer: addr.to_string() }))
    }

    fn name(&self) -> &'static str {
        "uds"
    }
}

#[cfg(unix)]
struct UdsListenerWrap {
    listener: std::os::unix::net::UnixListener,
    addr: String,
}

#[cfg(unix)]
impl Listener for UdsListenerWrap {
    fn accept(&mut self) -> Result<Box<dyn Conn>, ProtoError> {
        let (stream, _) = self.listener.accept()?;
        Ok(Box::new(UdsConn { stream, peer: self.addr.clone() }))
    }

    fn addr(&self) -> &str {
        &self.addr
    }
}

#[cfg(unix)]
struct UdsConn {
    stream: std::os::unix::net::UnixStream,
    peer: String,
}

#[cfg(unix)]
impl Conn for UdsConn {
    fn send(&mut self, payload: &[u8]) -> Result<(), ProtoError> {
        write_frame(&mut self.stream, payload)
    }

    fn recv(&mut self) -> Result<Vec<u8>, ProtoError> {
        read_frame(&mut self.stream)
    }

    fn set_recv_timeout(&mut self, t: Option<Duration>) -> Result<(), ProtoError> {
        self.stream.set_read_timeout(t)?;
        Ok(())
    }

    fn peer(&self) -> &str {
        &self.peer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrip_and_close() {
        let lo = Loopback::new();
        let mut listener = lo.listen("a").unwrap();
        let mut client = lo.connect("a").unwrap();
        client.send(b"ping").unwrap();
        let mut server = listener.accept().unwrap();
        assert_eq!(server.recv().unwrap(), b"ping");
        server.send(b"pong").unwrap();
        assert_eq!(client.recv().unwrap(), b"pong");
        drop(server);
        assert_eq!(client.recv(), Err(ProtoError::Closed));
    }

    #[test]
    fn loopback_cut_drops_in_flight_frames() {
        let lo = Loopback::new();
        let _listener = lo.listen("ring0").unwrap();
        let mut client = lo.connect("ring0").unwrap();
        client.send(b"in-flight").unwrap();
        assert_eq!(lo.cut("ring0"), 1);
        assert_eq!(client.recv(), Err(ProtoError::Closed));
        assert_eq!(client.send(b"more"), Err(ProtoError::Closed));
        // The listener survives; new connections work.
        let mut c2 = lo.connect("ring0").unwrap();
        c2.send(b"fresh").unwrap();
    }

    #[test]
    fn loopback_recv_timeout() {
        let lo = Loopback::new();
        let _listener = lo.listen("t").unwrap();
        let mut client = lo.connect("t").unwrap();
        client.set_recv_timeout(Some(Duration::from_millis(20))).unwrap();
        assert_eq!(client.recv(), Err(ProtoError::Timeout));
    }

    #[test]
    fn connect_to_unbound_address_is_refused() {
        let lo = Loopback::new();
        assert!(matches!(lo.connect("nowhere"), Err(ProtoError::Io(_))));
    }

    #[test]
    fn tcp_roundtrip_on_ephemeral_port() {
        let mut listener = Tcp.listen("127.0.0.1:0").unwrap();
        let addr = listener.addr().to_string();
        let handle = std::thread::spawn(move || {
            let mut conn = listener.accept().unwrap();
            let got = conn.recv().unwrap();
            conn.send(&got).unwrap();
        });
        let mut client = Tcp.connect(&addr).unwrap();
        client.send(b"echo me").unwrap();
        assert_eq!(client.recv().unwrap(), b"echo me");
        handle.join().unwrap();
    }
}
