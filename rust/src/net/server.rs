//! The served deployment: one [`ServerCore`] per server, clients over
//! framed request/reply connections, and the conveyor belt token
//! travelling the ring as a real [`Msg::TokenPass`] frame.
//!
//! Per server there are three kinds of thread, exactly the networked
//! split of [`Deployment`](crate::conveyor::Deployment):
//!
//! * an **accept thread** takes client connections and spawns a handler
//!   per connection;
//! * **handler threads** decode [`Msg::Request`]s, route them
//!   ([`Route`]) and drive the shared [`ServerCore`] — local and
//!   confluent operations execute immediately, globals park until the
//!   belt thread's next stop;
//! * a **belt thread** owns the ring: it receives the token from its
//!   predecessor, runs [`ServerCore::token_stop`] (apply remotes, drain
//!   the confluent outbox, run the parked round), and forwards the token
//!   to its successor.
//!
//! ## Exactly-once token custody
//!
//! The ring must survive cut connections without duplicating or losing
//! a token (and with it, committed [`StateUpdate`](crate::db::StateUpdate)s).
//! The envelope carries a monotone `hop` counter:
//!
//! * the **receiver acks immediately on receipt** — before processing —
//!   so custody transfers as soon as the frame lands;
//! * the **sender holds its copy until acked**; on timeout or a broken
//!   connection it reconnects and resends the *same* frame;
//! * the receiver **dedupes** by hop (`hop <= last_hop` is a stale
//!   retransmit: ack again, process nothing).
//!
//! A cut before receipt loses the frame → no ack → the sender's copy is
//! retransmitted; a cut after receipt loses only the ack → the
//! retransmit is deduped. Either way each hop is processed exactly once,
//! and the token's per-server watermarks make update application
//! idempotent on top of that.
//!
//! ## Shutdown
//!
//! [`Cluster::shutdown`] sets a stop flag; the belt keeps rotating until
//! some server observes a fully drained system (empty token and a full
//! ring of no-work stops). That server records the final token, raises
//! the `halted` flag, and simply exits — its dropped connections cascade
//! a clean close around the ring, so no in-band halt message (which
//! would itself need acking) exists.

use super::client::NetClient;
use super::proto::{decode_msg, encode_msg, Msg, ProtoError, Role, WireError};
use super::transport::{Conn, Listener, Transport};
use crate::analysis::drift::{
    assignment_from_wire, assignment_to_wire, AdaptiveConfig, EpochController,
};
use crate::conveyor::token::{Token, TokenEntry};
use crate::conveyor::ServerCore;
use crate::db::{Db, DurabilityConfig, Retryable, TxnError, Value};
use crate::workload::analyzed::{AnalyzedApp, Route, RoutingEpoch};
use crate::workload::spec::{Operation, PreparedStmts};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

/// Configuration of a served cluster.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Per-server client-facing listen addresses.
    pub client_addrs: Vec<String>,
    /// Per-server ring listen addresses (server `p` listens here for its
    /// predecessor; `p`'s successor is `(p + 1) % n`).
    pub ring_addrs: Vec<String>,
    /// Max wait-die retries per operation (as [`DeployConfig`](crate::conveyor::DeployConfig)).
    pub max_retries: u32,
    /// Pause when the ring has been idle for over two full rotations.
    pub idle_pause: Duration,
    /// Token-ack deadline; an unacked pass is retransmitted after this.
    pub ack_timeout: Duration,
    /// When set, each server runs a write-ahead log at
    /// `<dir>/server<p>.wal` (and replays it at start).
    pub wal_dir: Option<PathBuf>,
    /// Record every token entry the belt threads observe (the
    /// fault-injection tests' no-dup/no-loss oracle; off by default).
    pub record_history: bool,
    /// Live routing epochs (`analysis::drift`): handlers count
    /// per-template arrivals, the belt flushes the counts onto the
    /// token, and the controller at server 0 installs a better
    /// [`RoutingEpoch`] over the token when the observed mix drifts.
    /// Misroutes from clients on an older epoch come back as retryable
    /// [`WireError`]s carrying the installed version, so
    /// [`NetClient`] re-handshakes and re-routes. `None` (default) =
    /// static routing.
    pub adaptive: Option<AdaptiveConfig>,
}

impl ServeConfig {
    fn base(client_addrs: Vec<String>, ring_addrs: Vec<String>) -> ServeConfig {
        ServeConfig {
            client_addrs,
            ring_addrs,
            max_retries: 1000,
            idle_pause: Duration::from_micros(200),
            ack_timeout: Duration::from_millis(50),
            wal_dir: None,
            record_history: false,
            adaptive: None,
        }
    }

    /// An `n`-server cluster on the in-memory [`Loopback`]
    /// (`crate::net::Loopback`) transport: `server<p>` / `ring<p>`
    /// addresses.
    pub fn loopback(n: usize) -> ServeConfig {
        ServeConfig::base(
            (0..n).map(|p| format!("server{p}")).collect(),
            (0..n).map(|p| format!("ring{p}")).collect(),
        )
    }

    /// An `n`-server cluster on 127.0.0.1. `base_port == 0` requests
    /// ephemeral ports — the resolved addresses come back from
    /// [`Cluster::client_addrs`], so tests never collide.
    pub fn tcp(n: usize, base_port: u16) -> ServeConfig {
        let port = |i: usize| if base_port == 0 { 0 } else { base_port + i as u16 };
        ServeConfig::base(
            (0..n).map(|p| format!("127.0.0.1:{}", port(2 * p))).collect(),
            (0..n).map(|p| format!("127.0.0.1:{}", port(2 * p + 1))).collect(),
        )
    }

    /// Number of servers this configuration describes.
    pub fn n_servers(&self) -> usize {
        self.client_addrs.len()
    }
}

/// Cross-thread flags and results of one cluster.
struct Shared {
    stop: AtomicBool,
    /// Raised by the server that drained the system; every other belt
    /// thread exits when it observes this after a connection error.
    halted: AtomicBool,
    done: Mutex<Option<Token>>,
    done_cv: Condvar,
    /// Token entries observed by the belt, for the no-dup/no-loss
    /// oracle (only filled when [`ServeConfig::record_history`]).
    history: Mutex<Vec<TokenEntry>>,
}

/// One served Eliá server: the shared [`ServerCore`] plus routing state
/// and per-class counters (mirrors [`Deployment`](crate::conveyor::Deployment)'s).
pub struct NetNode {
    index: usize,
    n: usize,
    app: Arc<AnalyzedApp>,
    stmt_maps: Arc<Vec<PreparedStmts>>,
    core: Arc<ServerCore>,
    /// Local + commutative operations handled here.
    pub ops_local: AtomicU64,
    /// Global operations parked and run here.
    pub ops_global: AtomicU64,
    /// Confluent operations executed here.
    pub ops_confluent: AtomicU64,
    /// The installed routing epoch (`Some` iff [`ServeConfig::adaptive`]).
    /// Handlers route under this; the belt thread swaps in newer epochs
    /// carried by the token.
    epoch: RwLock<Option<Arc<RoutingEpoch>>>,
    /// Per-template operation counts since the belt last flushed them
    /// onto the token (empty when adaptivity is off).
    obs: Vec<AtomicU64>,
    /// Epoch installations this server's controller initiated.
    epoch_switches: AtomicU64,
}

impl NetNode {
    /// This server's DBMS.
    pub fn db(&self) -> &Db {
        self.core.db()
    }

    /// Wait-die retries burned by this server's handler threads.
    pub fn retries(&self) -> u64 {
        self.core.retries.load(Ordering::Relaxed)
    }

    /// The installed routing epoch's version (0 when adaptivity is off
    /// or no switch has happened yet).
    pub fn epoch_version(&self) -> u64 {
        self.epoch.read().unwrap().as_ref().map(|e| e.version).unwrap_or(0)
    }

    /// Epoch installations initiated by this server's controller
    /// (non-zero only at server 0).
    pub fn epoch_switches(&self) -> u64 {
        self.epoch_switches.load(Ordering::Relaxed)
    }

    /// `(version, wire assignment)` of the installed epoch, for the
    /// client handshake.
    fn epoch_wire(&self) -> (u64, Vec<i64>) {
        match self.epoch.read().unwrap().as_ref() {
            Some(e) => (e.version, assignment_to_wire(&e.assignment)),
            None => (0, Vec::new()),
        }
    }

    /// Execute one decoded request: resolve the template, route, run.
    /// `client_epoch` is the routing-epoch version the client issued
    /// under (0 without adaptivity). Misrouted operations are rejected
    /// rather than silently executed on the wrong server; under
    /// adaptivity the rejection is *retryable* when the client's epoch is
    /// simply stale — it carries the installed version so the stub
    /// re-handshakes and re-routes — and fatal only when client and
    /// server disagree within the same epoch (a buggy or malicious
    /// client: the routing function is deterministic).
    pub fn handle_request(&self, txn: &str, args: Vec<(String, Value)>, client_epoch: u64) -> Msg {
        let Some(ti) = self.app.spec.txn_index(txn) else {
            return Msg::ReplyErr(WireError::plain(
                false,
                format!("unknown transaction '{txn}'"),
            ));
        };
        let op = Operation { txn: ti, args: args.into_iter().collect() };
        let tpl = &self.app.spec.txns[ti];
        let stmts = &self.stmt_maps[ti];
        let installed = self.epoch.read().unwrap().clone();
        let misroute = |s: usize| match &installed {
            Some(e) if client_epoch != e.version => {
                let err = TxnError::StaleEpoch { installed: e.version };
                Msg::ReplyErr(WireError {
                    retryable: err.classify() == Retryable::Transient,
                    message: format!("{err}: '{txn}' belongs to server {s}"),
                    epoch: Some(e.version),
                })
            }
            _ => Msg::ReplyErr(WireError::plain(
                false,
                format!(
                    "misrouted: '{txn}' belongs to server {s}, this is server {}",
                    self.index
                ),
            )),
        };
        let route = match &installed {
            Some(e) => e.route_op(&self.app, &op, self.n),
            None => self.app.route(&op, self.n),
        };
        let executing = match route {
            Route::Any => true,
            Route::LocalAt(s) | Route::GlobalAt(s) | Route::ConfluentAt(s) => s == self.index,
        };
        if executing && !self.obs.is_empty() {
            self.obs[ti].fetch_add(1, Ordering::Relaxed);
        }
        let result = match route {
            Route::Any => {
                self.ops_local.fetch_add(1, Ordering::Relaxed);
                self.core.execute_local(tpl, stmts, &op)
            }
            Route::LocalAt(s) => {
                if s != self.index {
                    return misroute(s);
                }
                self.ops_local.fetch_add(1, Ordering::Relaxed);
                self.core.execute_local(tpl, stmts, &op)
            }
            Route::GlobalAt(s) => {
                if s != self.index {
                    return misroute(s);
                }
                self.ops_global.fetch_add(1, Ordering::Relaxed);
                self.core.execute_global(tpl, stmts, op)
            }
            Route::ConfluentAt(s) => {
                if s != self.index {
                    return misroute(s);
                }
                self.ops_confluent.fetch_add(1, Ordering::Relaxed);
                self.core.execute_confluent(tpl, stmts, &op)
            }
        };
        match result {
            Ok(reply) => Msg::ReplyOk(reply),
            Err(e) => Msg::ReplyErr(WireError::plain(
                e.classify() == Retryable::Transient,
                e.to_string(),
            )),
        }
    }
}

/// A running served cluster (all servers in this process, one thread
/// set per server). Real deployments run one [`Cluster`] of size 1 per
/// machine via `elia serve`; tests run size-`n` clusters over
/// [`Loopback`](crate::net::Loopback) or 127.0.0.1 TCP.
pub struct Cluster {
    transport: Arc<dyn Transport>,
    nodes: Vec<Arc<NetNode>>,
    shared: Arc<Shared>,
    client_addrs: Vec<String>,
    ring_addrs: Vec<String>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Cluster {
    /// Start a cluster: bind every listener (client and ring) up front —
    /// so ring connects cannot race the ring accepts — then spawn each
    /// server's accept and belt threads. `seed_db` runs against every
    /// server's fresh DB before its WAL (if any) replays.
    pub fn start(
        app: Arc<AnalyzedApp>,
        cfg: ServeConfig,
        transport: Arc<dyn Transport>,
        seed_db: impl Fn(&Db),
    ) -> Result<Cluster, ProtoError> {
        let n = cfg.n_servers();
        assert!(n >= 1, "cluster needs at least one server");
        assert_eq!(cfg.ring_addrs.len(), n, "one ring address per server");

        // Bind everything before any thread runs: a connect() to any
        // ring/client address is guaranteed to land in a live backlog.
        let mut client_listeners = Vec::with_capacity(n);
        let mut ring_listeners = Vec::with_capacity(n);
        for addr in &cfg.client_addrs {
            client_listeners.push(transport.listen(addr)?);
        }
        if n >= 2 {
            for addr in &cfg.ring_addrs {
                ring_listeners.push(transport.listen(addr)?);
            }
        }
        let client_addrs: Vec<String> =
            client_listeners.iter().map(|l| l.addr().to_string()).collect();
        let ring_addrs: Vec<String> = if n >= 2 {
            ring_listeners.iter().map(|l| l.addr().to_string()).collect()
        } else {
            cfg.ring_addrs.clone()
        };

        let stmt_maps: Arc<Vec<PreparedStmts>> =
            Arc::new(app.spec.txns.iter().map(|t| t.prepared_map(&app.spec.schema)).collect());
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            halted: AtomicBool::new(false),
            done: Mutex::new(None),
            done_cv: Condvar::new(),
            history: Mutex::new(Vec::new()),
        });

        // Epoch 0 is computed once and installed everywhere at boot;
        // later epochs install via the token.
        let epoch0 = cfg.adaptive.as_ref().map(|_| Arc::new(app.epoch0()));
        let n_templates = app.spec.txns.len();
        let mut nodes = Vec::with_capacity(n);
        for p in 0..n {
            let db = Db::new(app.spec.schema.clone());
            seed_db(&db);
            let db = match &cfg.wal_dir {
                Some(dir) => db
                    .with_durability(&DurabilityConfig::new(dir.join(format!("server{p}.wal"))))
                    .map_err(|e| ProtoError::Io(e.to_string()))?,
                None => db,
            };
            nodes.push(Arc::new(NetNode {
                index: p,
                n,
                app: Arc::clone(&app),
                stmt_maps: Arc::clone(&stmt_maps),
                core: Arc::new(ServerCore::new(db, cfg.max_retries)),
                ops_local: AtomicU64::new(0),
                ops_global: AtomicU64::new(0),
                ops_confluent: AtomicU64::new(0),
                epoch: RwLock::new(epoch0.clone()),
                obs: if cfg.adaptive.is_some() {
                    (0..n_templates).map(|_| AtomicU64::new(0)).collect()
                } else {
                    Vec::new()
                },
                epoch_switches: AtomicU64::new(0),
            }));
        }

        let mut threads = Vec::new();
        for (p, listener) in client_listeners.into_iter().enumerate() {
            let node = Arc::clone(&nodes[p]);
            let shared2 = Arc::clone(&shared);
            let app_name = app.spec.name.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("elia-accept-{p}"))
                    .spawn(move || accept_loop(node, shared2, listener, app_name))
                    .expect("spawn accept thread"),
            );
        }
        if n == 1 {
            let belt = Belt {
                node: Arc::clone(&nodes[0]),
                shared: Arc::clone(&shared),
                transport: Arc::clone(&transport),
                succ_addr: String::new(),
                app_name: app.spec.name.clone(),
                n,
                ack_timeout: cfg.ack_timeout,
                idle_pause: cfg.idle_pause,
                record_history: cfg.record_history,
                adaptive: cfg.adaptive.clone(),
                controller: cfg
                    .adaptive
                    .as_ref()
                    .map(|ac| EpochController::new(&app, ac.clone())),
            };
            threads.push(
                std::thread::Builder::new()
                    .name("elia-belt-0".into())
                    .spawn(move || belt.run_single())
                    .expect("spawn belt thread"),
            );
        } else {
            for (p, listener) in ring_listeners.into_iter().enumerate() {
                let belt = Belt {
                    node: Arc::clone(&nodes[p]),
                    shared: Arc::clone(&shared),
                    transport: Arc::clone(&transport),
                    succ_addr: ring_addrs[(p + 1) % n].clone(),
                    app_name: app.spec.name.clone(),
                    n,
                    ack_timeout: cfg.ack_timeout,
                    idle_pause: cfg.idle_pause,
                    record_history: cfg.record_history,
                    adaptive: cfg.adaptive.clone(),
                    // The controller runs where rotations are counted.
                    controller: cfg
                        .adaptive
                        .as_ref()
                        .filter(|_| p == 0)
                        .map(|ac| EpochController::new(&app, ac.clone())),
                };
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("elia-belt-{p}"))
                        .spawn(move || belt.run(listener))
                        .expect("spawn belt thread"),
                );
            }
        }

        Ok(Cluster {
            transport,
            nodes,
            shared,
            client_addrs,
            ring_addrs,
            threads: Mutex::new(threads),
        })
    }

    /// Resolved client-facing addresses (differ from the configured ones
    /// when ephemeral ports were requested).
    pub fn client_addrs(&self) -> &[String] {
        &self.client_addrs
    }

    /// Resolved ring addresses (fault tests `cut` these).
    pub fn ring_addrs(&self) -> &[String] {
        &self.ring_addrs
    }

    /// Number of servers.
    pub fn n_servers(&self) -> usize {
        self.nodes.len()
    }

    /// One server's state (counters, DB).
    pub fn node(&self, p: usize) -> &NetNode {
        &self.nodes[p]
    }

    /// One server's DBMS (convergence checks).
    pub fn db(&self, p: usize) -> &Db {
        self.nodes[p].db()
    }

    /// A connected client stub for this cluster (tests).
    pub fn client(&self, app: Arc<AnalyzedApp>) -> Result<NetClient, ProtoError> {
        NetClient::connect(
            app,
            Arc::clone(&self.transport),
            self.client_addrs.clone(),
            super::client::ClientConfig::default(),
        )
    }

    /// Stop the belt, wait for the drain to complete, join every server
    /// thread, and return the final token. All client connections must
    /// be dropped before calling this (handler threads exit on client
    /// disconnect; parked globals would otherwise never finish).
    pub fn shutdown(&self) -> Token {
        self.shared.stop.store(true, Ordering::SeqCst);
        let token = {
            let mut done = self.shared.done.lock().unwrap();
            while done.is_none() {
                done = self.shared.done_cv.wait(done).unwrap();
            }
            done.take().unwrap()
        };
        // Unblock accept loops (client and ring): a dummy connection
        // wakes each blocked accept, which then observes `halted`.
        for addr in self.client_addrs.iter().chain(self.ring_addrs.iter()) {
            let _ = self.transport.connect(addr);
        }
        for h in self.threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        token
    }

    /// All token entries the belt observed, in global sequence order
    /// (requires [`ServeConfig::record_history`]). Sequence numbers are
    /// assigned contiguously by [`Token::append`], so the no-dup/no-loss
    /// oracle is `seqs == 1..=appended`.
    pub fn global_history(&self) -> Vec<TokenEntry> {
        let mut h = self.shared.history.lock().unwrap().clone();
        h.sort_by_key(|e| e.seq);
        h
    }
}

/// Accept client connections for one server until halt.
fn accept_loop(
    node: Arc<NetNode>,
    shared: Arc<Shared>,
    mut listener: Box<dyn Listener>,
    app_name: String,
) {
    loop {
        let conn = listener.accept();
        if shared.halted.load(Ordering::SeqCst) {
            return;
        }
        let Ok(conn) = conn else { continue };
        let node = Arc::clone(&node);
        let app_name = app_name.clone();
        std::thread::Builder::new()
            .name(format!("elia-conn-{}", node.index))
            .spawn(move || client_conn(node, conn, app_name))
            .expect("spawn handler thread");
    }
}

/// Serve one client connection: handshake, then request/reply until the
/// client disconnects.
fn client_conn(node: Arc<NetNode>, mut conn: Box<dyn Conn>, app_name: String) {
    let Ok(payload) = conn.recv() else { return };
    match decode_msg(&payload) {
        Ok(Msg::Hello { role: Role::Client, app, n_servers, .. }) => {
            if app != app_name || n_servers as usize != node.n {
                let err = Msg::ReplyErr(WireError::plain(
                    false,
                    format!(
                        "handshake mismatch: got app '{app}' x{n_servers}, serving '{app_name}' x{}",
                        node.n
                    ),
                ));
                let _ = conn.send(&encode_msg(&err));
                return;
            }
            // The handshake doubles as the epoch refresh: a client that
            // was told its epoch is stale reconnects and learns the
            // installed version + assignment here.
            let (epoch, assignment) = node.epoch_wire();
            let ok = Msg::HelloOk { server: node.index as u32, epoch, assignment };
            if conn.send(&encode_msg(&ok)).is_err() {
                return;
            }
        }
        _ => {
            let err = Msg::ReplyErr(WireError::plain(
                false,
                "protocol violation: expected Hello".into(),
            ));
            let _ = conn.send(&encode_msg(&err));
            return;
        }
    }
    loop {
        let Ok(payload) = conn.recv() else { return };
        let reply = match decode_msg(&payload) {
            Ok(Msg::Request { txn, args, epoch }) => node.handle_request(&txn, args, epoch),
            Ok(_) => Msg::ReplyErr(WireError::plain(
                false,
                "protocol violation: expected Request".into(),
            )),
            Err(e) => Msg::ReplyErr(WireError::plain(false, format!("bad request: {e}"))),
        };
        if conn.send(&encode_msg(&reply)).is_err() {
            return;
        }
    }
}

/// One server's belt thread: ring I/O plus the per-stop protocol.
struct Belt {
    node: Arc<NetNode>,
    shared: Arc<Shared>,
    transport: Arc<dyn Transport>,
    succ_addr: String,
    app_name: String,
    n: usize,
    ack_timeout: Duration,
    idle_pause: Duration,
    record_history: bool,
    adaptive: Option<AdaptiveConfig>,
    /// Re-partitioning controller; `Some` only at server 0 under
    /// adaptivity.
    controller: Option<EpochController>,
}

impl Belt {
    fn halted(&self) -> bool {
        self.shared.halted.load(Ordering::SeqCst)
    }

    /// Record this server's halt decision and let the connection-close
    /// cascade take the rest of the ring down.
    fn halt(&self, token: Token) {
        self.shared.halted.store(true, Ordering::SeqCst);
        let mut done = self.shared.done.lock().unwrap();
        *done = Some(token);
        self.shared.done_cv.notify_all();
    }

    fn record(&self, token: &Token, before: u64) {
        if !self.record_history {
            return;
        }
        let mut h = self.shared.history.lock().unwrap();
        for e in token.entries() {
            if e.seq > before {
                h.push(e.clone());
            }
        }
    }

    /// Run one stop of this server. Returns the halt decision.
    fn stop_here(&self, token: &mut Token, idle: u32) -> StopOutcome {
        if let Some(acfg) = &self.adaptive {
            // Flush this server's observation counts onto the token and
            // install any newer epoch it carries — the install rides the
            // token's total order, so no extra coordination is needed.
            token.ensure_obs(self.node.app.spec.txns.len());
            for (t, c) in self.node.obs.iter().enumerate() {
                token.obs[t] += c.swap(0, Ordering::Relaxed);
            }
            if token.epoch > self.node.epoch_version() {
                let assign = assignment_from_wire(&token.epoch_assignment);
                let e = Arc::new(self.node.app.epoch_from(token.epoch, assign));
                *self.node.epoch.write().unwrap() = Some(e);
            }
            if let Some(controller) = &self.controller {
                if token.rotations > 0 && token.rotations % acfg.window_rotations == 0 {
                    let installed = self
                        .node
                        .epoch
                        .read()
                        .unwrap()
                        .clone()
                        .expect("adaptive node without an epoch");
                    if let Some(next) = controller.evaluate(&token.obs, &installed.assignment) {
                        let version = installed.version + 1;
                        token.epoch = version;
                        token.epoch_assignment = assignment_to_wire(&next);
                        *self.node.epoch.write().unwrap() =
                            Some(Arc::new(self.node.app.epoch_from(version, next)));
                        self.node.epoch_switches.fetch_add(1, Ordering::Relaxed);
                    }
                    // The observation window is consumed either way.
                    for c in token.obs.iter_mut() {
                        *c = 0;
                    }
                }
            }
        }
        let before = token.appended;
        let any_work = self.node.core.token_stop(self.node.index, token);
        self.record(token, before);
        let streak = if any_work { 0 } else { idle.saturating_add(1) };
        if self.shared.stop.load(Ordering::SeqCst)
            && token.is_empty()
            && streak as usize >= self.n
        {
            return StopOutcome::Drained;
        }
        if streak as usize > 2 * self.n {
            std::thread::sleep(self.idle_pause);
        }
        StopOutcome::Forward(streak)
    }

    /// The single-server degenerate case: no ring connections; the belt
    /// is an in-process loop exactly like
    /// [`Deployment`](crate::conveyor::Deployment)'s token thread.
    fn run_single(self) {
        let mut token = Token::new(1);
        let mut idle: u32 = 0;
        loop {
            token.rotations += 1;
            match self.stop_here(&mut token, idle) {
                StopOutcome::Drained => {
                    self.halt(token);
                    return;
                }
                StopOutcome::Forward(streak) => idle = streak,
            }
        }
    }

    /// The ring case: receive from the predecessor, stop, forward to the
    /// successor — with the exactly-once custody envelope described in
    /// the [module docs](self).
    fn run(self, mut listener: Box<dyn Listener>) {
        // Connect out first (every listener already exists, so this
        // lands in a live backlog), then accept our predecessor.
        let mut out = self.ring_connect();
        if out.is_none() {
            return;
        }
        let mut inn = match self.ring_accept(&mut listener) {
            Some(c) => c,
            None => return,
        };
        let mut last_hop: u64 = 0;
        // Server 0 mints the token.
        let mut pending: Option<(u64, u32, Token)> =
            (self.node.index == 0).then(|| (0, 0, Token::new(self.n)));
        loop {
            let (hop, idle, mut token) = match pending.take() {
                Some(t) => t,
                None => {
                    let payload = match inn.recv() {
                        Ok(p) => p,
                        Err(_) => {
                            if self.halted() {
                                return;
                            }
                            // Predecessor died or was cut: wait for its
                            // reconnect and retransmit.
                            inn = match self.ring_accept(&mut listener) {
                                Some(c) => c,
                                None => return,
                            };
                            continue;
                        }
                    };
                    match decode_msg(&payload) {
                        Ok(Msg::TokenPass { hop, idle, token }) => {
                            // Ack first: custody transfers on receipt,
                            // and the sender releases its copy.
                            let _ = inn.send(&encode_msg(&Msg::TokenAck { hop }));
                            if hop <= last_hop {
                                continue; // stale retransmit, already processed
                            }
                            last_hop = hop;
                            (hop, idle, token)
                        }
                        _ => continue,
                    }
                }
            };
            if self.node.index == 0 && hop > 0 {
                token.rotations += 1;
            }
            match self.stop_here(&mut token, idle) {
                StopOutcome::Drained => {
                    // Dropping `inn`/`out`/`listener` closes our ring
                    // connections; the cascade shuts the others down.
                    self.halt(token);
                    return;
                }
                StopOutcome::Forward(streak) => {
                    let msg = Msg::TokenPass { hop: hop + 1, idle: streak, token };
                    if !self.send_token(&mut out, &msg, hop + 1) {
                        return;
                    }
                }
            }
        }
    }

    /// Dial the successor's ring listener and handshake, retrying until
    /// success or halt.
    fn ring_connect(&self) -> Option<Box<dyn Conn>> {
        let hello = Msg::Hello {
            role: Role::Ring,
            app: self.app_name.clone(),
            n_servers: self.n as u32,
            sender: self.node.index as u32,
        };
        let hello_bytes = encode_msg(&hello);
        loop {
            if self.halted() {
                return None;
            }
            let mut conn = match self.transport.connect(&self.succ_addr) {
                Ok(c) => c,
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
            };
            // The ack deadline doubles as the handshake deadline and
            // stays armed for the lifetime of the out-connection.
            if conn.set_recv_timeout(Some(self.ack_timeout)).is_err() {
                continue;
            }
            if conn.send(&hello_bytes).is_err() {
                continue;
            }
            match conn.recv() {
                Ok(p) => match decode_msg(&p) {
                    Ok(Msg::HelloOk { .. }) => return Some(conn),
                    _ => continue,
                },
                Err(_) => continue,
            }
        }
    }

    /// Accept the predecessor's ring connection (validating its Hello),
    /// skipping stale or foreign connections, until success or halt.
    fn ring_accept(&self, listener: &mut Box<dyn Listener>) -> Option<Box<dyn Conn>> {
        loop {
            if self.halted() {
                return None;
            }
            let Ok(mut conn) = listener.accept() else { continue };
            if self.halted() {
                return None;
            }
            // Deadline on the handshake so an abandoned half-open
            // connection (or shutdown's dummy wake-up) can't wedge us.
            if conn.set_recv_timeout(Some(self.ack_timeout)).is_err() {
                continue;
            }
            let Ok(p) = conn.recv() else { continue };
            match decode_msg(&p) {
                Ok(Msg::Hello { role: Role::Ring, app, n_servers, .. })
                    if app == self.app_name && n_servers as usize == self.n =>
                {
                    // Ring peers don't consume epoch state from the
                    // handshake (it rides the token); send the current
                    // view anyway for symmetry.
                    let (epoch, assignment) = self.node.epoch_wire();
                    let ok = Msg::HelloOk { server: self.node.index as u32, epoch, assignment };
                    if conn.send(&encode_msg(&ok)).is_err() {
                        continue;
                    }
                    // Token receipt has no deadline: idle rings are
                    // legitimately quiet.
                    if conn.set_recv_timeout(None).is_err() {
                        continue;
                    }
                    return Some(conn);
                }
                _ => continue,
            }
        }
    }

    /// Send a token pass and hold it until the successor acks `hop`.
    /// Retransmits on timeout; reconnects and retransmits on a broken
    /// connection. Returns false only when the cluster halted.
    fn send_token(&self, out: &mut Option<Box<dyn Conn>>, msg: &Msg, hop: u64) -> bool {
        let bytes = encode_msg(msg);
        loop {
            if self.halted() {
                return false;
            }
            if out.is_none() {
                *out = match self.ring_connect() {
                    Some(c) => Some(c),
                    None => return false,
                };
            }
            let conn = out.as_mut().unwrap();
            if conn.send(&bytes).is_err() {
                *out = None;
                continue;
            }
            // Await the ack (the out-connection's recv deadline is the
            // ack timeout).
            loop {
                match conn.recv() {
                    Ok(p) => match decode_msg(&p) {
                        Ok(Msg::TokenAck { hop: h }) if h == hop => return true,
                        // A stale ack from an earlier retransmit round:
                        // keep waiting for ours.
                        Ok(Msg::TokenAck { .. }) => continue,
                        _ => continue,
                    },
                    // Deadline passed unacked: retransmit on the same
                    // connection (the receiver dedupes).
                    Err(ProtoError::Timeout) => break,
                    // Broken: reconnect and retransmit.
                    Err(_) => {
                        *out = None;
                        break;
                    }
                }
            }
        }
    }
}

/// Outcome of one token stop.
enum StopOutcome {
    /// Keep rotating; carries the updated idle streak.
    Forward(u32),
    /// Stop flag set and the system is drained: halt here.
    Drained,
}
