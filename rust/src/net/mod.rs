//! The served Eliá system: wire protocol, transports, servers, clients.
//!
//! Everything the in-process [`Deployment`](crate::conveyor::Deployment)
//! does — routing, parked globals, the circulating token — promoted to
//! a real networked system:
//!
//! * [`proto`] — the length-prefixed, checksummed frame codec and the
//!   [`Msg`] set (requests, replies, token passes, acks);
//! * [`transport`] — [`Transport`]/[`Listener`]/[`Conn`] traits with
//!   real TCP/UDS implementations and a deterministic in-memory
//!   [`Loopback`] for tests (with fault injection via
//!   [`Loopback::cut`]);
//! * [`server`] — [`Cluster`]: per-server accept/handler/belt threads,
//!   the token as a framed ring message with exactly-once custody;
//! * [`client`] — [`NetClient`]: routing-parity client stub with
//!   automatic retry of retryable errors.
//!
//! See `src/net/README.md` for the frame layout and the token-message
//! mapping onto [`crate::conveyor::token`].

pub mod client;
pub mod proto;
pub mod server;
pub mod transport;

pub use client::{ClientConfig, NetClient, NetError};
pub use proto::{Msg, ProtoError, Role, WireError, FRAME_HEADER, MAX_FRAME};
pub use server::{Cluster, NetNode, ServeConfig};
pub use transport::{Conn, Listener, Loopback, Tcp, Transport};
#[cfg(unix)]
pub use transport::Uds;
