//! The Eliá wire protocol: length-prefixed, checksummed binary frames.
//!
//! Framing mirrors the WAL's record discipline
//! ([`crate::db::wal`]): every frame is
//!
//! ```text
//! [len: u32 LE][fnv1a64(payload): u64 LE][payload: len bytes]
//! ```
//!
//! and the decode side applies the same torn-tail rules — a frame cut
//! short mid-header or mid-payload is [`ProtoError::Torn`], a checksum
//! mismatch is [`ProtoError::Checksum`], and a checksum-valid payload
//! that does not decode is [`ProtoError::Decode`] (corruption the
//! checksum cannot explain away). Nothing in this module panics on
//! hostile bytes: a declared length beyond [`MAX_FRAME`] is rejected
//! *before* any allocation ([`ProtoError::Oversized`]).
//!
//! Message payloads ([`Msg`]) reuse the WAL's value/update codec
//! (`put_value`, `encode_update`, the byte [`Reader`]) so the two wire
//! formats cannot drift. Replies are encoded straight from borrowed
//! [`RowRef`](crate::db::RowRef)s — the encode path clones no `Value`s,
//! keeping the engine's allocation-free read path intact across the
//! socket boundary.

use crate::conveyor::token::{Token, TokenEntry};
use crate::db::wal::{decode_update, encode_update, fnv1a, put_u32, put_value, Reader};
use crate::db::{Row, Value};
use crate::workload::spec::Reply;
use std::fmt;
use std::io::{Read, Write};

/// Hard cap on a frame's payload length: a hostile or corrupt length
/// prefix is rejected before allocation.
pub const MAX_FRAME: usize = 32 * 1024 * 1024;

/// Bytes of frame header: `len: u32` + `fnv1a64: u64`.
pub const FRAME_HEADER: usize = 12;

/// Everything that can go wrong on the wire. Mirrors the WAL's recovery
/// taxonomy: torn frames and bad checksums are distinguishable from
/// clean closes and from semantic decode failures.
#[derive(Debug, Clone, PartialEq)]
pub enum ProtoError {
    /// Underlying transport I/O failure (rendered `io::Error`).
    Io(String),
    /// The peer closed the connection cleanly (EOF at a frame boundary).
    Closed,
    /// The byte stream ended mid-frame (header or payload cut short).
    Torn(String),
    /// The length prefix exceeds [`MAX_FRAME`] — rejected before any
    /// allocation.
    Oversized {
        /// Declared payload length.
        len: usize,
        /// The cap it exceeded ([`MAX_FRAME`]).
        max: usize,
    },
    /// Frame checksum mismatch: the payload arrived complete but corrupt.
    Checksum,
    /// The payload passed the checksum but is not a valid message.
    Decode(String),
    /// A receive deadline elapsed (ack timeouts on the belt ring).
    Timeout,
    /// Handshake violation: wrong app, wrong cluster size, bad role.
    Handshake(String),
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "i/o error: {e}"),
            ProtoError::Closed => write!(f, "connection closed by peer"),
            ProtoError::Torn(d) => write!(f, "torn frame: {d}"),
            ProtoError::Oversized { len, max } => {
                write!(f, "oversized frame: declared {len} bytes exceeds cap {max}")
            }
            ProtoError::Checksum => write!(f, "frame checksum mismatch"),
            ProtoError::Decode(d) => write!(f, "undecodable message: {d}"),
            ProtoError::Timeout => write!(f, "receive timed out"),
            ProtoError::Handshake(d) => write!(f, "handshake rejected: {d}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ProtoError::Timeout,
            _ => ProtoError::Io(e.to_string()),
        }
    }
}

/// Wrap a payload in a frame (length + checksum header).
pub fn frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "frame payload exceeds MAX_FRAME");
    let mut buf = Vec::with_capacity(FRAME_HEADER + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Decode one frame from the front of `bytes`: returns the payload slice
/// and the total bytes consumed. Errors follow the WAL's torn-tail
/// discipline (see the [module docs](self)); never panics on corrupt
/// input.
pub fn deframe(bytes: &[u8]) -> Result<(&[u8], usize), ProtoError> {
    if bytes.len() < FRAME_HEADER {
        return Err(ProtoError::Torn(format!(
            "header truncated: {} of {FRAME_HEADER} bytes",
            bytes.len()
        )));
    }
    let len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(ProtoError::Oversized { len, max: MAX_FRAME });
    }
    let expect = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    let end = FRAME_HEADER + len;
    if bytes.len() < end {
        return Err(ProtoError::Torn(format!(
            "payload truncated: {} of {len} bytes",
            bytes.len() - FRAME_HEADER
        )));
    }
    let payload = &bytes[FRAME_HEADER..end];
    if fnv1a(payload) != expect {
        return Err(ProtoError::Checksum);
    }
    Ok((payload, end))
}

/// Write one frame to a byte stream (the TCP/UDS path).
pub fn write_frame(w: &mut dyn Write, payload: &[u8]) -> Result<(), ProtoError> {
    if payload.len() > MAX_FRAME {
        return Err(ProtoError::Oversized { len: payload.len(), max: MAX_FRAME });
    }
    let mut header = [0u8; FRAME_HEADER];
    header[0..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[4..12].copy_from_slice(&fnv1a(payload).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from a byte stream. EOF *at* a frame boundary is a
/// clean [`ProtoError::Closed`]; EOF *inside* a frame is
/// [`ProtoError::Torn`]; a read deadline maps to [`ProtoError::Timeout`].
pub fn read_frame(r: &mut dyn Read) -> Result<Vec<u8>, ProtoError> {
    let mut header = [0u8; FRAME_HEADER];
    read_full(r, &mut header, true)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(ProtoError::Oversized { len, max: MAX_FRAME });
    }
    let expect = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, false)?;
    if fnv1a(&payload) != expect {
        return Err(ProtoError::Checksum);
    }
    Ok(payload)
}

/// `read_exact` with the protocol's EOF semantics: a clean EOF before the
/// first byte is [`ProtoError::Closed`] when `clean_eof_ok` (frame
/// boundary), anything else mid-buffer is [`ProtoError::Torn`].
fn read_full(r: &mut dyn Read, buf: &mut [u8], clean_eof_ok: bool) -> Result<(), ProtoError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 && clean_eof_ok {
                    Err(ProtoError::Closed)
                } else {
                    Err(ProtoError::Torn(format!("eof after {filled} of {} bytes", buf.len())))
                };
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Who is connecting: a request/reply client or the predecessor server
/// on the belt ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Submits operations, receives replies.
    Client,
    /// The ring predecessor; forwards [`Msg::TokenPass`] frames.
    Ring,
}

/// A transaction error crossing the wire: the retryability classification
/// ([`crate::db::Retryable`]) plus the rendered message. The client stub
/// auto-retries `retryable` errors with capped backoff and surfaces the
/// rest.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    /// True for concurrency victims (wait-die aborts): retry may succeed.
    pub retryable: bool,
    /// Rendered [`TxnError`](crate::db::TxnError) text.
    pub message: String,
    /// On an epoch misroute (the request was routed under a routing
    /// epoch older than the server's installed one): the installed
    /// version. The client re-handshakes to fetch the new epoch's
    /// assignment and re-routes instead of failing.
    pub epoch: Option<u64>,
}

impl WireError {
    /// A plain wire error with no epoch payload.
    pub fn plain(retryable: bool, message: impl Into<String>) -> WireError {
        WireError { retryable, message: message.into(), epoch: None }
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", if self.retryable { "[retryable] " } else { "" }, self.message)
    }
}

/// Every message the protocol speaks. One frame carries exactly one
/// message; the first byte of the payload is the variant tag.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Connection opener (both roles): names the app and the expected
    /// cluster size so mismatched deployments fail fast.
    Hello {
        /// Client or ring predecessor.
        role: Role,
        /// Application name ([`AppSpec::name`](crate::workload::spec::AppSpec)).
        app: String,
        /// Cluster size the sender expects.
        n_servers: u32,
        /// Sender's server index (ring role) or client id.
        sender: u32,
    },
    /// Handshake accepted; carries the receiving server's index and the
    /// installed routing epoch, so (re)connecting is also how a client
    /// refreshes its routing view after an epoch misroute.
    HelloOk {
        /// The server index the client actually reached.
        server: u32,
        /// Installed routing-epoch version (0 = static / adaptivity off).
        epoch: u64,
        /// The epoch's partitioning assignment in wire form (`-1` =
        /// `None`; see `analysis::drift::assignment_to_wire`). Empty when
        /// the server routes statically.
        assignment: Vec<i64>,
    },
    /// One operation: template name plus bound parameters in canonical
    /// (name-sorted) order.
    Request {
        /// Transaction template name.
        txn: String,
        /// Bound parameters, name-sorted
        /// ([`Operation::canonical_args`](crate::workload::spec::Operation::canonical_args)).
        args: Vec<(String, Value)>,
        /// Routing-epoch version the client routed this request under
        /// (0 = static). A server on a newer epoch that disagrees with
        /// the client's target answers with a retryable epoch-misroute
        /// [`WireError`] instead of a fatal misroute.
        epoch: u64,
    },
    /// Successful reply: the operation's [`ResultSet`](crate::db::ResultSet),
    /// encoded row-by-row from borrowed [`RowRef`](crate::db::RowRef)s.
    ReplyOk(Reply),
    /// Failed reply: the classified error.
    ReplyErr(WireError),
    /// The belt token in flight, wrapped in the ring's exactly-once
    /// envelope: `hop` increments on every forward and the receiver
    /// dedupes stale retransmits by it; `idle` carries the no-work streak
    /// that drives idle pauses (the networked form of
    /// [`Deployment`](crate::conveyor::Deployment)'s `idle_rounds`).
    TokenPass {
        /// Monotone forward count; the retransmit dedupe key.
        hop: u64,
        /// Consecutive no-work stops preceding this hop.
        idle: u32,
        /// The [`Token`] itself: pending entries + per-server watermarks.
        token: Token,
    },
    /// Receipt acknowledgement for [`Msg::TokenPass`] — sent *before*
    /// processing, so the sender can release the token as soon as custody
    /// transfers.
    TokenAck {
        /// Echo of the acknowledged hop.
        hop: u64,
    },
}

const TAG_HELLO: u8 = 0;
const TAG_HELLO_OK: u8 = 1;
const TAG_REQUEST: u8 = 2;
const TAG_REPLY_OK: u8 = 3;
const TAG_REPLY_ERR: u8 = 4;
const TAG_TOKEN_PASS: u8 = 5;
const TAG_TOKEN_ACK: u8 = 6;

const ROLE_CLIENT: u8 = 0;
const ROLE_RING: u8 = 1;

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_string(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Encode one message into an unframed payload (pair with [`frame`] /
/// [`write_frame`]). The [`Msg::ReplyOk`] arm iterates the result's
/// borrowed rows and clones no values.
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64);
    match msg {
        Msg::Hello { role, app, n_servers, sender } => {
            buf.push(TAG_HELLO);
            buf.push(match role {
                Role::Client => ROLE_CLIENT,
                Role::Ring => ROLE_RING,
            });
            put_string(&mut buf, app);
            put_u32(&mut buf, *n_servers);
            put_u32(&mut buf, *sender);
        }
        Msg::HelloOk { server, epoch, assignment } => {
            buf.push(TAG_HELLO_OK);
            put_u32(&mut buf, *server);
            put_u64(&mut buf, *epoch);
            put_u32(&mut buf, assignment.len() as u32);
            for &a in assignment {
                put_u64(&mut buf, a as u64);
            }
        }
        Msg::Request { txn, args, epoch } => {
            buf.push(TAG_REQUEST);
            put_string(&mut buf, txn);
            put_u32(&mut buf, args.len() as u32);
            for (name, v) in args {
                put_string(&mut buf, name);
                put_value(&mut buf, v);
            }
            put_u64(&mut buf, *epoch);
        }
        Msg::ReplyOk(rs) => {
            buf.push(TAG_REPLY_OK);
            put_u64(&mut buf, rs.affected as u64);
            put_u32(&mut buf, rs.len() as u32);
            for row in rs.iter() {
                put_u32(&mut buf, row.len() as u32);
                for v in row.iter() {
                    put_value(&mut buf, v);
                }
            }
        }
        Msg::ReplyErr(e) => {
            buf.push(TAG_REPLY_ERR);
            buf.push(e.retryable as u8);
            put_string(&mut buf, &e.message);
            match e.epoch {
                Some(v) => {
                    buf.push(1);
                    put_u64(&mut buf, v);
                }
                None => buf.push(0),
            }
        }
        Msg::TokenPass { hop, idle, token } => {
            buf.push(TAG_TOKEN_PASS);
            put_u64(&mut buf, *hop);
            put_u32(&mut buf, *idle);
            let entries: Vec<&TokenEntry> = token.entries().collect();
            put_u32(&mut buf, entries.len() as u32);
            for e in entries {
                put_u32(&mut buf, e.origin as u32);
                put_u64(&mut buf, e.seq);
                let mut ubuf = Vec::with_capacity(e.update.wire_size());
                encode_update(&mut ubuf, &e.update);
                put_u32(&mut buf, ubuf.len() as u32);
                buf.extend_from_slice(&ubuf);
            }
            let wms = token.watermarks();
            put_u32(&mut buf, wms.len() as u32);
            for &w in wms {
                put_u64(&mut buf, w);
            }
            put_u64(&mut buf, token.appended);
            put_u64(&mut buf, token.rotations);
            put_u64(&mut buf, token.epoch);
            put_u32(&mut buf, token.epoch_assignment.len() as u32);
            for &a in &token.epoch_assignment {
                put_u64(&mut buf, a as u64);
            }
            put_u32(&mut buf, token.obs.len() as u32);
            for &c in &token.obs {
                put_u64(&mut buf, c);
            }
        }
        Msg::TokenAck { hop } => {
            buf.push(TAG_TOKEN_ACK);
            put_u64(&mut buf, *hop);
        }
    }
    buf
}

/// Decode one message from an unframed payload. Trailing bytes, unknown
/// tags, and truncated fields are [`ProtoError::Decode`] — never a
/// panic, mirroring the WAL's "checksum ok but undecodable" hard error.
pub fn decode_msg(payload: &[u8]) -> Result<Msg, ProtoError> {
    decode_msg_inner(payload).map_err(ProtoError::Decode)
}

fn decode_msg_inner(payload: &[u8]) -> Result<Msg, String> {
    let mut r = Reader::new(payload);
    let msg = match r.u8()? {
        TAG_HELLO => {
            let role = match r.u8()? {
                ROLE_CLIENT => Role::Client,
                ROLE_RING => Role::Ring,
                t => return Err(format!("unknown role tag {t}")),
            };
            let app = r.string()?;
            let n_servers = r.u32()?;
            let sender = r.u32()?;
            Msg::Hello { role, app, n_servers, sender }
        }
        TAG_HELLO_OK => {
            let server = r.u32()?;
            let epoch = r.u64()?;
            let na = r.u32()? as usize;
            let mut assignment = Vec::with_capacity(na.min(1024));
            for _ in 0..na {
                assignment.push(r.u64()? as i64);
            }
            Msg::HelloOk { server, epoch, assignment }
        }
        TAG_REQUEST => {
            let txn = r.string()?;
            let n = r.u32()? as usize;
            let mut args = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let name = r.string()?;
                let v = r.value()?;
                args.push((name, v));
            }
            let epoch = r.u64()?;
            Msg::Request { txn, args, epoch }
        }
        TAG_REPLY_OK => {
            let affected = r.u64()? as usize;
            let n = r.u32()? as usize;
            let mut rows: Vec<Row> = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let w = r.u32()? as usize;
                let mut row = Vec::with_capacity(w.min(1024));
                for _ in 0..w {
                    row.push(r.value()?);
                }
                rows.push(row);
            }
            Msg::ReplyOk(Reply::from_owned_rows(rows, affected))
        }
        TAG_REPLY_ERR => {
            let retryable = match r.u8()? {
                0 => false,
                1 => true,
                t => return Err(format!("bad bool tag {t}")),
            };
            let message = r.string()?;
            let epoch = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                t => return Err(format!("bad option tag {t}")),
            };
            Msg::ReplyErr(WireError { retryable, message, epoch })
        }
        TAG_TOKEN_PASS => {
            let hop = r.u64()?;
            let idle = r.u32()?;
            let n = r.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let origin = r.u32()? as usize;
                let seq = r.u64()?;
                let ulen = r.u32()? as usize;
                let update = decode_update(r.take(ulen)?)?;
                entries.push(TokenEntry { origin, seq, update });
            }
            let nw = r.u32()? as usize;
            let mut wms = Vec::with_capacity(nw.min(1024));
            for _ in 0..nw {
                wms.push(r.u64()?);
            }
            let appended = r.u64()?;
            let rotations = r.u64()?;
            let epoch = r.u64()?;
            let na = r.u32()? as usize;
            let mut epoch_assignment = Vec::with_capacity(na.min(1024));
            for _ in 0..na {
                epoch_assignment.push(r.u64()? as i64);
            }
            let no = r.u32()? as usize;
            let mut obs = Vec::with_capacity(no.min(1024));
            for _ in 0..no {
                obs.push(r.u64()?);
            }
            Msg::TokenPass {
                hop,
                idle,
                token: Token::from_parts(
                    entries,
                    wms,
                    appended,
                    rotations,
                    epoch,
                    epoch_assignment,
                    obs,
                ),
            }
        }
        TAG_TOKEN_ACK => Msg::TokenAck { hop: r.u64()? },
        t => return Err(format!("unknown message tag {t}")),
    };
    r.done()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello elia";
        let framed = frame(payload);
        assert_eq!(framed.len(), FRAME_HEADER + payload.len());
        let (got, consumed) = deframe(&framed).unwrap();
        assert_eq!(got, payload);
        assert_eq!(consumed, framed.len());
    }

    #[test]
    fn torn_and_corrupt_frames_error_cleanly() {
        let framed = frame(b"payload");
        // Torn header.
        assert!(matches!(deframe(&framed[..5]), Err(ProtoError::Torn(_))));
        // Torn payload.
        assert!(matches!(deframe(&framed[..FRAME_HEADER + 3]), Err(ProtoError::Torn(_))));
        // Flipped payload bit.
        let mut corrupt = framed.clone();
        *corrupt.last_mut().unwrap() ^= 1;
        assert_eq!(deframe(&corrupt), Err(ProtoError::Checksum));
        // Hostile length prefix: rejected before allocation.
        let mut oversized = framed;
        oversized[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(deframe(&oversized), Err(ProtoError::Oversized { .. })));
    }

    #[test]
    fn message_roundtrip() {
        let msgs = vec![
            Msg::Hello { role: Role::Ring, app: "tpcw".into(), n_servers: 3, sender: 2 },
            Msg::HelloOk { server: 1, epoch: 0, assignment: vec![] },
            Msg::HelloOk { server: 1, epoch: 3, assignment: vec![0, -1, 1] },
            Msg::Request {
                txn: "createCart".into(),
                args: vec![
                    ("cid".into(), Value::Int(7)),
                    ("name".into(), Value::Str("x".into())),
                ],
                epoch: 0,
            },
            Msg::Request { txn: "move".into(), args: vec![], epoch: 7 },
            Msg::ReplyErr(WireError::plain(true, "lock conflict")),
            Msg::ReplyErr(WireError {
                retryable: true,
                message: "stale routing epoch".into(),
                epoch: Some(4),
            }),
            Msg::TokenAck { hop: 42 },
        ];
        for msg in msgs {
            let bytes = encode_msg(&msg);
            assert_eq!(decode_msg(&bytes).unwrap(), msg, "{msg:?}");
        }
    }

    #[test]
    fn reply_roundtrip_preserves_rows() {
        let reply = Reply::from_owned_rows(
            vec![
                vec![Value::Int(1), Value::Str("a".into()), Value::Null],
                vec![Value::Int(2), Value::Float(0.5), Value::Int(-3)],
            ],
            0,
        );
        let msg = Msg::ReplyOk(reply);
        let bytes = encode_msg(&msg);
        assert_eq!(decode_msg(&bytes).unwrap(), msg);
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut bytes = encode_msg(&Msg::HelloOk { server: 0, epoch: 0, assignment: vec![] });
        bytes.push(0xFF);
        assert!(matches!(decode_msg(&bytes), Err(ProtoError::Decode(_))));
    }

    #[test]
    fn token_pass_roundtrips_epoch_fields() {
        let mut token = Token::new(3);
        token.epoch = 2;
        token.epoch_assignment = vec![1, -1, 0];
        token.ensure_obs(3);
        token.obs[2] = 99;
        let msg = Msg::TokenPass { hop: 5, idle: 1, token };
        let bytes = encode_msg(&msg);
        assert_eq!(decode_msg(&bytes).unwrap(), msg);
    }
}
