//! The client stub: routes operations exactly like
//! [`Deployment::submit`](crate::conveyor::Deployment::submit) and
//! speaks the request/reply half of the wire protocol.
//!
//! Routing parity matters: the served cluster rejects misrouted
//! operations instead of forwarding them, so the stub computes the same
//! [`Route`] (including the commutative-spread hash) as the in-process
//! deployment. This is what makes the net path and the in-process path
//! bit-identical under a deterministic workload — the same operation
//! lands on the same server either way.
//!
//! Retry discipline: a [`Msg::ReplyErr`] marked retryable (a wait-die
//! victim on the server) is retried with capped exponential backoff. A
//! *transport* error is different — the request may or may not have
//! executed — so the stub reconnects and surfaces the error rather than
//! silently re-executing a possibly-committed operation.

use super::proto::{decode_msg, encode_msg, Msg, ProtoError, Role, WireError};
use super::transport::{Conn, Transport};
use crate::analysis::drift::assignment_from_wire;
use crate::workload::analyzed::{AnalyzedApp, Route, RoutingEpoch};
use crate::workload::spec::{Operation, Reply};
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

/// Client stub tuning.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Max automatic retries of a retryable server error (the retry
    /// budget: stale-epoch re-routes and wait-die victims both draw
    /// from it).
    pub max_retries: u32,
    /// Initial backoff before the first retry; doubles per attempt.
    pub backoff: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Exponent ceiling of the doubling schedule: the multiplier is
    /// `2^min(attempt, backoff_exp_cap)` (then clamped to
    /// [`ClientConfig::backoff_cap`]). Keeps `backoff << attempt` from
    /// overflowing on long retry runs.
    pub backoff_exp_cap: u32,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            max_retries: 50,
            backoff: Duration::from_micros(200),
            backoff_cap: Duration::from_millis(20),
            backoff_exp_cap: 8,
        }
    }
}

/// Everything a submit can fail with.
#[derive(Debug, Clone, PartialEq)]
pub enum NetError {
    /// The connection failed (handshake, send, or receive). The
    /// operation may or may not have executed on the server.
    Transport(ProtoError),
    /// The server executed (or refused) the operation and reported an
    /// error; retryable ones were already retried `max_retries` times.
    Server(WireError),
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NetError::Transport(e) => write!(f, "transport: {e}"),
            NetError::Server(e) => write!(f, "server: {e}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<ProtoError> for NetError {
    fn from(e: ProtoError) -> Self {
        NetError::Transport(e)
    }
}

/// A connected client: one lazily-established connection per server.
pub struct NetClient {
    app: Arc<AnalyzedApp>,
    transport: Arc<dyn Transport>,
    addrs: Vec<String>,
    conns: Vec<Option<Box<dyn Conn>>>,
    cfg: ClientConfig,
    /// Retryable server errors absorbed by the automatic retry loop.
    pub retries: u64,
    /// The routing epoch learned at handshake (`None` against a static
    /// cluster). A stale-epoch rejection triggers a re-handshake, which
    /// refreshes this and re-routes the operation.
    epoch: Option<RoutingEpoch>,
}

impl NetClient {
    /// Connect to every server eagerly (handshakes included), so a
    /// misconfigured cluster fails at construction, not mid-workload.
    pub fn connect(
        app: Arc<AnalyzedApp>,
        transport: Arc<dyn Transport>,
        addrs: Vec<String>,
        cfg: ClientConfig,
    ) -> Result<NetClient, ProtoError> {
        let mut client = NetClient {
            conns: (0..addrs.len()).map(|_| None).collect(),
            app,
            transport,
            addrs,
            cfg,
            retries: 0,
            epoch: None,
        };
        for s in 0..client.addrs.len() {
            client.ensure(s)?;
        }
        Ok(client)
    }

    /// The server this operation routes to — the same decision
    /// [`Deployment::submit`](crate::conveyor::Deployment::submit)
    /// makes, including the commutative-spread hash for [`Route::Any`].
    pub fn target(&self, op: &Operation) -> usize {
        let n = self.addrs.len();
        let route = match &self.epoch {
            Some(e) => e.route_op(&self.app, op, n),
            None => self.app.route(op, n),
        };
        match route {
            Route::Any => (op.txn + op.args.len()) % n,
            Route::LocalAt(s) | Route::GlobalAt(s) | Route::ConfluentAt(s) => s,
        }
    }

    /// The routing-epoch version this stub currently issues under (0
    /// against a static cluster or before any handshake).
    pub fn epoch_version(&self) -> u64 {
        self.epoch.as_ref().map(|e| e.version).unwrap_or(0)
    }

    /// (Re)establish the connection to server `s`, handshake included.
    fn ensure(&mut self, s: usize) -> Result<(), ProtoError> {
        if self.conns[s].is_some() {
            return Ok(());
        }
        let mut conn = self.transport.connect(&self.addrs[s])?;
        let hello = Msg::Hello {
            role: Role::Client,
            app: self.app.spec.name.clone(),
            n_servers: self.addrs.len() as u32,
            sender: s as u32,
        };
        conn.send(&encode_msg(&hello))?;
        match decode_msg(&conn.recv()?)? {
            Msg::HelloOk { epoch, assignment, .. } => {
                // Adaptive clusters advertise their installed epoch in
                // the handshake; adopt it when it is news (a re-ensure
                // after a stale-epoch rejection lands here).
                if !assignment.is_empty()
                    && (self.epoch.is_none() || epoch > self.epoch_version())
                {
                    self.epoch =
                        Some(self.app.epoch_from(epoch, assignment_from_wire(&assignment)));
                }
            }
            Msg::ReplyErr(e) => return Err(ProtoError::Handshake(e.message)),
            other => {
                return Err(ProtoError::Handshake(format!("unexpected reply {other:?}")));
            }
        }
        self.conns[s] = Some(conn);
        Ok(())
    }

    /// Submit one operation: route, encode, send, await the reply.
    /// Retryable server errors are retried with capped exponential
    /// backoff (ceilings from [`ClientConfig`]); a stale-epoch rejection
    /// re-handshakes to learn the new epoch and re-routes without
    /// backoff (it is a routing race, not contention). Transport errors
    /// drop the connection (it re-establishes on the next submit) and
    /// surface immediately.
    pub fn submit(&mut self, op: &Operation) -> Result<Reply, NetError> {
        let mut attempt: u32 = 0;
        loop {
            // Route and encode per attempt: an epoch refresh between
            // attempts can change both the target and the version the
            // request must carry.
            let s = self.target(op);
            let request = Msg::Request {
                txn: self.app.spec.txns[op.txn].name.clone(),
                args: op
                    .canonical_args()
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v.clone()))
                    .collect(),
                epoch: self.epoch_version(),
            };
            let bytes = encode_msg(&request);
            let outcome = self.roundtrip(s, &bytes);
            match outcome {
                Ok(Msg::ReplyOk(reply)) => return Ok(reply),
                Ok(Msg::ReplyErr(e)) => {
                    if e.retryable && attempt < self.cfg.max_retries {
                        attempt += 1;
                        self.retries += 1;
                        if let Some(v) = e.epoch {
                            // Stale-epoch misroute: refresh via a fresh
                            // handshake (it carries the installed epoch),
                            // then re-route immediately.
                            if v > self.epoch_version() {
                                self.conns[s] = None;
                                if let Err(err) = self.ensure(s) {
                                    return Err(NetError::Transport(err));
                                }
                            }
                            continue;
                        }
                        let backoff = self
                            .cfg
                            .backoff
                            .saturating_mul(1u32 << attempt.min(self.cfg.backoff_exp_cap))
                            .min(self.cfg.backoff_cap);
                        std::thread::sleep(backoff);
                    } else {
                        return Err(NetError::Server(e));
                    }
                }
                Ok(other) => {
                    self.conns[s] = None;
                    return Err(NetError::Transport(ProtoError::Decode(format!(
                        "unexpected reply {other:?}"
                    ))));
                }
                Err(e) => {
                    self.conns[s] = None;
                    return Err(NetError::Transport(e));
                }
            }
        }
    }

    fn roundtrip(&mut self, s: usize, bytes: &[u8]) -> Result<Msg, ProtoError> {
        self.ensure(s)?;
        let conn = self.conns[s].as_mut().unwrap();
        conn.send(bytes)?;
        decode_msg(&conn.recv()?)
    }
}
