//! Operation generation: the interface every workload (TPC-W, RUBiS,
//! micro) implements, plus the per-operation service-time model used by
//! the simulator.

use crate::util::{Rng, VTime};
use crate::workload::spec::{Operation, TxnTemplate};

/// Generates the next operation for a client.
///
/// `client_site` lets generators produce site-affine key values (the
/// paper's server-specific unique ids: carts created at a site get ids
/// routing back to that site's server). `n_servers` is the deployment
/// size the routing function hashes into.
pub trait OpGenerator: Send {
    fn next_op(&mut self, rng: &mut Rng, client_site: usize, n_servers: usize) -> Operation;

    /// Time-aware generation hook: like [`OpGenerator::next_op`] but
    /// handed the issuing client's virtual clock, so a generator can play
    /// a deterministic drift schedule (`analysis::drift::DriftConfig`) —
    /// the mix is a pure function of `(rng stream, now)`, which keeps
    /// simulation results bit-identical at any thread or client-group
    /// count. The default ignores time and delegates, so existing
    /// generators (including plain closures) are unaffected.
    fn next_op_at(
        &mut self,
        rng: &mut Rng,
        client_site: usize,
        n_servers: usize,
        _now: VTime,
    ) -> Operation {
        self.next_op(rng, client_site, n_servers)
    }
}

impl<F> OpGenerator for F
where
    F: FnMut(&mut Rng, usize, usize) -> Operation + Send,
{
    fn next_op(&mut self, rng: &mut Rng, client_site: usize, n_servers: usize) -> Operation {
        self(rng, client_site, n_servers)
    }
}

/// Service-time model: how long an operation occupies a worker.
///
/// The paper's microbenchmark fixes this at 5 ms per operation; for the
/// macro benchmarks we model `base + per_stmt · n_statements` to reflect
/// that multi-statement transactions cost more.
#[derive(Debug, Clone, Copy)]
pub struct ServiceModel {
    pub base_ms: f64,
    pub per_stmt_ms: f64,
    /// Multiplicative jitter amplitude in [0, 1): service is scaled by
    /// `1 + U(-jitter, +jitter)`.
    pub jitter: f64,
}

impl Default for ServiceModel {
    fn default() -> Self {
        // ~5 ms for a 2-3 statement transaction, matching the paper's
        // microbenchmark scale.
        ServiceModel { base_ms: 2.0, per_stmt_ms: 1.0, jitter: 0.1 }
    }
}

impl ServiceModel {
    /// Fixed per-op cost (the RQ3 microbenchmark: exactly 5 ms).
    pub fn fixed(ms: f64) -> Self {
        ServiceModel { base_ms: ms, per_stmt_ms: 0.0, jitter: 0.0 }
    }

    pub fn sample(&self, tpl: &TxnTemplate, rng: &mut Rng) -> VTime {
        let mut ms = self.base_ms + self.per_stmt_ms * tpl.stmts.len() as f64;
        if self.jitter > 0.0 {
            ms *= 1.0 + (rng.f64() * 2.0 - 1.0) * self.jitter;
        }
        VTime::from_millis_f64(ms.max(0.01))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::TxnTemplate;

    fn tpl(nstmts: usize) -> TxnTemplate {
        let stmts: Vec<(String, String)> = (0..nstmts)
            .map(|i| (format!("s{i}"), format!("SELECT A FROM T WHERE A = {i}")))
            .collect();
        let refs: Vec<(&str, &str)> =
            stmts.iter().map(|(a, b)| (a.as_str(), b.as_str())).collect();
        TxnTemplate::new("t", &[], &refs, 1.0)
    }

    #[test]
    fn fixed_model_is_exact() {
        let m = ServiceModel::fixed(5.0);
        let mut rng = Rng::new(1);
        assert_eq!(m.sample(&tpl(3), &mut rng), VTime::from_millis(5));
        assert_eq!(m.sample(&tpl(1), &mut rng), VTime::from_millis(5));
    }

    #[test]
    fn per_stmt_scales() {
        let m = ServiceModel { base_ms: 1.0, per_stmt_ms: 2.0, jitter: 0.0 };
        let mut rng = Rng::new(1);
        assert_eq!(m.sample(&tpl(3), &mut rng), VTime::from_millis(7));
    }

    #[test]
    fn jitter_bounded() {
        let m = ServiceModel { base_ms: 10.0, per_stmt_ms: 0.0, jitter: 0.2 };
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let s = m.sample(&tpl(1), &mut rng).as_millis_f64();
            assert!((8.0..=12.0).contains(&s), "s={s}");
        }
    }

    #[test]
    fn closure_is_a_generator() {
        use crate::db::Bindings;
        use crate::workload::spec::Operation;
        let mut g = |_rng: &mut Rng, _site: usize, _n: usize| Operation {
            txn: 0,
            args: Bindings::new(),
        };
        let op = crate::workload::generator::OpGenerator::next_op(&mut g, &mut Rng::new(1), 0, 4);
        assert_eq!(op.txn, 0);
    }
}
