//! TPC-W: the online bookstore benchmark (paper §6).
//!
//! 10 tables, 20 transaction templates. Under Operation Partitioning the
//! classification matches the paper's Table 1 exactly: **10 local, 5
//! global, 5 commutative**, 13 read-only templates. Local transactions
//! update customer data (partitioned by customer id) or manipulate
//! shopping carts (partitioned by cart id); global transactions order
//! books or perform administrative updates of the book list; commutative
//! transactions read immutable tables (countries, authors, subjects).
//!
//! Two templates — the best-seller and new-product searches — are
//! *forced* global (the paper's "global search" treatment, see
//! [`crate::analysis::Classification::force_global`]); the shopping-mix
//! weights then reproduce Table 1's operation frequencies:
//! L ≈ 47%, G ≈ 39%, C ≈ 14%, ~30% writes.

use crate::catalog::{Schema, TableSchema, ValueType};
use crate::db::{Bindings, Db, Value};
use crate::util::Rng;
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::OpGenerator;
use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

/// Scale parameters for seeding.
#[derive(Debug, Clone, Copy)]
pub struct TpcwScale {
    pub items: i64,
    pub customers: i64,
    pub authors: i64,
    pub countries: i64,
    pub subjects: i64,
}

impl Default for TpcwScale {
    fn default() -> Self {
        TpcwScale { items: 1000, customers: 1000, authors: 100, countries: 92, subjects: 24 }
    }
}

/// The 10-table TPC-W schema.
pub fn schema() -> Schema {
    use ValueType::*;
    Schema::new(vec![
        TableSchema::new(
            "CUSTOMER",
            &[
                ("C_ID", Int),
                ("C_UNAME", Str),
                ("C_FNAME", Str),
                ("C_LNAME", Str),
                ("C_ADDR_ID", Int),
                ("C_BALANCE", Float),
                ("C_LOGIN", Int),
            ],
            &["C_ID"],
        ),
        TableSchema::new(
            "ADDRESS",
            &[("ADDR_ID", Int), ("ADDR_STREET", Str), ("ADDR_CITY", Str), ("ADDR_CO_ID", Int)],
            &["ADDR_ID"],
        ),
        TableSchema::new("COUNTRY", &[("CO_ID", Int), ("CO_NAME", Str)], &["CO_ID"]),
        TableSchema::new(
            "AUTHOR",
            &[("A_ID", Int), ("A_FNAME", Str), ("A_LNAME", Str)],
            &["A_ID"],
        )
        .with_index("A_LNAME"),
        TableSchema::new("SUBJECTS", &[("SUB_ID", Int), ("SUB_NAME", Str)], &["SUB_ID"]),
        TableSchema::new(
            "ITEM",
            &[
                ("I_ID", Int),
                ("I_TITLE", Str),
                ("I_A_ID", Int),
                ("I_SUBJECT", Int),
                ("I_COST", Float),
                ("I_STOCK", Int),
                ("I_TOTAL_SOLD", Int),
                ("I_PUB_DATE", Int),
            ],
            &["I_ID"],
        )
        .with_index("I_SUBJECT")
        // Stock can never go below zero (the bounded-apply check aborts a
        // violating decrement locally); this is what lets the confluence
        // pass prove adminRestock's increment coordination-free.
        .with_nonnegative("I_STOCK"),
        TableSchema::new(
            "ORDERS",
            &[
                ("O_ID", Int),
                ("O_C_ID", Int),
                ("O_DATE", Int),
                ("O_TOTAL", Float),
                ("O_STATUS", Str),
            ],
            &["O_ID"],
        )
        .with_index("O_C_ID"),
        TableSchema::new(
            "ORDER_LINE",
            &[("OL_O_ID", Int), ("OL_SEQ", Int), ("OL_I_ID", Int), ("OL_QTY", Int)],
            &["OL_O_ID", "OL_SEQ"],
        ),
        TableSchema::new(
            "CC_XACTS",
            &[("CX_O_ID", Int), ("CX_TYPE", Str), ("CX_AMOUNT", Float)],
            &["CX_O_ID"],
        ),
        TableSchema::new(
            "SHOPPING_CART",
            &[("SC_ID", Int), ("SC_TIME", Int), ("SC_TOTAL", Float)],
            &["SC_ID"],
        ),
        // NOTE: the paper counts 10 tables; SHOPPING_CART_LINE is added
        // by full_schema() as the composite-key line table.
    ])
}

/// Full schema including the cart-line table (11 physical tables; the
/// paper counts 10 — cart lines live inside the cart table there).
pub fn full_schema() -> Schema {
    let mut tables: Vec<TableSchema> = schema().tables().to_vec();
    tables.push(TableSchema::new(
        "SHOPPING_CART_LINE",
        &[
            ("SCL_SC_ID", ValueType::Int),
            ("SCL_I_ID", ValueType::Int),
            ("SCL_QTY", ValueType::Int),
        ],
        &["SCL_SC_ID", "SCL_I_ID"],
    ));
    Schema::new(tables)
}

/// Build the 20 TPC-W transaction templates with shopping-mix weights.
pub fn templates() -> Vec<TxnTemplate> {
    vec![
        // ---------- Local: shopping carts (partitioned by sid) ----------
        TxnTemplate::new(
            "createCart",
            &["sid", "now"],
            &[("ins", "INSERT INTO SHOPPING_CART (SC_ID, SC_TIME, SC_TOTAL) VALUES (?sid, ?now, 0.0)")],
            4.0,
        )
        .with_body(|ctx, args| ctx.exec("ins", args)),
        TxnTemplate::new(
            "doCart",
            &["sid", "iid", "qty", "now"],
            &[
                ("upd", "UPDATE SHOPPING_CART_LINE SET SCL_QTY = ?qty WHERE SCL_SC_ID = ?sid AND SCL_I_ID = ?iid"),
                ("ins", "INSERT INTO SHOPPING_CART_LINE (SCL_SC_ID, SCL_I_ID, SCL_QTY) VALUES (?sid, ?iid, ?qty)"),
                ("touch", "UPDATE SHOPPING_CART SET SC_TIME = ?now WHERE SC_ID = ?sid"),
            ],
            10.0,
        )
        .with_body(|ctx, args| {
            let r = ctx.exec("upd", args)?;
            if r.affected == 0 {
                // Not in the cart yet: insert (ignore a lost race on
                // duplicate keys — same cart, same item).
                let _ = ctx.exec("ins", args);
            }
            ctx.exec("touch", args)
        }),
        TxnTemplate::new(
            "getCart",
            &["sid"],
            &[
                ("lines", "SELECT SCL_I_ID, SCL_QTY FROM SHOPPING_CART_LINE WHERE SCL_SC_ID = ?sid"),
                ("cart", "SELECT SC_TOTAL FROM SHOPPING_CART WHERE SC_ID = ?sid"),
            ],
            8.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("cart", args)?;
            ctx.exec("lines", args)
        }),
        // ---------- Local: customers (partitioned by cid) ----------
        TxnTemplate::new(
            "createCustomer",
            &["cid", "uname"],
            &[
                ("addr", "INSERT INTO ADDRESS (ADDR_ID, ADDR_STREET, ADDR_CITY, ADDR_CO_ID) VALUES (?cid, 'street', 'city', 1)"),
                ("cust", "INSERT INTO CUSTOMER (C_ID, C_UNAME, C_FNAME, C_LNAME, C_ADDR_ID, C_BALANCE, C_LOGIN) VALUES (?cid, ?uname, 'f', 'l', ?cid, 0.0, 0)"),
            ],
            2.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("addr", args)?;
            ctx.exec("cust", args)
        }),
        TxnTemplate::new(
            "getCustomer",
            &["cid"],
            &[("q", "SELECT C_UNAME, C_FNAME, C_LNAME, C_BALANCE FROM CUSTOMER WHERE C_ID = ?cid")],
            6.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "refreshSession",
            &["cid"],
            &[("u", "UPDATE CUSTOMER SET C_LOGIN = C_LOGIN + 1 WHERE C_ID = ?cid")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        TxnTemplate::new(
            "getAddress",
            &["cid"],
            &[("q", "SELECT ADDR_STREET, ADDR_CITY, ADDR_CO_ID FROM ADDRESS WHERE ADDR_ID = ?cid")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getMostRecentOrder",
            &["cid"],
            &[("q", "SELECT O_ID, O_DATE, O_TOTAL, O_STATUS FROM ORDERS WHERE O_C_ID = ?cid ORDER BY O_DATE DESC LIMIT 1")],
            5.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getOrderDetail",
            &["oid"],
            &[
                ("o", "SELECT O_C_ID, O_DATE, O_TOTAL, O_STATUS FROM ORDERS WHERE O_ID = ?oid"),
                ("lines", "SELECT OL_SEQ, OL_I_ID, OL_QTY FROM ORDER_LINE WHERE OL_O_ID = ?oid"),
                ("cc", "SELECT CX_TYPE, CX_AMOUNT FROM CC_XACTS WHERE CX_O_ID = ?oid"),
            ],
            4.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("o", args)?;
            ctx.exec("lines", args)?;
            ctx.exec("cc", args)
        }),
        TxnTemplate::new(
            "getItem",
            &["iid"],
            &[("q", "SELECT I_TITLE, I_A_ID, I_COST, I_STOCK FROM ITEM WHERE I_ID = ?iid")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        // ---------- Global: ordering + administration ----------
        TxnTemplate::new(
            "buyConfirm",
            &["sid", "cid", "oid", "now"],
            &[
                ("lines", "SELECT SCL_I_ID, SCL_QTY FROM SHOPPING_CART_LINE WHERE SCL_SC_ID = ?sid"),
                ("order", "INSERT INTO ORDERS (O_ID, O_C_ID, O_DATE, O_TOTAL, O_STATUS) VALUES (?oid, ?cid, ?now, ?derived_total, 'PENDING')"),
                ("oline", "INSERT INTO ORDER_LINE (OL_O_ID, OL_SEQ, OL_I_ID, OL_QTY) VALUES (?oid, ?derived_seq, ?derived_iid, ?derived_qty)"),
                ("stock", "UPDATE ITEM SET I_STOCK = I_STOCK - ?derived_qty, I_TOTAL_SOLD = I_TOTAL_SOLD + ?derived_qty WHERE I_ID = ?derived_iid"),
                ("cc", "INSERT INTO CC_XACTS (CX_O_ID, CX_TYPE, CX_AMOUNT) VALUES (?oid, 'VISA', ?derived_total)"),
                ("clear", "DELETE FROM SHOPPING_CART_LINE WHERE SCL_SC_ID = ?sid"),
                ("cart", "UPDATE SHOPPING_CART SET SC_TOTAL = 0.0 WHERE SC_ID = ?sid"),
            ],
            10.0,
        )
        .with_body(|ctx, args| {
            let lines = ctx.exec("lines", args)?;
            let mut b = args.clone();
            let mut total = 0.0f64;
            for (seq, line) in lines.iter().enumerate() {
                let iid = line[0].clone();
                let qty = line[1].as_int().unwrap_or(1).max(1);
                total += qty as f64;
                b.insert("derived_seq".into(), Value::Int(seq as i64));
                b.insert("derived_iid".into(), iid);
                b.insert("derived_qty".into(), Value::Int(qty));
                b.insert("derived_total".into(), Value::Float(total));
                ctx.exec("oline", &b)?;
                ctx.exec("stock", &b)?;
            }
            b.insert("derived_total".into(), Value::Float(total));
            ctx.exec("order", &b)?;
            ctx.exec("cc", &b)?;
            ctx.exec("clear", &b)?;
            ctx.exec("cart", &b)
        }),
        TxnTemplate::new(
            "adminRestock",
            &["iid", "q"],
            &[("u", "UPDATE ITEM SET I_STOCK = I_STOCK + ?q WHERE I_ID = ?iid")],
            1.0,
        )
        .with_nonneg_param("q")
        .with_body(|ctx, args| ctx.exec("u", args)),
        TxnTemplate::new(
            "adminUpdateItem",
            &["iid", "cost", "now"],
            &[("u", "UPDATE ITEM SET I_COST = ?cost, I_PUB_DATE = ?now WHERE I_ID = ?iid")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        // Multi-partition searches: forced global (paper §6).
        TxnTemplate::new(
            "getBestSellers",
            &[],
            &[("q", "SELECT I_ID, I_TITLE, I_TOTAL_SOLD FROM ITEM ORDER BY I_TOTAL_SOLD DESC LIMIT 50")],
            13.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getNewProducts",
            &["subject"],
            &[("q", "SELECT I_ID, I_TITLE, I_PUB_DATE FROM ITEM WHERE I_SUBJECT = ?subject ORDER BY I_PUB_DATE DESC LIMIT 50")],
            14.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        // ---------- Commutative: immutable reference data ----------
        TxnTemplate::new(
            "getCountries",
            &[],
            &[("q", "SELECT CO_ID, CO_NAME FROM COUNTRY ORDER BY CO_NAME LIMIT 100")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getCountry",
            &["co"],
            &[("q", "SELECT CO_NAME FROM COUNTRY WHERE CO_ID = ?co")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getAuthor",
            &["aid"],
            &[("q", "SELECT A_FNAME, A_LNAME FROM AUTHOR WHERE A_ID = ?aid")],
            4.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "searchByAuthor",
            &["lname"],
            &[("q", "SELECT A_ID, A_FNAME FROM AUTHOR WHERE A_LNAME = ?lname")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getSubjects",
            &[],
            &[("q", "SELECT SUB_ID, SUB_NAME FROM SUBJECTS ORDER BY SUB_ID LIMIT 50")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
    ]
}

/// Analyze TPC-W with the full pipeline, including the
/// invariant-confluence pass: the administrative writers (restock,
/// item update) become coordination-free, then the paper's forced-global
/// searches apply.
pub fn analyzed() -> AnalyzedApp {
    let spec = AppSpec { name: "tpcw".into(), schema: full_schema(), txns: templates() };
    let mut app = AnalyzedApp::analyze_confluent(spec);
    app.force_global("getBestSellers");
    app.force_global("getNewProducts");
    app
}

/// The conflict-only classification — exactly the paper's Table 1 row
/// (10 L / 5 G / 5 C). Kept for the paper pins and the bench's
/// `--no-confluence` comparison.
pub fn analyzed_no_confluence() -> AnalyzedApp {
    let spec = AppSpec { name: "tpcw".into(), schema: full_schema(), txns: templates() };
    let mut app = AnalyzedApp::analyze(spec);
    app.force_global("getBestSellers");
    app.force_global("getNewProducts");
    app
}

/// Seed a server database at the given scale.
pub fn seed(db: &Db, scale: TpcwScale) {
    // Prepare once per statement; the loader is itself a hot path at
    // full scale (one insert per row).
    let exec = |p: &crate::db::Prepared, pairs: &[(&str, Value)]| {
        db.exec_auto_prepared(p, &p.bind_pairs(pairs).unwrap()).unwrap();
    };
    let mut rng = Rng::new(0x79C3u64);
    let ins = db.prepare_sql("INSERT INTO COUNTRY (CO_ID, CO_NAME) VALUES (?i, ?n)").unwrap();
    for co in 0..scale.countries {
        exec(
            &ins,
            &[("i", Value::Int(co)), ("n", Value::Str(format!("country{co}")))],
        );
    }
    let ins = db.prepare_sql("INSERT INTO SUBJECTS (SUB_ID, SUB_NAME) VALUES (?i, ?n)").unwrap();
    for s in 0..scale.subjects {
        exec(
            &ins,
            &[("i", Value::Int(s)), ("n", Value::Str(format!("subject{s}")))],
        );
    }
    let ins =
        db.prepare_sql("INSERT INTO AUTHOR (A_ID, A_FNAME, A_LNAME) VALUES (?i, ?f, ?l)").unwrap();
    for a in 0..scale.authors {
        exec(
            &ins,
            &[
                ("i", Value::Int(a)),
                ("f", Value::Str(format!("first{a}"))),
                ("l", Value::Str(format!("last{}", a % 37))),
            ],
        );
    }
    let ins = db
        .prepare_sql("INSERT INTO ITEM (I_ID, I_TITLE, I_A_ID, I_SUBJECT, I_COST, I_STOCK, I_TOTAL_SOLD, I_PUB_DATE) VALUES (?i, ?t, ?a, ?s, ?c, ?st, 0, ?d)")
        .unwrap();
    for i in 0..scale.items {
        exec(
            &ins,
            &[
                ("i", Value::Int(i)),
                ("t", Value::Str(format!("book{i}"))),
                ("a", Value::Int(i % scale.authors)),
                ("s", Value::Int(i % scale.subjects)),
                ("c", Value::Float(5.0 + rng.f64() * 50.0)),
                ("st", Value::Int(500 + rng.range(0, 500) as i64)),
                ("d", Value::Int(rng.range(0, 10_000) as i64)),
            ],
        );
    }
    let ins_addr = db
        .prepare_sql("INSERT INTO ADDRESS (ADDR_ID, ADDR_STREET, ADDR_CITY, ADDR_CO_ID) VALUES (?i, 's', 'c', ?co)")
        .unwrap();
    let ins_cust = db
        .prepare_sql("INSERT INTO CUSTOMER (C_ID, C_UNAME, C_FNAME, C_LNAME, C_ADDR_ID, C_BALANCE, C_LOGIN) VALUES (?i, ?u, 'f', 'l', ?i, 0.0, 0)")
        .unwrap();
    for c in 0..scale.customers {
        exec(
            &ins_addr,
            &[("i", Value::Int(c)), ("co", Value::Int(c % scale.countries))],
        );
        exec(
            &ins_cust,
            &[("i", Value::Int(c)), ("u", Value::Str(format!("user{c}")))],
        );
    }
}

/// Shopping-mix operation generator with site-affine ids.
pub struct TpcwGenerator {
    scale: TpcwScale,
    /// Template indices resolved once.
    idx: std::collections::HashMap<String, usize>,
    weights: Vec<f64>,
    /// Per-site monotonically increasing id bases (server-specific ids).
    next_cart: Vec<i64>,
    next_customer: Vec<i64>,
    next_order: Vec<i64>,
    route_helper: AnalyzedApp,
}

impl TpcwGenerator {
    pub fn new(app: &AnalyzedApp, scale: TpcwScale, max_sites: usize) -> Self {
        let idx = app
            .spec
            .txns
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        let weights = app.spec.txns.iter().map(|t| t.weight).collect();
        TpcwGenerator {
            scale,
            idx,
            weights,
            next_cart: vec![1_000_000; max_sites],
            next_customer: vec![2_000_000; max_sites],
            next_order: vec![3_000_000; max_sites],
            route_helper: app.clone(),
        }
    }

    fn t(&self, name: &str) -> usize {
        self.idx[name]
    }

    /// Stagger fresh id bases so concurrent generator instances (one per
    /// client thread) never collide on cart/customer/order ids.
    pub fn with_stream(mut self, stream: u64) -> Self {
        let off = (stream as i64) * 50_000_000;
        for v in self
            .next_cart
            .iter_mut()
            .chain(self.next_customer.iter_mut())
            .chain(self.next_order.iter_mut())
        {
            *v += off;
        }
        self
    }

    /// Fresh id routed to the site's server.
    fn fresh_id(&mut self, counter: &mut Vec<i64>, site: usize, n: usize) -> Value
    where
        Self: Sized,
    {
        let base = counter[site];
        counter[site] += 1;
        self.route_helper.value_routing_to(base, site % n, n)
    }
}

fn b(pairs: Vec<(&str, Value)>) -> Bindings {
    pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

impl OpGenerator for TpcwGenerator {
    fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
        let txn = rng.weighted(&self.weights);
        let name = self.route_helper.spec.txns[txn].name.clone();
        // Site-affine existing ids: ids previously created at this site
        // (approximated by sampling the site's residue class).
        let exist_cart = {
            let base = 1_000_000 + rng.range(0, 10_000) as i64;
            self.route_helper.value_routing_to(base, site % n, n)
        };
        let exist_customer = {
            let base = 2_000_000 + rng.range(0, 10_000) as i64;
            self.route_helper.value_routing_to(base, site % n, n)
        };
        let exist_order = {
            let base = 3_000_000 + rng.range(0, 10_000) as i64;
            self.route_helper.value_routing_to(base, site % n, n)
        };
        let now = Value::Int(rng.range(0, 1_000_000) as i64);
        let iid = Value::Int(rng.range(0, self.scale.items as usize) as i64);
        let args = match name.as_str() {
            "createCart" => {
                let mut c = self.next_cart.clone();
                let sid = self.fresh_id(&mut c, site, n);
                self.next_cart = c;
                b(vec![("sid", sid), ("now", now)])
            }
            "doCart" => b(vec![
                ("sid", exist_cart),
                ("iid", iid),
                ("qty", Value::Int(1 + rng.range(0, 5) as i64)),
                ("now", now),
            ]),
            "getCart" => b(vec![("sid", exist_cart)]),
            "createCustomer" => {
                let mut c = self.next_customer.clone();
                let cid = self.fresh_id(&mut c, site, n);
                self.next_customer = c;
                let uname = Value::Str(format!("u{}", cid.as_int().unwrap_or(0)));
                b(vec![("cid", cid), ("uname", uname)])
            }
            "getCustomer" | "refreshSession" | "getAddress" | "getMostRecentOrder" => {
                // Mix of seeded and created customers.
                let cid = if rng.chance(0.5) {
                    Value::Int(rng.range(0, self.scale.customers as usize) as i64)
                } else {
                    exist_customer
                };
                b(vec![("cid", cid)])
            }
            "getOrderDetail" => b(vec![("oid", exist_order)]),
            "getItem" => b(vec![("iid", iid)]),
            "buyConfirm" => {
                let mut c = self.next_order.clone();
                let oid = self.fresh_id(&mut c, site, n);
                self.next_order = c;
                b(vec![("sid", exist_cart), ("cid", exist_customer), ("oid", oid), ("now", now)])
            }
            "adminRestock" => b(vec![("iid", iid), ("q", Value::Int(50))]),
            "adminUpdateItem" => {
                b(vec![("iid", iid), ("cost", Value::Float(9.99)), ("now", now)])
            }
            "getNewProducts" => {
                b(vec![("subject", Value::Int(rng.range(0, self.scale.subjects as usize) as i64))])
            }
            "getCountry" => {
                b(vec![("co", Value::Int(rng.range(0, self.scale.countries as usize) as i64))])
            }
            "getAuthor" => {
                b(vec![("aid", Value::Int(rng.range(0, self.scale.authors as usize) as i64))])
            }
            "searchByAuthor" => {
                b(vec![("lname", Value::Str(format!("last{}", rng.range(0, 37))))])
            }
            // getBestSellers, getCountries, getSubjects: no parameters.
            _ => Bindings::new(),
        };
        let _ = self.t("createCart"); // keep idx used
        Operation { txn, args }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::OpClass;
    use crate::sqlir::parse_statement;

    #[test]
    fn classification_matches_paper_table1() {
        let app = analyzed_no_confluence();
        let (l, g, c, lg, cf, ro, total) = app.table1_row();
        assert_eq!(total, 20, "TPC-W has 20 transactions");
        assert_eq!(l, 10, "10 local (paper Table 1): {:?}", names_by_class(&app));
        assert_eq!(g, 5, "5 global: {:?}", names_by_class(&app));
        assert_eq!(c, 5, "5 commutative: {:?}", names_by_class(&app));
        assert_eq!(lg, 0, "TPC-W uses no double-key scheme");
        assert_eq!(cf, 0, "conflict-only pipeline never emits Confluent");
        assert_eq!(ro, 13, "13 read-only templates");
    }

    #[test]
    fn confluence_widens_the_coordination_free_class() {
        let app = analyzed();
        let (l, g, c, lg, cf, ro, total) = app.table1_row();
        assert_eq!(total, 20);
        assert_eq!(
            (l, g, c, lg, cf),
            (10, 3, 5, 0, 2),
            "classes: {:?}",
            names_by_class(&app)
        );
        assert_eq!(ro, 13);
        // Strictly more coordination-free operations than conflict-only.
        let (l0, _, c0, _, cf0, _, _) = analyzed_no_confluence().table1_row();
        assert_eq!(cf0, 0);
        assert!(l + c + cf > l0 + c0, "{} vs {}", l + c + cf, l0 + c0);
        // The administrative writers are the promoted ones: restock is a
        // safe delta against NonNegative(I_STOCK); the item update's
        // assignments stay covered by iid routing and only its readers
        // (consistent-prefix) made it global before.
        for name in ["adminRestock", "adminUpdateItem"] {
            let t = app.spec.txn_index(name).unwrap();
            assert_eq!(app.classification.classes[t], OpClass::Confluent, "{name}");
        }
        // buyConfirm still coordinates: it deletes cart lines and
        // decrements the NonNegative stock column.
        let t = app.spec.txn_index("buyConfirm").unwrap();
        assert_eq!(app.classification.classes[t], OpClass::Global);
    }

    fn names_by_class(app: &AnalyzedApp) -> Vec<(String, OpClass)> {
        app.spec
            .txns
            .iter()
            .zip(&app.classification.classes)
            .map(|(t, c)| (t.name.clone(), c.clone()))
            .collect()
    }

    #[test]
    fn carts_partition_by_sid_customers_by_cid() {
        let app = analyzed();
        let t = app.spec.txn_index("doCart").unwrap();
        let k = app.partitioning.choice[t].unwrap();
        assert_eq!(app.spec.txns[t].params[k], "sid");
        let t = app.spec.txn_index("getCustomer").unwrap();
        let k = app.classification.routing_params[t][0];
        assert_eq!(app.spec.txns[t].params[k], "cid");
    }

    #[test]
    fn frequencies_match_paper() {
        let app = analyzed_no_confluence();
        let total: f64 = app.spec.txns.iter().map(|t| t.weight).sum();
        let freq = |class: OpClass| -> f64 {
            app.spec
                .txns
                .iter()
                .zip(&app.classification.classes)
                .filter(|(_, c)| **c == class)
                .map(|(t, _)| t.weight)
                .sum::<f64>()
                / total
        };
        let l = freq(OpClass::Local);
        let g = freq(OpClass::Global);
        let c = freq(OpClass::Commutative);
        assert!((l - 0.47).abs() < 0.02, "L freq {l}");
        assert!((g - 0.39).abs() < 0.02, "G freq {g}");
        assert!((c - 0.14).abs() < 0.02, "C freq {c}");
        // ~30% writes (shopping mix).
        let w: f64 = app
            .spec
            .txns
            .iter()
            .filter(|t| !t.is_read_only())
            .map(|t| t.weight)
            .sum::<f64>()
            / total;
        assert!((w - 0.30).abs() < 0.03, "write freq {w}");
    }

    #[test]
    fn seed_and_execute_key_transactions() {
        let app = analyzed();
        let db = Db::new(app.spec.schema.clone());
        seed(&db, TpcwScale { items: 50, customers: 20, authors: 10, countries: 5, subjects: 4 });
        assert_eq!(db.row_count("ITEM"), 50);

        let run = |name: &str, args: Bindings| -> crate::db::ResultSet {
            let t = app.spec.txn_index(name).unwrap();
            let tpl = &app.spec.txns[t];
            let stmts = tpl.prepared_map(&app.spec.schema);
            let mut h = db.begin();
            let mut ctx = crate::workload::spec::TxnCtx::new(&mut h, &stmts);
            let r = (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
            h.commit().unwrap();
            r
        };

        run("createCart", b(vec![("sid", Value::Int(100)), ("now", Value::Int(1))]));
        run(
            "doCart",
            b(vec![
                ("sid", Value::Int(100)),
                ("iid", Value::Int(3)),
                ("qty", Value::Int(2)),
                ("now", Value::Int(2)),
            ]),
        );
        let cart = run("getCart", b(vec![("sid", Value::Int(100))]));
        assert_eq!(cart.len(), 1);
        // Buy: stock of item 3 decreases by 2, order materializes.
        let before = db
            .exec_auto(
                &parse_statement("SELECT I_STOCK FROM ITEM WHERE I_ID = 3").unwrap(),
                &Bindings::new(),
            )
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        run(
            "buyConfirm",
            b(vec![
                ("sid", Value::Int(100)),
                ("cid", Value::Int(5)),
                ("oid", Value::Int(900)),
                ("now", Value::Int(3)),
            ]),
        );
        let after = db
            .exec_auto(
                &parse_statement("SELECT I_STOCK FROM ITEM WHERE I_ID = 3").unwrap(),
                &Bindings::new(),
            )
            .unwrap()
            .scalar()
            .unwrap()
            .as_int()
            .unwrap();
        assert_eq!(after, before - 2);
        assert_eq!(db.row_count("ORDERS"), 1);
        assert_eq!(db.row_count("CC_XACTS"), 1);
        // Cart emptied (length checks never materialize values).
        let cart = run("getCart", b(vec![("sid", Value::Int(100))]));
        assert!(cart.is_empty());
        // Order readable by detail view.
        let detail = run("getOrderDetail", b(vec![("oid", Value::Int(900))]));
        assert_eq!(detail.len(), 1);
    }

    #[test]
    fn generator_produces_valid_routable_ops() {
        let app = analyzed();
        let mut g = TpcwGenerator::new(&app, TpcwScale::default(), 4);
        let mut rng = Rng::new(5);
        let mut class_counts = [0usize; 3]; // local-ish, global, any
        for i in 0..2000 {
            let op = g.next_op(&mut rng, i % 4, 4);
            assert!(op.txn < 20);
            match app.route(&op, 4) {
                crate::workload::analyzed::Route::LocalAt(s) => {
                    assert!(s < 4);
                    class_counts[0] += 1;
                }
                // Confluent ops execute immediately like locals.
                crate::workload::analyzed::Route::ConfluentAt(s) => {
                    assert!(s < 4);
                    class_counts[0] += 1;
                }
                crate::workload::analyzed::Route::GlobalAt(_) => class_counts[1] += 1,
                crate::workload::analyzed::Route::Any => class_counts[2] += 1,
            }
        }
        // Mix roughly L/G/C = 47/39/14.
        let total = 2000.0;
        assert!((class_counts[0] as f64 / total - 0.47).abs() < 0.08, "{class_counts:?}");
        assert!((class_counts[1] as f64 / total - 0.39).abs() < 0.08, "{class_counts:?}");
    }

    #[test]
    fn site_affinity_routes_local_ops_home() {
        let app = analyzed();
        let mut g = TpcwGenerator::new(&app, TpcwScale::default(), 4);
        let mut rng = Rng::new(9);
        let mut home = 0;
        let mut total = 0;
        for _ in 0..1000 {
            let site = rng.range(0, 4);
            let op = g.next_op(&mut rng, site, 4);
            if app.spec.txns[op.txn].name == "doCart" {
                total += 1;
                if let crate::workload::analyzed::Route::LocalAt(s) = app.route(&op, 4) {
                    if s == site {
                        home += 1;
                    }
                }
            }
        }
        assert!(total > 30);
        assert_eq!(home, total, "cart ids must route to the client's site");
    }
}
