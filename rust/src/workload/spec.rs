//! Application specifications: transaction templates.
//!
//! A [`TxnTemplate`] is the unit the paper's static analysis operates on:
//! a named procedure with input parameters and the set of SQL statements
//! it *may* execute (collected over all execution paths, per §3.1). The
//! template additionally carries a procedural `body` that the runtime
//! invokes to actually execute an operation; the body may only issue the
//! declared statements, so the analysis surface and the executed code
//! cannot drift apart.

use crate::db::{Bindings, Prepared, ResultSet, TxnError, TxnHandle};
use crate::sqlir::{parse_statement, Stmt};
use std::collections::HashMap;
use std::sync::Arc;

/// Statements of one template compiled against a schema (prepare once,
/// execute for the lifetime of the deployment/simulation).
pub type PreparedStmts = HashMap<String, Prepared>;

/// Reply returned to a client: the result of the operation. Borrowed
/// ([`ResultSet`] holds `Arc` row handles), so returning a read result
/// to the client clones no values.
pub type Reply = ResultSet;

/// Execution context handed to a transaction body: it can only execute
/// statements declared in its template, by name. Statements are
/// pre-compiled ([`Prepared`]); the per-call work is resolving the
/// name-keyed `binds` into positional slots.
pub struct TxnCtx<'a, 'b> {
    handle: &'b mut TxnHandle<'a>,
    stmts: &'b PreparedStmts,
}

impl<'a, 'b> TxnCtx<'a, 'b> {
    pub fn new(handle: &'b mut TxnHandle<'a>, stmts: &'b PreparedStmts) -> Self {
        TxnCtx { handle, stmts }
    }

    /// Execute a declared statement with the given bindings.
    pub fn exec(&mut self, stmt_name: &str, binds: &Bindings) -> Result<ResultSet, TxnError> {
        let prepared = self
            .stmts
            .get(stmt_name)
            .unwrap_or_else(|| panic!("transaction body uses undeclared statement {stmt_name:?}"));
        let slots = prepared.bind(binds).map_err(TxnError::Sql)?;
        self.handle.exec_prepared(prepared, &slots)
    }
}

/// Procedural glue executed inside one DBMS transaction.
pub type TxnBody =
    Arc<dyn Fn(&mut TxnCtx<'_, '_>, &Bindings) -> Result<Reply, TxnError> + Send + Sync>;

/// One transaction type of the application.
#[derive(Clone)]
pub struct TxnTemplate {
    pub name: String,
    /// Input parameter names (candidate partitioning parameters).
    pub params: Vec<String>,
    /// Every SQL statement the transaction may execute, keyed by name.
    pub stmts: Vec<(String, Stmt)>,
    /// Relative frequency in the workload mix (used as the cost weight in
    /// Algorithm 1 and to drive the generator).
    pub weight: f64,
    /// Procedural body; `None` for analysis-only templates.
    pub body: Option<TxnBody>,
    /// Weak (consistent-prefix) reads: this transaction's reads do not
    /// demand co-location with their writers — it observes its server's
    /// local prefix of the global order. Used for the paper's global
    /// multi-partition searches (RUBiS §6); such templates are normally
    /// combined with `Classification::force_global`.
    pub weak_reads: bool,
    /// Parameters the caller guarantees to bind to non-negative values
    /// (workload contract). The confluence pass uses this to prove a
    /// `SET c = c + ?p` delta safe against a declared `NonNegative{c}`
    /// invariant; the engine still validates the post-image at commit,
    /// so a violated promise aborts instead of corrupting state.
    pub nonneg_params: Vec<String>,
}

impl std::fmt::Debug for TxnTemplate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TxnTemplate")
            .field("name", &self.name)
            .field("params", &self.params)
            .field("stmts", &self.stmts.len())
            .field("weight", &self.weight)
            .finish()
    }
}

impl TxnTemplate {
    /// Build a template from SQL sources. Panics on parse errors — the
    /// templates are compiled into the binary, so this is a build bug.
    pub fn new(name: &str, params: &[&str], stmts: &[(&str, &str)], weight: f64) -> Self {
        let parsed = stmts
            .iter()
            .map(|(n, sql)| {
                let stmt = parse_statement(sql)
                    .unwrap_or_else(|e| panic!("template {name}/{n}: {e}\n  sql: {sql}"));
                (n.to_string(), stmt)
            })
            .collect();
        TxnTemplate {
            name: name.to_string(),
            params: params.iter().map(|s| s.to_string()).collect(),
            stmts: parsed,
            weight,
            body: None,
            weak_reads: false,
            nonneg_params: Vec::new(),
        }
    }

    /// Mark this template's reads as weak (see the field docs).
    pub fn with_weak_reads(mut self) -> Self {
        self.weak_reads = true;
        self
    }

    /// Declare that callers always bind `param` to a non-negative value
    /// (see the `nonneg_params` field docs).
    pub fn with_nonneg_param(mut self, param: &str) -> Self {
        assert!(
            self.params.iter().any(|p| p == param),
            "nonneg declaration on unknown param {param}"
        );
        self.nonneg_params.push(param.to_string());
        self
    }

    pub fn with_body(
        mut self,
        body: impl Fn(&mut TxnCtx<'_, '_>, &Bindings) -> Result<Reply, TxnError> + Send + Sync + 'static,
    ) -> Self {
        self.body = Some(Arc::new(body));
        self
    }

    pub fn with_weight(mut self, w: f64) -> Self {
        self.weight = w;
        self
    }

    /// A transaction is read-only iff all its declared statements are.
    pub fn is_read_only(&self) -> bool {
        self.stmts.iter().all(|(_, s)| s.is_read_only())
    }

    pub fn stmt_map(&self) -> HashMap<String, Stmt> {
        self.stmts.iter().cloned().collect()
    }

    /// Compile every declared statement against `schema` — the
    /// prepare-once side of the engine's prepared-execution pipeline.
    /// Panics on compile errors: templates are validated against their
    /// application schema at build time, so a failure is a build bug.
    pub fn prepared_map(&self, schema: &crate::catalog::Schema) -> PreparedStmts {
        self.stmts
            .iter()
            .map(|(n, s)| {
                let p = Prepared::compile(s, schema).unwrap_or_else(|e| {
                    panic!("template {}/{n}: {e}\n  sql: {s}", self.name)
                });
                (n.clone(), p)
            })
            .collect()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p == name)
    }
}

/// An application: schema + transaction templates (+ a human name).
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub name: String,
    pub schema: crate::catalog::Schema,
    pub txns: Vec<TxnTemplate>,
}

impl AppSpec {
    pub fn txn_index(&self, name: &str) -> Option<usize> {
        self.txns.iter().position(|t| t.name == name)
    }

    pub fn txn(&self, name: &str) -> &TxnTemplate {
        &self.txns[self.txn_index(name).unwrap_or_else(|| panic!("unknown txn {name}"))]
    }
}

/// A concrete operation: an invocation of a transaction template with
/// bound arguments (the paper's `createCart(5)`).
#[derive(Debug, Clone)]
pub struct Operation {
    /// Index into `AppSpec::txns`.
    pub txn: usize,
    pub args: Bindings,
}

impl Operation {
    /// The bound arguments in a canonical (name-sorted) order — the wire
    /// codec (`net::proto`) needs a deterministic parameter sequence, and
    /// `Bindings` is a hash map with no stable iteration order.
    pub fn canonical_args(&self) -> Vec<(&str, &crate::db::Value)> {
        let mut args: Vec<_> = self.args.iter().map(|(k, v)| (k.as_str(), v)).collect();
        args.sort_by_key(|&(k, _)| k);
        args
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Db, Value};

    fn mini_app() -> AppSpec {
        let schema = Schema::new(vec![TableSchema::new(
            "SC",
            &[("ID", ValueType::Int), ("QTY", ValueType::Int)],
            &["ID"],
        )]);
        let create = TxnTemplate::new(
            "createCart",
            &["sid"],
            &[("ins", "INSERT INTO SC (ID, QTY) VALUES (?sid, 0)")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("ins", args));
        AppSpec { name: "mini".into(), schema, txns: vec![create] }
    }

    #[test]
    fn template_parses_and_flags_read_only() {
        let app = mini_app();
        assert!(!app.txns[0].is_read_only());
        assert_eq!(app.txns[0].param_index("sid"), Some(0));
        let ro = TxnTemplate::new("q", &["x"], &[("s", "SELECT * FROM SC WHERE ID = ?x")], 1.0);
        assert!(ro.is_read_only());
    }

    #[test]
    fn body_executes_declared_statement() {
        let app = mini_app();
        let db = Db::new(app.schema.clone());
        let tpl = &app.txns[0];
        let mut handle = db.begin();
        let stmts = tpl.prepared_map(&app.schema);
        let mut ctx = TxnCtx::new(&mut handle, &stmts);
        let args: Bindings = [("sid".to_string(), Value::Int(7))].into_iter().collect();
        let r = (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
        assert_eq!(r.affected, 1);
        handle.commit().unwrap();
        assert_eq!(db.row_count("SC"), 1);
    }

    #[test]
    #[should_panic(expected = "undeclared statement")]
    fn undeclared_statement_panics() {
        let app = mini_app();
        let db = Db::new(app.schema.clone());
        let mut handle = db.begin();
        let stmts = HashMap::new();
        let mut ctx = TxnCtx::new(&mut handle, &stmts);
        let _ = ctx.exec("nope", &Bindings::new());
    }

    #[test]
    #[should_panic(expected = "template bad/x")]
    fn parse_error_panics_with_context() {
        TxnTemplate::new("bad", &[], &[("x", "SELEC * FORM T")], 1.0);
    }
}
