//! The RQ3 microbenchmark (paper §7.3): a synthetic workload with a
//! precisely controllable fraction of local operations and a fixed 5 ms
//! execution time per operation (local or global).

use crate::catalog::{Schema, TableSchema, ValueType};
use crate::db::{Db, Value};
use crate::util::Rng;
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::OpGenerator;
use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

/// Keys per server partition in the local table.
pub const LOCAL_KEYS: i64 = 10_000;
/// Shared global rows.
pub const GLOBAL_KEYS: i64 = 64;

pub fn schema() -> Schema {
    Schema::new(vec![
        TableSchema::new(
            "LOCAL_TAB",
            &[("K", ValueType::Int), ("V", ValueType::Int)],
            &["K"],
        ),
        TableSchema::new(
            "GLOBAL_TAB",
            &[("G", ValueType::Int), ("V", ValueType::Int)],
            &["G"],
        ),
    ])
}

pub fn templates() -> Vec<TxnTemplate> {
    vec![
        // Partitioned single-row update: local under Operation Partitioning.
        TxnTemplate::new(
            "localOp",
            &["k"],
            &[("u", "UPDATE LOCAL_TAB SET V = V + 1 WHERE K = ?k")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        // Derived-key update on the shared table: global (uncoverable).
        TxnTemplate::new(
            "globalOp",
            &["k"],
            &[("u", "UPDATE GLOBAL_TAB SET V = V + 1 WHERE G = ?derived_g")],
            1.0,
        )
        .with_body(|ctx, args| {
            let k = args.get("k").and_then(|v| v.as_int()).unwrap_or(0);
            let mut b = args.clone();
            b.insert("derived_g".to_string(), Value::Int(k.rem_euclid(GLOBAL_KEYS)));
            ctx.exec("u", &b)
        }),
    ]
}

pub fn analyzed() -> AnalyzedApp {
    let app = AnalyzedApp::analyze(AppSpec {
        name: "micro".into(),
        schema: schema(),
        txns: templates(),
    });
    debug_assert_eq!(*app.class(0), crate::analysis::OpClass::Local);
    debug_assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
    app
}

pub fn seed(db: &Db) {
    use crate::db::BindSlots;
    let lt = db.prepare_sql("INSERT INTO LOCAL_TAB (K, V) VALUES (?k, 0)").unwrap();
    let gt = db.prepare_sql("INSERT INTO GLOBAL_TAB (G, V) VALUES (?g, 0)").unwrap();
    for k in 0..LOCAL_KEYS {
        db.exec_auto_prepared(&lt, &BindSlots(vec![Value::Int(k)])).unwrap();
    }
    for g in 0..GLOBAL_KEYS {
        db.exec_auto_prepared(&gt, &BindSlots(vec![Value::Int(g)])).unwrap();
    }
}

/// Generator with an exact local-operation ratio. Local keys are
/// site-affine so local ops execute at the client's nearest server.
pub struct MicroGenerator {
    pub local_ratio: f64,
    route_helper: AnalyzedApp,
}

impl MicroGenerator {
    pub fn new(app: &AnalyzedApp, local_ratio: f64) -> Self {
        assert!((0.0..=1.0).contains(&local_ratio));
        MicroGenerator { local_ratio, route_helper: app.clone() }
    }
}

impl OpGenerator for MicroGenerator {
    fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
        if rng.chance(self.local_ratio) {
            let base = rng.range(0, LOCAL_KEYS as usize) as i64;
            let k = self.route_helper.value_routing_to(base, site % n.max(1), n);
            Operation {
                txn: 0,
                args: [("k".to_string(), k)].into_iter().collect(),
            }
        } else {
            let k = Value::Int(rng.range(0, LOCAL_KEYS as usize) as i64);
            Operation {
                txn: 1,
                args: [("k".to_string(), k)].into_iter().collect(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::OpClass;
    use crate::db::Bindings;
    use crate::sqlir::parse_statement;
    use crate::workload::analyzed::Route;

    #[test]
    fn classification_is_one_local_one_global() {
        let app = analyzed();
        assert_eq!(*app.class(0), OpClass::Local);
        assert_eq!(*app.class(1), OpClass::Global);
    }

    #[test]
    fn ratio_is_respected() {
        let app = analyzed();
        let mut g = MicroGenerator::new(&app, 0.7);
        let mut rng = Rng::new(1);
        let mut local = 0;
        for _ in 0..10_000 {
            let op = g.next_op(&mut rng, 0, 3);
            if op.txn == 0 {
                local += 1;
            }
        }
        let frac = local as f64 / 10_000.0;
        assert!((frac - 0.7).abs() < 0.02, "{frac}");
    }

    #[test]
    fn local_ops_route_to_client_site() {
        let app = analyzed();
        let mut g = MicroGenerator::new(&app, 1.0);
        let mut rng = Rng::new(2);
        for site in 0..3 {
            for _ in 0..100 {
                let op = g.next_op(&mut rng, site, 3);
                assert_eq!(app.route(&op, 3), Route::LocalAt(site));
            }
        }
    }

    #[test]
    fn bodies_execute() {
        let app = analyzed();
        let db = Db::new(app.spec.schema.clone());
        seed(&db);
        for (txn, k) in [(0usize, 5i64), (1, 9)] {
            let tpl = &app.spec.txns[txn];
            let stmts = tpl.prepared_map(&app.spec.schema);
            let mut h = db.begin();
            let mut ctx = crate::workload::spec::TxnCtx::new(&mut h, &stmts);
            let args: Bindings = [("k".to_string(), Value::Int(k))].into_iter().collect();
            (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
            h.commit().unwrap();
        }
        let q = parse_statement("SELECT V FROM LOCAL_TAB WHERE K = 5").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
        let q = parse_statement("SELECT V FROM GLOBAL_TAB WHERE G = 9").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
    }
}
