//! The RQ3 microbenchmark (paper §7.3): a synthetic workload with a
//! precisely controllable fraction of local operations and a fixed 5 ms
//! execution time per operation (local or global) — plus the *drift*
//! microbenchmark behind the live-routing-epoch experiments
//! ([`drift_analyzed`], [`DriftGen`]): a workload whose optimal
//! partitioning parameter flips as the hot side moves between tables.

use crate::analysis::drift::DriftConfig;
use crate::catalog::{Schema, TableSchema, ValueType};
use crate::db::{Db, Value};
use crate::util::{Rng, VTime};
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::OpGenerator;
use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

/// Keys per server partition in the local table.
pub const LOCAL_KEYS: i64 = 10_000;
/// Shared global rows.
pub const GLOBAL_KEYS: i64 = 64;

pub fn schema() -> Schema {
    Schema::new(vec![
        TableSchema::new(
            "LOCAL_TAB",
            &[("K", ValueType::Int), ("V", ValueType::Int)],
            &["K"],
        ),
        TableSchema::new(
            "GLOBAL_TAB",
            &[("G", ValueType::Int), ("V", ValueType::Int)],
            &["G"],
        ),
    ])
}

pub fn templates() -> Vec<TxnTemplate> {
    vec![
        // Partitioned single-row update: local under Operation Partitioning.
        TxnTemplate::new(
            "localOp",
            &["k"],
            &[("u", "UPDATE LOCAL_TAB SET V = V + 1 WHERE K = ?k")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        // Derived-key update on the shared table: global (uncoverable).
        TxnTemplate::new(
            "globalOp",
            &["k"],
            &[("u", "UPDATE GLOBAL_TAB SET V = V + 1 WHERE G = ?derived_g")],
            1.0,
        )
        .with_body(|ctx, args| {
            let k = args.get("k").and_then(|v| v.as_int()).unwrap_or(0);
            let mut b = args.clone();
            b.insert("derived_g".to_string(), Value::Int(k.rem_euclid(GLOBAL_KEYS)));
            ctx.exec("u", &b)
        }),
    ]
}

pub fn analyzed() -> AnalyzedApp {
    let app = AnalyzedApp::analyze(AppSpec {
        name: "micro".into(),
        schema: schema(),
        txns: templates(),
    });
    debug_assert_eq!(*app.class(0), crate::analysis::OpClass::Local);
    debug_assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
    app
}

pub fn seed(db: &Db) {
    use crate::db::BindSlots;
    let lt = db.prepare_sql("INSERT INTO LOCAL_TAB (K, V) VALUES (?k, 0)").unwrap();
    let gt = db.prepare_sql("INSERT INTO GLOBAL_TAB (G, V) VALUES (?g, 0)").unwrap();
    for k in 0..LOCAL_KEYS {
        db.exec_auto_prepared(&lt, &BindSlots(vec![Value::Int(k)])).unwrap();
    }
    for g in 0..GLOBAL_KEYS {
        db.exec_auto_prepared(&gt, &BindSlots(vec![Value::Int(g)])).unwrap();
    }
}

/// Generator with an exact local-operation ratio. Local keys are
/// site-affine so local ops execute at the client's nearest server.
pub struct MicroGenerator {
    pub local_ratio: f64,
    route_helper: AnalyzedApp,
}

impl MicroGenerator {
    pub fn new(app: &AnalyzedApp, local_ratio: f64) -> Self {
        assert!((0.0..=1.0).contains(&local_ratio));
        MicroGenerator { local_ratio, route_helper: app.clone() }
    }
}

impl OpGenerator for MicroGenerator {
    fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
        if rng.chance(self.local_ratio) {
            let base = rng.range(0, LOCAL_KEYS as usize) as i64;
            let k = self.route_helper.value_routing_to(base, site % n.max(1), n);
            Operation {
                txn: 0,
                args: [("k".to_string(), k)].into_iter().collect(),
            }
        } else {
            let k = Value::Int(rng.range(0, LOCAL_KEYS as usize) as i64);
            Operation {
                txn: 1,
                args: [("k".to_string(), k)].into_iter().collect(),
            }
        }
    }
}

// ---------------------------------------------------------------------
// The drift microbenchmark: adaptive-vs-static routing under a moving
// hot set.
// ---------------------------------------------------------------------

/// Keys per drift table.
pub const DRIFT_KEYS: i64 = 2048;

/// Three single-key tables. `A_TAB` and `B_TAB` take independent update
/// streams; `C_TAB` is written *only* by the coupling `move` template
/// (always token-ordered in every epoch), so its replicas must converge
/// bit-identically — the convergence witness for epoch-switch tests.
pub fn drift_schema() -> Schema {
    Schema::new(vec![
        TableSchema::new("A_TAB", &[("K", ValueType::Int), ("V", ValueType::Int)], &["K"]),
        TableSchema::new("B_TAB", &[("K", ValueType::Int), ("V", ValueType::Int)], &["K"]),
        TableSchema::new("C_TAB", &[("K", ValueType::Int), ("V", ValueType::Int)], &["K"]),
    ])
}

/// The trade-off the controller navigates:
///
/// * `move(a, b)` writes both tables (plus the witness) — its self
///   conflict needs `a` *and* `b` covered at once, so it is Global under
///   every pinned assignment; its *choice* decides who else gets to be
///   local.
/// * `aupd(a)` is Local iff `move` pins on `a`; `bupd(b)` is Local iff
///   `move` pins on `b`. Static weights (5:1 toward `aupd`) make epoch 0
///   pin `a`; when the observed mix drifts toward `bupd`, the optimal
///   pin flips to `b`.
pub fn drift_templates() -> Vec<TxnTemplate> {
    vec![
        TxnTemplate::new(
            "move",
            &["a", "b"],
            &[
                ("ua", "UPDATE A_TAB SET V = V + 1 WHERE K = ?a"),
                ("ub", "UPDATE B_TAB SET V = V + 1 WHERE K = ?b"),
                ("uc", "UPDATE C_TAB SET V = V + 1 WHERE K = ?a"),
            ],
            1.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("ua", args)?;
            ctx.exec("ub", args)?;
            ctx.exec("uc", args)
        }),
        TxnTemplate::new(
            "aupd",
            &["a"],
            &[("u", "UPDATE A_TAB SET V = V + 1 WHERE K = ?a")],
            5.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        TxnTemplate::new(
            "bupd",
            &["b"],
            &[("u", "UPDATE B_TAB SET V = V + 1 WHERE K = ?b")],
            1.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
    ]
}

/// Analyze the drift app. `move` is forced Global so the static
/// classification agrees with what every pinned epoch says about it
/// (the growth classifier would call it LocalGlobal), keeping the
/// replicated-table set identical across epochs.
pub fn drift_analyzed() -> AnalyzedApp {
    let mut app = AnalyzedApp::analyze(AppSpec {
        name: "drift".into(),
        schema: drift_schema(),
        txns: drift_templates(),
    });
    app.force_global("move");
    debug_assert_eq!(*app.class(0), crate::analysis::OpClass::Global);
    debug_assert_eq!(app.partitioning.choice[0], Some(0), "epoch 0 must pin `move` on a");
    app
}

/// Seed all three drift tables with zeroed counters.
pub fn drift_seed(db: &Db) {
    use crate::db::BindSlots;
    for table in ["A_TAB", "B_TAB", "C_TAB"] {
        let ins = db.prepare_sql(&format!("INSERT INTO {table} (K, V) VALUES (?k, 0)")).unwrap();
        for k in 0..DRIFT_KEYS {
            db.exec_auto_prepared(&ins, &BindSlots(vec![Value::Int(k)])).unwrap();
        }
    }
}

/// Plays a [`DriftConfig`] schedule: the template mix (and the B-side
/// key band) is a pure function of the issuing client's rng stream and
/// virtual clock, so runs stay bit-identical at any thread or
/// client-group count.
pub struct DriftGen {
    pub cfg: DriftConfig,
}

impl DriftGen {
    pub fn new(cfg: DriftConfig) -> Self {
        DriftGen { cfg }
    }

    fn gen_at(&mut self, rng: &mut Rng, now: VTime) -> Operation {
        let t_s = now.as_secs_f64();
        if rng.chance(self.cfg.pivot_share) {
            let a = rng.range(0, DRIFT_KEYS as usize) as i64;
            let b = rng.range(0, DRIFT_KEYS as usize) as i64;
            Operation {
                txn: 0,
                args: [
                    ("a".to_string(), Value::Int(a)),
                    ("b".to_string(), Value::Int(b)),
                ]
                .into_iter()
                .collect(),
            }
        } else if rng.chance(self.cfg.b_share(t_s)) {
            let (lo, hi) = self.cfg.key_band(t_s, DRIFT_KEYS);
            let b = lo + rng.range(0, (hi - lo).max(1) as usize) as i64;
            Operation { txn: 2, args: [("b".to_string(), Value::Int(b))].into_iter().collect() }
        } else {
            let a = rng.range(0, DRIFT_KEYS as usize) as i64;
            Operation { txn: 1, args: [("a".to_string(), Value::Int(a))].into_iter().collect() }
        }
    }
}

impl OpGenerator for DriftGen {
    fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
        self.gen_at(rng, VTime::ZERO)
    }

    fn next_op_at(&mut self, rng: &mut Rng, _site: usize, _n: usize, now: VTime) -> Operation {
        self.gen_at(rng, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::OpClass;
    use crate::db::Bindings;
    use crate::sqlir::parse_statement;
    use crate::workload::analyzed::Route;

    #[test]
    fn classification_is_one_local_one_global() {
        let app = analyzed();
        assert_eq!(*app.class(0), OpClass::Local);
        assert_eq!(*app.class(1), OpClass::Global);
    }

    #[test]
    fn ratio_is_respected() {
        let app = analyzed();
        let mut g = MicroGenerator::new(&app, 0.7);
        let mut rng = Rng::new(1);
        let mut local = 0;
        for _ in 0..10_000 {
            let op = g.next_op(&mut rng, 0, 3);
            if op.txn == 0 {
                local += 1;
            }
        }
        let frac = local as f64 / 10_000.0;
        assert!((frac - 0.7).abs() < 0.02, "{frac}");
    }

    #[test]
    fn local_ops_route_to_client_site() {
        let app = analyzed();
        let mut g = MicroGenerator::new(&app, 1.0);
        let mut rng = Rng::new(2);
        for site in 0..3 {
            for _ in 0..100 {
                let op = g.next_op(&mut rng, site, 3);
                assert_eq!(app.route(&op, 3), Route::LocalAt(site));
            }
        }
    }

    #[test]
    fn drift_app_pins_flip_the_local_class() {
        let app = drift_analyzed();
        // Epoch 0 pins `move` on a: aupd local, bupd global.
        let e0 = app.epoch0();
        assert_eq!(e0.assignment[0], Some(0));
        assert_eq!(
            e0.classification.classes,
            vec![OpClass::Global, OpClass::Local, OpClass::Global]
        );
        // Repinning `move` on b flips which neighbour is local.
        let e1 = app.epoch_from(1, vec![Some(1), Some(0), Some(0)]);
        assert_eq!(
            e1.classification.classes,
            vec![OpClass::Global, OpClass::Global, OpClass::Local]
        );
        // Local homes never move across the switch: aupd routes by its
        // own key under both epochs (only its *class* changes).
        let op = Operation {
            txn: 1,
            args: [("a".to_string(), Value::Int(77))].into_iter().collect(),
        };
        let (r0, r1) = (e0.route_op(&app, &op, 3), e1.route_op(&app, &op, 3));
        let server_of = |r: Route| match r {
            Route::LocalAt(s) | Route::GlobalAt(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(server_of(r0), server_of(r1));
    }

    #[test]
    fn drift_gen_follows_the_schedule() {
        let cfg = DriftConfig::default(); // flash crowd at 10 s
        let mut g = DriftGen::new(cfg);
        let mut rng = Rng::new(7);
        let count = |g: &mut DriftGen, rng: &mut Rng, t_ms: u64| -> [f64; 3] {
            let mut c = [0usize; 3];
            for _ in 0..20_000 {
                let op = g.next_op_at(rng, 0, 3, VTime::from_millis(t_ms));
                c[op.txn] += 1;
            }
            [0, 1, 2].map(|i| c[i] as f64 / 20_000.0)
        };
        let before = count(&mut g, &mut rng, 1_000);
        assert!((before[0] - 0.10).abs() < 0.02, "{before:?}");
        assert!((before[1] - 0.72).abs() < 0.02, "{before:?}");
        assert!((before[2] - 0.18).abs() < 0.02, "{before:?}");
        let after = count(&mut g, &mut rng, 15_000);
        assert!((after[1] - 0.18).abs() < 0.02, "{after:?}");
        assert!((after[2] - 0.72).abs() < 0.02, "{after:?}");
        // The flash crowd concentrates every bupd on one key.
        for _ in 0..50 {
            let op = g.next_op_at(&mut rng, 0, 3, VTime::from_millis(15_000));
            if op.txn == 2 {
                assert_eq!(op.args.get("b"), Some(&Value::Int(0)));
            }
        }
    }

    #[test]
    fn drift_bodies_execute() {
        let app = drift_analyzed();
        let db = Db::new(app.spec.schema.clone());
        drift_seed(&db);
        let args: Bindings = [
            ("a".to_string(), Value::Int(3)),
            ("b".to_string(), Value::Int(4)),
        ]
        .into_iter()
        .collect();
        for txn in 0..3 {
            let tpl = &app.spec.txns[txn];
            let stmts = tpl.prepared_map(&app.spec.schema);
            let mut h = db.begin();
            let mut ctx = crate::workload::spec::TxnCtx::new(&mut h, &stmts);
            (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
            h.commit().unwrap();
        }
        // move + aupd touched A(3); move + bupd touched B(4); only move
        // touched the witness C(3).
        for (table, k, v) in [("A_TAB", 3, 2), ("B_TAB", 4, 2), ("C_TAB", 3, 1)] {
            let q = parse_statement(&format!("SELECT V FROM {table} WHERE K = {k}")).unwrap();
            assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(v)));
        }
    }

    #[test]
    fn bodies_execute() {
        let app = analyzed();
        let db = Db::new(app.spec.schema.clone());
        seed(&db);
        for (txn, k) in [(0usize, 5i64), (1, 9)] {
            let tpl = &app.spec.txns[txn];
            let stmts = tpl.prepared_map(&app.spec.schema);
            let mut h = db.begin();
            let mut ctx = crate::workload::spec::TxnCtx::new(&mut h, &stmts);
            let args: Bindings = [("k".to_string(), Value::Int(k))].into_iter().collect();
            (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
            h.commit().unwrap();
        }
        let q = parse_statement("SELECT V FROM LOCAL_TAB WHERE K = 5").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
        let q = parse_statement("SELECT V FROM GLOBAL_TAB WHERE G = 9").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
    }
}
