//! An application bundled with its Operation Partitioning results — the
//! artifact every runtime (simulated or real-threads) consumes.

use crate::analysis::classify::{classify, Classification, OpClass};
use crate::analysis::conflict::ConflictMatrix;
use crate::analysis::elim::EliminationTensor;
use crate::analysis::partition::{optimize, PartitionOptions, Partitioning};
use crate::analysis::rwsets::{extract_rwsets, ExtractOptions, RwSets};
use crate::analysis::score::Assignment;
use crate::db::{Bindings, Value};
use crate::workload::spec::{AppSpec, Operation};

/// Deterministic value hash shared by every server and client — routing
/// must agree across processes, so no `RandomState` here (FNV-1a).
pub fn route_hash(v: &Value) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    match v {
        Value::Int(i) => eat(&i.to_le_bytes()),
        Value::Float(x) => eat(&x.to_bits().to_le_bytes()),
        Value::Str(s) => eat(s.as_bytes()),
        Value::Null => eat(&[0xFF]),
    }
    h
}

/// Where an operation must execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Commutative: any server may run it (clients pick the nearest).
    Any,
    /// Local operation owned by this server.
    LocalAt(usize),
    /// Global operation assigned to this server's partition; execution
    /// waits for the token there.
    GlobalAt(usize),
    /// Invariant-confluent operation owned by this server: executes
    /// immediately (no token wait) like a local one, but its state
    /// update is replicated as a merged delta on the next token pass.
    ConfluentAt(usize),
}

impl Route {
    pub fn is_global(&self) -> bool {
        matches!(self, Route::GlobalAt(_))
    }
}

/// The routing function, parameterized over *which* classification is in
/// force — the static one baked into an [`AnalyzedApp`], or the pinned
/// classification of an installed [`RoutingEpoch`]. Every layer (client,
/// server, simulators) routes through this one function, so an epoch
/// switch changes routing everywhere by swapping one argument.
pub fn route_with(
    spec: &AppSpec,
    classification: &Classification,
    txn: usize,
    args: &Bindings,
    n_servers: usize,
) -> Route {
    let params = &classification.routing_params[txn];
    let value_of = |k: usize| -> Option<&Value> {
        let name = &spec.txns[txn].params[k];
        args.get(name)
    };
    let route_value = |v: &Value| (route_hash(v) % n_servers as u64) as usize;
    match &classification.classes[txn] {
        OpClass::Commutative => Route::Any,
        OpClass::Local => match params.first().and_then(|&k| value_of(k)) {
            Some(v) => Route::LocalAt(route_value(v)),
            // Local op with no routing parameter: reads only global
            // (fully replicated) state — any server works.
            None => Route::Any,
        },
        OpClass::Global => {
            let server = params
                .first()
                .and_then(|&k| value_of(k))
                .map(route_value)
                // Unpartitionable global: a fixed home per template.
                .unwrap_or(txn % n_servers);
            Route::GlobalAt(server)
        }
        OpClass::LocalGlobal => {
            let routes: Vec<usize> =
                params.iter().filter_map(|&k| value_of(k)).map(route_value).collect();
            match routes.split_first() {
                Some((first, rest)) if rest.iter().all(|r| r == first) => Route::LocalAt(*first),
                Some((first, _)) => Route::GlobalAt(*first),
                None => Route::GlobalAt(txn % n_servers),
            }
        }
        // Confluent ops route like locals — same home-server choice a
        // Local/Global with this routing set would make — so peers
        // that rely on routing coverage still co-locate with them.
        OpClass::Confluent => {
            let server = params
                .first()
                .and_then(|&k| value_of(k))
                .map(route_value)
                .unwrap_or(txn % n_servers);
            Route::ConfluentAt(server)
        }
    }
}

/// A versioned routing view: one partitioning assignment plus the
/// classification it pins (see [`crate::analysis::drift`] — epochs
/// classify by *pinning*, so their classes are exactly what the cost
/// function counts). Installed via the conveyor-belt token: the token
/// carries `(version, assignment)`, every server installs at token
/// receipt, so installation is a total-order barrier.
///
/// Transition semantics: in-flight operations complete under their issue
/// epoch. That is sound here because a pinned *Local* template routes by
/// the value of its own pinned parameter — and a template whose Local
/// coverage survives a switch keeps the same parameter, so Local homes
/// never move; only *Global* templates (token-ordered wherever they
/// execute) change home or class across a switch. A workload whose
/// optimum moved a Local template between two different covering
/// parameters would need state migration, which the belt deliberately
/// does not do — the controller's candidates never produce that for the
/// shipped workloads, and token-ordered execution keeps even a misrouted
/// global correct.
#[derive(Debug, Clone)]
pub struct RoutingEpoch {
    /// Monotonic version; epoch 0 is the offline analysis result.
    pub version: u64,
    /// Per-template partitioning parameter choice this epoch pins.
    pub assignment: Assignment,
    /// The pinned classification (never `LocalGlobal`; statically
    /// Confluent templates stay Confluent — see `epoch_from`).
    pub classification: Classification,
}

impl RoutingEpoch {
    /// Route under this epoch instead of the app's static classification.
    pub fn route(&self, app: &AnalyzedApp, txn: usize, args: &Bindings, n_servers: usize) -> Route {
        route_with(&app.spec, &self.classification, txn, args, n_servers)
    }

    /// Convenience wrapper over [`RoutingEpoch::route`].
    pub fn route_op(&self, app: &AnalyzedApp, op: &Operation, n_servers: usize) -> Route {
        self.route(app, op.txn, &op.args, n_servers)
    }
}

/// An application plus its static-analysis outputs.
#[derive(Debug, Clone)]
pub struct AnalyzedApp {
    pub spec: AppSpec,
    pub rwsets: Vec<RwSets>,
    pub matrix: ConflictMatrix,
    pub partitioning: Partitioning,
    pub classification: Classification,
}

impl AnalyzedApp {
    /// Run the full Operation Partitioning pipeline (Algorithm 1 +
    /// classification) on an application.
    pub fn analyze(spec: AppSpec) -> Self {
        Self::analyze_with(spec, &PartitionOptions::default(), ExtractOptions::default())
    }

    /// Like [`AnalyzedApp::analyze`], but additionally runs the
    /// invariant-confluence pass ([`crate::analysis::confluence`]):
    /// Global / LocalGlobal transactions whose residual ww conflicts are
    /// all provably mergeable under the schema's declared invariants are
    /// promoted to [`OpClass::Confluent`]. Call any
    /// [`AnalyzedApp::force_global`] *after* this (forcing expresses an
    /// ordering demand the pass must not undo).
    pub fn analyze_confluent(spec: AppSpec) -> Self {
        let mut app = Self::analyze(spec);
        crate::analysis::confluence::reclassify(
            &app.spec.txns,
            &app.spec.schema,
            &app.rwsets,
            &mut app.classification,
        );
        app
    }

    pub fn analyze_with(
        spec: AppSpec,
        popts: &PartitionOptions,
        eopts: ExtractOptions,
    ) -> Self {
        let rwsets: Vec<RwSets> =
            spec.txns.iter().map(|t| extract_rwsets(t, &spec.schema, eopts)).collect();
        let matrix = ConflictMatrix::detect(&rwsets);
        let tensor = EliminationTensor::build(&spec.txns, &matrix);
        let partitioning = optimize(&tensor, popts);
        let classification = classify(&spec.txns, &matrix, &partitioning);
        AnalyzedApp { spec, rwsets, matrix, partitioning, classification }
    }

    pub fn class(&self, txn: usize) -> &OpClass {
        &self.classification.classes[txn]
    }

    /// The deterministic routing function (paper §3.1: "Operation
    /// Partitioning uses the same deterministic routing function for all
    /// operations").
    pub fn route_value(&self, v: &Value, n_servers: usize) -> usize {
        (route_hash(v) % n_servers as u64) as usize
    }

    /// Route an operation to a server, per its classification.
    pub fn route(&self, op: &Operation, n_servers: usize) -> Route {
        route_with(&self.spec, &self.classification, op.txn, &op.args, n_servers)
    }

    /// Generate a value for parameter `param` of `txn` that routes to
    /// `server` (the paper's "server-specific unique ids"): take any base
    /// id and shift it into the right residue class of the route hash.
    pub fn value_routing_to(&self, base: i64, server: usize, n_servers: usize) -> Value {
        // Linear probe over candidate ids; the FNV hash disperses well so
        // a handful of probes suffice.
        for delta in 0..(n_servers as i64 * 64) {
            let v = Value::Int(base * n_servers as i64 + delta);
            if self.route_value(&v, n_servers) == server {
                return v;
            }
        }
        Value::Int(base)
    }

    /// The initial routing epoch: version 0, the offline partitioning
    /// choice, classified by *pinning* (see [`crate::analysis::drift`]).
    /// Pinned coverage is a subset of the growth classifier's, so epoch 0
    /// may belt more than the static classification would — which is
    /// exactly what makes epochs comparable by cost. Runtimes with
    /// adaptivity off never construct epochs and keep today's behavior.
    pub fn epoch0(&self) -> RoutingEpoch {
        self.epoch_from(0, self.partitioning.choice.clone())
    }

    /// Build the epoch that pins `assignment` at `version`: rebuild the
    /// elimination tensor (the offline run discards it) and classify by
    /// pinning. Statically Confluent templates stay Confluent — invariant
    /// confluence is proven against the schema, independent of the
    /// assignment, and keeping the class stable keeps the replicated
    /// table set stable across switches.
    pub fn epoch_from(&self, version: u64, assignment: Assignment) -> RoutingEpoch {
        let tensor = EliminationTensor::build(&self.spec.txns, &self.matrix);
        let mut classification = crate::analysis::drift::pin_classes(&tensor, &assignment);
        for (t, c) in self.classification.classes.iter().enumerate() {
            if *c == OpClass::Confluent {
                classification.classes[t] = OpClass::Confluent;
            }
        }
        RoutingEpoch { version, assignment, classification }
    }

    /// Force a named transaction to Global (see
    /// [`Classification::force_global`]); panics on unknown names.
    pub fn force_global(&mut self, txn_name: &str) {
        let t = self.spec.txn_index(txn_name).unwrap_or_else(|| panic!("unknown txn {txn_name}"));
        self.classification.force_global(t);
    }

    /// Table 1 summary: (#local, #global, #commutative, #local-global,
    /// #confluent, #read-only, total).
    pub fn table1_row(&self) -> (usize, usize, usize, usize, usize, usize, usize) {
        let (l, g, c, lg, cf) = self.classification.summary();
        let ro = self.spec.txns.iter().filter(|t| t.is_read_only()).count();
        (l, g, c, lg, cf, ro, self.spec.txns.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::Bindings;
    use crate::workload::spec::TxnTemplate;

    fn mini_app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "addCart",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            ),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived"),
                ],
                1.0,
            ),
        ];
        AnalyzedApp::analyze(AppSpec { name: "mini".into(), schema, txns })
    }

    fn op(txn: usize, cid: i64) -> Operation {
        let args: Bindings = [("cid".to_string(), Value::Int(cid))].into_iter().collect();
        Operation { txn, args }
    }

    #[test]
    fn local_routes_by_param_global_waits() {
        let app = mini_app();
        assert_eq!(*app.class(0), OpClass::Local);
        assert_eq!(*app.class(1), OpClass::Global);
        let r = app.route(&op(0, 42), 4);
        match r {
            Route::LocalAt(s) => assert!(s < 4),
            other => panic!("{other:?}"),
        }
        assert!(app.route(&op(1, 42), 4).is_global());
    }

    #[test]
    fn routing_is_deterministic_and_spread() {
        let app = mini_app();
        let mut seen = std::collections::HashSet::new();
        for cid in 0..64 {
            let Route::LocalAt(s) = app.route(&op(0, cid), 8) else { panic!() };
            let Route::LocalAt(s2) = app.route(&op(0, cid), 8) else { panic!() };
            assert_eq!(s, s2);
            seen.insert(s);
        }
        assert!(seen.len() >= 6, "routing should spread across servers: {seen:?}");
    }

    #[test]
    fn value_routing_to_hits_target() {
        let app = mini_app();
        for server in 0..5 {
            for base in 0..50 {
                let v = app.value_routing_to(base, server, 5);
                assert_eq!(app.route_value(&v, 5), server);
            }
        }
    }

    #[test]
    fn table1_row_counts() {
        let app = mini_app();
        let (l, g, c, lg, cf, ro, total) = app.table1_row();
        assert_eq!((l, g, c, lg, cf, ro, total), (1, 1, 0, 0, 0, 0, 2));
    }

    #[test]
    fn epoch0_pins_the_offline_choice() {
        let app = mini_app();
        let e = app.epoch0();
        assert_eq!(e.version, 0);
        assert_eq!(e.assignment, app.partitioning.choice);
        // In mini_app the pinned classes coincide with the grown ones
        // (addCart fully covered on cid, order's self-conflict on the
        // derived item is uncoverable), so epoch-0 routing agrees with
        // the static route for both templates.
        for (txn, cid) in [(0, 42), (1, 42), (0, 7), (1, 9)] {
            let o = op(txn, cid);
            assert_eq!(e.route_op(&app, &o, 4), app.route(&o, 4));
        }
        assert_eq!(e.classification.classes, vec![OpClass::Local, OpClass::Global]);
    }

    #[test]
    fn confluent_routes_like_local_without_waiting() {
        // Declare LEVEL non-negative and promise the (derived) decrement
        // away: make `order` increment instead, so the confluence pass
        // promotes it and routing switches from GlobalAt to ConfluentAt.
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            )
            .with_nonnegative("LEVEL"),
        ]);
        let txns = vec![TxnTemplate::new(
            "restock",
            &["cid"],
            &[("w", "UPDATE STOCK SET LEVEL = LEVEL + 1 WHERE ITEM = ?derived")],
            1.0,
        )];
        let app = AnalyzedApp::analyze_confluent(AppSpec {
            name: "mini".into(),
            schema,
            txns,
        });
        assert_eq!(*app.class(0), OpClass::Confluent);
        let r = app.route(&op(0, 42), 4);
        assert!(matches!(r, Route::ConfluentAt(s) if s < 4), "{r:?}");
        assert!(!r.is_global(), "confluent ops never wait for the token");
    }
}
