//! RUBiS: the eBay-style auction benchmark (paper §6).
//!
//! 8 tables, 26 transaction templates, 17 read-only. RUBiS uses the
//! paper's **double-key scheme**: many operations are partitioned by both
//! user id and item id — local when both route to the same server, global
//! otherwise. The target classification (paper Table 1):
//! **11 local, 4 global, 3 commutative, 8 local/global.**
//!
//! The four globals are multi-partition searches/browses ("a global
//! search for items based on some criteria or browsing through a user's
//! own bought items") — read-only templates with weak (consistent-prefix)
//! reads, forced global exactly as the paper treats them. The bidding-mix
//! weights reproduce Table 1's frequencies: L ≈ 64%, G ≈ 8%, C ≈ 28%,
//! ~15% writes.
//!
//! With declared invariants (`I_QTY` non-negative; `U_ID`, `I_ID`,
//! `CM_SEQ`, `R_SEQ` unique) the invariant-confluence pass additionally
//! promotes **storeComment, registerItem and rateUser** — pure
//! counter-delta + fresh-unique-insert writers — from local/global to
//! [`crate::analysis::OpClass::Confluent`]. storeBid stays local/global
//! (it *assigns* `I_MAX_BID`), as do storeBuyNow (decrements the
//! non-negative `I_QTY`) and the status/description assigners.

use crate::catalog::{Schema, TableSchema, ValueType};
use crate::db::{Bindings, Db, Value};
use crate::util::Rng;
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::OpGenerator;
use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

/// Seeding scale.
#[derive(Debug, Clone, Copy)]
pub struct RubisScale {
    pub users: i64,
    pub items: i64,
    pub categories: i64,
    pub regions: i64,
}

impl Default for RubisScale {
    fn default() -> Self {
        RubisScale { users: 1000, items: 2000, categories: 20, regions: 62 }
    }
}

/// The 8-table RUBiS schema.
pub fn schema() -> Schema {
    use ValueType::*;
    Schema::new(vec![
        TableSchema::new(
            "USERS",
            &[
                ("U_ID", Int),
                ("U_NAME", Str),
                ("U_EMAIL", Str),
                ("U_REGION", Int),
                ("U_RATING", Int),
                ("U_NB_BIDS", Int),
                ("U_NB_BOUGHT", Int),
                ("U_NB_SOLD", Int),
                ("U_NB_ITEMS", Int),
                ("U_NB_COMMENTS", Int),
                ("U_NB_RATINGS", Int),
            ],
            &["U_ID"],
        )
        .with_unique("U_ID"),
        TableSchema::new(
            "ITEMS",
            &[
                ("I_ID", Int),
                ("I_NAME", Str),
                ("I_SELLER", Int),
                ("I_CATEGORY", Int),
                ("I_REGION", Int),
                ("I_DESC", Str),
                ("I_QTY", Int),
                ("I_STATUS", Str),
                ("I_END_DATE", Int),
                ("I_MAX_BID", Float),
                ("I_NB_BIDS", Int),
            ],
            &["I_ID"],
        )
        .with_index("I_SELLER")
        .with_index("I_CATEGORY")
        .with_nonnegative("I_QTY")
        .with_unique("I_ID"),
        TableSchema::new("CATEGORIES", &[("C_ID", Int), ("C_NAME", Str)], &["C_ID"]),
        TableSchema::new("REGIONS", &[("R_ID", Int), ("R_NAME", Str)], &["R_ID"]),
        TableSchema::new(
            "BIDS",
            &[("B_IID", Int), ("B_SEQ", Int), ("B_UID", Int), ("B_AMT", Float)],
            &["B_IID", "B_SEQ"],
        )
        .with_index("B_UID"),
        TableSchema::new(
            "COMMENTS",
            &[
                ("CM_TO", Int),
                ("CM_SEQ", Int),
                ("CM_FROM", Int),
                ("CM_IID", Int),
                ("CM_TEXT", Str),
            ],
            &["CM_TO", "CM_SEQ"],
        )
        .with_index("CM_IID")
        .with_unique("CM_SEQ"),
        TableSchema::new(
            "BUY_NOW",
            &[("BN_IID", Int), ("BN_SEQ", Int), ("BN_UID", Int), ("BN_QTY", Int)],
            &["BN_IID", "BN_SEQ"],
        )
        .with_index("BN_UID"),
        TableSchema::new(
            "RATINGS",
            &[("R_TO", Int), ("R_SEQ", Int), ("R_FROM", Int), ("R_VAL", Int)],
            &["R_TO", "R_SEQ"],
        )
        .with_unique("R_SEQ"),
    ])
}

/// The 26 RUBiS transaction templates with bidding-mix weights.
pub fn templates() -> Vec<TxnTemplate> {
    vec![
        // ============ Local/Global: the double-key writers ============
        TxnTemplate::new(
            "storeBid",
            &["uid", "iid", "bseq", "amt"],
            &[
                ("item", "UPDATE ITEMS SET I_MAX_BID = ?amt, I_NB_BIDS = I_NB_BIDS + 1 WHERE I_ID = ?iid"),
                ("bid", "INSERT INTO BIDS (B_IID, B_SEQ, B_UID, B_AMT) VALUES (?iid, ?bseq, ?uid, ?amt)"),
                ("user", "UPDATE USERS SET U_NB_BIDS = U_NB_BIDS + 1 WHERE U_ID = ?uid"),
            ],
            6.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("bid", args)?;
            ctx.exec("user", args)
        }),
        TxnTemplate::new(
            "storeBuyNow",
            &["uid", "iid", "bnseq", "qty"],
            &[
                ("item", "UPDATE ITEMS SET I_QTY = I_QTY - ?qty WHERE I_ID = ?iid"),
                ("bn", "INSERT INTO BUY_NOW (BN_IID, BN_SEQ, BN_UID, BN_QTY) VALUES (?iid, ?bnseq, ?uid, ?qty)"),
                ("user", "UPDATE USERS SET U_NB_BOUGHT = U_NB_BOUGHT + 1 WHERE U_ID = ?uid"),
            ],
            2.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("bn", args)?;
            ctx.exec("user", args)
        }),
        TxnTemplate::new(
            "storeComment",
            &["uid", "to", "iid", "cseq", "text"],
            &[
                ("cm", "INSERT INTO COMMENTS (CM_TO, CM_SEQ, CM_FROM, CM_IID, CM_TEXT) VALUES (?to, ?cseq, ?uid, ?iid, ?text)"),
                ("rated", "UPDATE USERS SET U_RATING = U_RATING + 1 WHERE U_ID = ?to"),
                ("from", "UPDATE USERS SET U_NB_COMMENTS = U_NB_COMMENTS + 1 WHERE U_ID = ?uid"),
            ],
            2.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("cm", args)?;
            ctx.exec("rated", args)?;
            ctx.exec("from", args)
        }),
        TxnTemplate::new(
            "registerItem",
            &["uid", "iid", "cat", "region", "name", "end"],
            &[
                ("item", "INSERT INTO ITEMS (I_ID, I_NAME, I_SELLER, I_CATEGORY, I_REGION, I_DESC, I_QTY, I_STATUS, I_END_DATE, I_MAX_BID, I_NB_BIDS) VALUES (?iid, ?name, ?uid, ?cat, ?region, 'd', 10, 'OPEN', ?end, 0.0, 0)"),
                ("user", "UPDATE USERS SET U_NB_ITEMS = U_NB_ITEMS + 1 WHERE U_ID = ?uid"),
            ],
            1.5,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("user", args)
        }),
        TxnTemplate::new(
            "rateUser",
            &["uid", "to", "rseq", "val"],
            &[
                ("r", "INSERT INTO RATINGS (R_TO, R_SEQ, R_FROM, R_VAL) VALUES (?to, ?rseq, ?uid, ?val)"),
                ("tgt", "UPDATE USERS SET U_RATING = U_RATING + ?val WHERE U_ID = ?to"),
                ("src", "UPDATE USERS SET U_NB_RATINGS = U_NB_RATINGS + 1 WHERE U_ID = ?uid"),
            ],
            0.5,
        )
        .with_body(|ctx, args| {
            ctx.exec("r", args)?;
            ctx.exec("tgt", args)?;
            ctx.exec("src", args)
        }),
        TxnTemplate::new(
            "closeAuction",
            &["uid", "iid"],
            &[
                ("item", "UPDATE ITEMS SET I_STATUS = 'CLOSED' WHERE I_ID = ?iid"),
                ("user", "UPDATE USERS SET U_NB_SOLD = U_NB_SOLD + 1 WHERE U_ID = ?uid"),
            ],
            0.5,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("user", args)
        }),
        TxnTemplate::new(
            "relistItem",
            &["uid", "iid", "end"],
            &[
                ("item", "UPDATE ITEMS SET I_STATUS = 'OPEN', I_END_DATE = ?end WHERE I_ID = ?iid"),
                ("user", "UPDATE USERS SET U_NB_ITEMS = U_NB_ITEMS + 1 WHERE U_ID = ?uid"),
            ],
            0.25,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("user", args)
        }),
        TxnTemplate::new(
            "updateItemDesc",
            &["uid", "iid", "d"],
            &[
                ("item", "UPDATE ITEMS SET I_DESC = ?d WHERE I_ID = ?iid"),
                ("user", "UPDATE USERS SET U_NB_ITEMS = U_NB_ITEMS + 0 WHERE U_ID = ?uid"),
            ],
            0.25,
        )
        .with_body(|ctx, args| {
            ctx.exec("item", args)?;
            ctx.exec("user", args)
        }),
        // ============ Local: profile browsing + one writer ============
        TxnTemplate::new(
            "registerUser",
            &["uid", "name", "region"],
            &[("u", "INSERT INTO USERS (U_ID, U_NAME, U_EMAIL, U_REGION, U_RATING, U_NB_BIDS, U_NB_BOUGHT, U_NB_SOLD, U_NB_ITEMS, U_NB_COMMENTS, U_NB_RATINGS) VALUES (?uid, ?name, 'e', ?region, 0, 0, 0, 0, 0, 0, 0)")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("u", args)),
        TxnTemplate::new(
            "viewUserInfo",
            &["uid"],
            &[("q", "SELECT U_NAME, U_REGION, U_RATING FROM USERS WHERE U_ID = ?uid")],
            8.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewUserComments",
            &["uid"],
            &[("q", "SELECT CM_FROM, CM_TEXT FROM COMMENTS WHERE CM_TO = ?uid")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewUserBids",
            &["uid"],
            &[("q", "SELECT B_IID, B_AMT FROM BIDS WHERE B_UID = ?uid")],
            4.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewUserBuyNows",
            &["uid"],
            &[("q", "SELECT BN_IID, BN_QTY FROM BUY_NOW WHERE BN_UID = ?uid")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewRatings",
            &["uid"],
            &[("q", "SELECT R_FROM, R_VAL FROM RATINGS WHERE R_TO = ?uid")],
            2.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "aboutMe",
            &["uid"],
            &[
                ("u", "SELECT U_NAME, U_RATING, U_NB_BIDS, U_NB_BOUGHT FROM USERS WHERE U_ID = ?uid"),
                ("cm", "SELECT CM_FROM, CM_TEXT FROM COMMENTS WHERE CM_TO = ?uid"),
            ],
            4.0,
        )
        .with_body(|ctx, args| {
            ctx.exec("u", args)?;
            ctx.exec("cm", args)
        }),
        TxnTemplate::new(
            "viewItem",
            &["iid"],
            &[("q", "SELECT I_NAME, I_SELLER, I_QTY, I_STATUS, I_MAX_BID, I_NB_BIDS FROM ITEMS WHERE I_ID = ?iid")],
            14.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewBidHistory",
            &["iid"],
            &[("q", "SELECT B_SEQ, B_UID, B_AMT FROM BIDS WHERE B_IID = ?iid ORDER BY B_AMT DESC LIMIT 20")],
            6.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewItemComments",
            &["iid"],
            &[("q", "SELECT CM_FROM, CM_TEXT FROM COMMENTS WHERE CM_IID = ?iid")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewSellerItems",
            &["uid"],
            &[("q", "SELECT I_NAME FROM ITEMS WHERE I_SELLER = ?uid")],
            3.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        // ============ Global: multi-partition searches (forced) ============
        TxnTemplate::new(
            "searchItemsByCategory",
            &["cat"],
            &[("q", "SELECT I_ID, I_NAME, I_MAX_BID FROM ITEMS WHERE I_CATEGORY = ?cat ORDER BY I_END_DATE DESC LIMIT 25")],
            4.0,
        )
        .with_weak_reads()
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "searchItemsByRegion",
            &["region", "cat"],
            &[("q", "SELECT I_ID, I_NAME, I_MAX_BID FROM ITEMS WHERE I_REGION = ?region AND I_CATEGORY = ?cat LIMIT 25")],
            2.0,
        )
        .with_weak_reads()
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "viewBoughtItems",
            &["uid"],
            &[
                ("bn", "SELECT BN_IID, BN_QTY FROM BUY_NOW WHERE BN_UID = ?uid"),
                ("item", "SELECT I_NAME FROM ITEMS WHERE I_ID = ?derived_iid"),
            ],
            1.5,
        )
        .with_weak_reads()
        .with_body(|ctx, args| {
            let bn = ctx.exec("bn", args)?;
            // Collect the probe ids first: `bn` borrows row handles, so
            // only the values actually needed are cloned.
            let iids: Vec<_> = bn.iter().take(5).map(|row| row[0].clone()).collect();
            let mut last = bn;
            for iid in iids {
                let mut b = args.clone();
                b.insert("derived_iid".into(), iid);
                last = ctx.exec("item", &b)?;
            }
            Ok(last)
        }),
        TxnTemplate::new(
            "dailyStats",
            &[],
            &[
                ("bids", "SELECT COUNT(*) FROM BIDS"),
                ("buys", "SELECT COUNT(*) FROM BUY_NOW"),
            ],
            0.5,
        )
        .with_weak_reads()
        .with_body(|ctx, args| {
            ctx.exec("bids", args)?;
            ctx.exec("buys", args)
        }),
        // ============ Commutative: immutable reference data ============
        TxnTemplate::new(
            "getCategories",
            &[],
            &[("q", "SELECT C_ID, C_NAME FROM CATEGORIES ORDER BY C_ID LIMIT 50")],
            10.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getRegions",
            &[],
            &[("q", "SELECT R_ID, R_NAME FROM REGIONS ORDER BY R_ID LIMIT 100")],
            8.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
        TxnTemplate::new(
            "getCategory",
            &["cat"],
            &[("q", "SELECT C_NAME FROM CATEGORIES WHERE C_ID = ?cat")],
            10.0,
        )
        .with_body(|ctx, args| ctx.exec("q", args)),
    ]
}

/// Analyze RUBiS — invariant-confluence pass included — and force the
/// paper's four global searches.
pub fn analyzed() -> AnalyzedApp {
    let spec = AppSpec { name: "rubis".into(), schema: schema(), txns: templates() };
    let mut app = AnalyzedApp::analyze_confluent(spec);
    for t in ["searchItemsByCategory", "searchItemsByRegion", "viewBoughtItems", "dailyStats"] {
        app.force_global(t);
    }
    app
}

/// The conflict-only classification (paper Table 1 exactly): same as
/// [`analyzed`] but without the invariant-confluence pass. Kept for the
/// paper-pinned comparisons and the `--no-confluence` bench mode.
pub fn analyzed_no_confluence() -> AnalyzedApp {
    let spec = AppSpec { name: "rubis".into(), schema: schema(), txns: templates() };
    let mut app = AnalyzedApp::analyze(spec);
    for t in ["searchItemsByCategory", "searchItemsByRegion", "viewBoughtItems", "dailyStats"] {
        app.force_global(t);
    }
    app
}

/// Seed a server database (prepare once per statement — the loader runs
/// one insert per row at full scale).
pub fn seed(db: &Db, scale: RubisScale) {
    let exec = |p: &crate::db::Prepared, pairs: &[(&str, Value)]| {
        db.exec_auto_prepared(p, &p.bind_pairs(pairs).unwrap()).unwrap();
    };
    let mut rng = Rng::new(0x28B15);
    let ins = db.prepare_sql("INSERT INTO CATEGORIES (C_ID, C_NAME) VALUES (?i, ?n)").unwrap();
    for c in 0..scale.categories {
        exec(&ins, &[("i", Value::Int(c)), ("n", Value::Str(format!("cat{c}")))]);
    }
    let ins = db.prepare_sql("INSERT INTO REGIONS (R_ID, R_NAME) VALUES (?i, ?n)").unwrap();
    for r in 0..scale.regions {
        exec(&ins, &[("i", Value::Int(r)), ("n", Value::Str(format!("region{r}")))]);
    }
    let ins = db
        .prepare_sql("INSERT INTO USERS (U_ID, U_NAME, U_EMAIL, U_REGION, U_RATING, U_NB_BIDS, U_NB_BOUGHT, U_NB_SOLD, U_NB_ITEMS, U_NB_COMMENTS, U_NB_RATINGS) VALUES (?i, ?n, 'e', ?r, 0, 0, 0, 0, 0, 0, 0)")
        .unwrap();
    for u in 0..scale.users {
        exec(
            &ins,
            &[
                ("i", Value::Int(u)),
                ("n", Value::Str(format!("user{u}"))),
                ("r", Value::Int(u % scale.regions)),
            ],
        );
    }
    let ins = db
        .prepare_sql("INSERT INTO ITEMS (I_ID, I_NAME, I_SELLER, I_CATEGORY, I_REGION, I_DESC, I_QTY, I_STATUS, I_END_DATE, I_MAX_BID, I_NB_BIDS) VALUES (?i, ?n, ?s, ?c, ?r, 'd', 10, 'OPEN', ?e, 0.0, 0)")
        .unwrap();
    for i in 0..scale.items {
        exec(
            &ins,
            &[
                ("i", Value::Int(i)),
                ("n", Value::Str(format!("item{i}"))),
                ("s", Value::Int(i % scale.users)),
                ("c", Value::Int(i % scale.categories)),
                ("r", Value::Int(i % scale.regions)),
                ("e", Value::Int(rng.range(0, 100_000) as i64)),
            ],
        );
    }
}

/// Bidding-mix generator with site-affine users and items.
///
/// `colocate_prob` controls how often a double-key op picks a user and an
/// item homed at the same server (the paper's clients mostly interact
/// with their own site's entities; the remainder resolves to global at
/// run time).
pub struct RubisGenerator {
    scale: RubisScale,
    weights: Vec<f64>,
    names: Vec<String>,
    seqs: i64,
    pub colocate_prob: f64,
    route_helper: AnalyzedApp,
}

impl RubisGenerator {
    pub fn new(app: &AnalyzedApp, scale: RubisScale) -> Self {
        RubisGenerator {
            scale,
            weights: app.spec.txns.iter().map(|t| t.weight).collect(),
            names: app.spec.txns.iter().map(|t| t.name.clone()).collect(),
            seqs: 10_000_000,
            colocate_prob: 0.8,
            route_helper: app.clone(),
        }
    }

    fn seq(&mut self) -> Value {
        self.seqs += 1;
        Value::Int(self.seqs)
    }

    /// Stagger fresh sequence ids so concurrent generator instances
    /// (one per client thread) never collide.
    pub fn with_stream(mut self, stream: u64) -> Self {
        self.seqs = 10_000_000 + (stream as i64) * 1_000_000_000;
        self
    }

    /// An *existing* (seeded) entity id homed at `site`'s server:
    /// rejection-sample within the seeded keyspace so every generated id
    /// references a real row.
    fn homed(&self, rng: &mut Rng, site: usize, n: usize, space: i64) -> Value {
        let target = site % n;
        for _ in 0..128 {
            let v = Value::Int(rng.range(0, space as usize) as i64);
            if self.route_helper.route_value(&v, n) == target {
                return v;
            }
        }
        Value::Int(rng.range(0, space as usize) as i64)
    }
}

fn b(pairs: Vec<(&str, Value)>) -> Bindings {
    pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

impl OpGenerator for RubisGenerator {
    fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
        let txn = rng.weighted(&self.weights);
        let name = self.names[txn].clone();
        let uid = self.homed(rng, site, n, self.scale.users);
        // Item site: co-located with probability colocate_prob.
        let item_site =
            if rng.chance(self.colocate_prob) { site } else { rng.range(0, n.max(1)) };
        let iid = self.homed(rng, item_site, n, self.scale.items);
        let other_uid = self.homed(rng, item_site, n, self.scale.users);
        let cat = Value::Int(rng.range(0, self.scale.categories as usize) as i64);
        let region = Value::Int(rng.range(0, self.scale.regions as usize) as i64);
        let args = match name.as_str() {
            "storeBid" => b(vec![
                ("uid", uid),
                ("iid", iid),
                ("bseq", self.seq()),
                ("amt", Value::Float(1.0 + rng.f64() * 100.0)),
            ]),
            "storeBuyNow" => b(vec![
                ("uid", uid),
                ("iid", iid),
                ("bnseq", self.seq()),
                ("qty", Value::Int(1)),
            ]),
            "storeComment" => b(vec![
                ("uid", uid),
                ("to", other_uid),
                ("iid", iid),
                ("cseq", self.seq()),
                ("text", Value::Str("nice".into())),
            ]),
            "registerItem" => b(vec![
                ("uid", uid),
                ("iid", self.seq()),
                ("cat", cat),
                ("region", region),
                ("name", Value::Str("thing".into())),
                ("end", Value::Int(rng.range(0, 100_000) as i64)),
            ]),
            "rateUser" => b(vec![
                ("uid", uid),
                ("to", other_uid),
                ("rseq", self.seq()),
                ("val", Value::Int(1)),
            ]),
            "closeAuction" => b(vec![("uid", uid), ("iid", iid)]),
            "relistItem" => {
                b(vec![("uid", uid), ("iid", iid), ("end", Value::Int(123))])
            }
            "updateItemDesc" => {
                b(vec![("uid", uid), ("iid", iid), ("d", Value::Str("d2".into()))])
            }
            "registerUser" => b(vec![
                ("uid", self.seq()),
                ("name", Value::Str("nn".into())),
                ("region", region),
            ]),
            "viewUserInfo" | "viewUserComments" | "viewUserBids" | "viewUserBuyNows"
            | "viewRatings" | "aboutMe" | "viewSellerItems" | "viewBoughtItems" => {
                b(vec![("uid", uid)])
            }
            "viewItem" | "viewBidHistory" | "viewItemComments" => b(vec![("iid", iid)]),
            "searchItemsByCategory" | "getCategory" => b(vec![("cat", cat)]),
            "searchItemsByRegion" => b(vec![("region", region), ("cat", cat)]),
            _ => Bindings::new(), // dailyStats, getCategories, getRegions
        };
        Operation { txn, args }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::OpClass;

    #[test]
    fn classification_matches_paper_table1() {
        let app = analyzed_no_confluence();
        let (l, g, c, lg, cf, ro, total) = app.table1_row();
        let names: Vec<(String, OpClass)> = app
            .spec
            .txns
            .iter()
            .zip(&app.classification.classes)
            .map(|(t, cl)| (t.name.clone(), cl.clone()))
            .collect();
        assert_eq!(total, 26, "RUBiS has 26 transactions");
        assert_eq!(lg, 8, "8 local/global (double-key): {names:?}");
        assert_eq!(g, 4, "4 global: {names:?}");
        assert_eq!(c, 3, "3 commutative: {names:?}");
        assert_eq!(l, 11, "11 local: {names:?}");
        assert_eq!(cf, 0, "conflict-only analysis never emits Confluent");
        assert_eq!(ro, 17, "17 read-only templates");
    }

    #[test]
    fn confluence_widens_the_coordination_free_class() {
        let app = analyzed();
        let (l, g, c, lg, cf, ro, total) = app.table1_row();
        let names: Vec<(String, OpClass)> = app
            .spec
            .txns
            .iter()
            .zip(&app.classification.classes)
            .map(|(t, cl)| (t.name.clone(), cl.clone()))
            .collect();
        assert_eq!(total, 26);
        assert_eq!(ro, 17);
        // Three of the eight double-key writers are pure counter deltas
        // plus fresh unique-key inserts — provably mergeable.
        assert_eq!((l, g, c, lg, cf), (11, 4, 3, 5, 3), "{names:?}");
        for t in ["storeComment", "registerItem", "rateUser"] {
            let i = app.spec.txn_index(t).unwrap();
            assert_eq!(app.classification.classes[i], OpClass::Confluent, "{t}");
        }
        // Assignments and non-negative decrements cannot merge.
        for t in ["storeBid", "storeBuyNow", "closeAuction", "relistItem", "updateItemDesc"] {
            let i = app.spec.txn_index(t).unwrap();
            assert_eq!(app.classification.classes[i], OpClass::LocalGlobal, "{t}");
        }
        // Strictly more coordination-free templates than conflict-only.
        let (l0, _, c0, _, cf0, _, _) = analyzed_no_confluence().table1_row();
        assert!(l + c + cf > l0 + c0 + cf0, "confluence must widen the class");
    }

    #[test]
    fn double_key_ops_route_by_agreement() {
        let app = analyzed();
        let t = app.spec.txn_index("storeBid").unwrap();
        assert_eq!(app.classification.classes[t], OpClass::LocalGlobal);
        // Routing params include both uid and iid.
        let params: Vec<&str> = app.classification.routing_params[t]
            .iter()
            .map(|&k| app.spec.txns[t].params[k].as_str())
            .collect();
        assert!(params.contains(&"uid") && params.contains(&"iid"), "{params:?}");

        // Same-server pair -> local; cross pair -> global.
        let n = 4;
        let uid = app.value_routing_to(10, 2, n);
        let iid_same = app.value_routing_to(20, 2, n);
        let iid_cross = app.value_routing_to(30, 1, n);
        let mk = |iid: Value| Operation {
            txn: t,
            args: [
                ("uid".to_string(), uid.clone()),
                ("iid".to_string(), iid),
                ("bseq".to_string(), Value::Int(1)),
                ("amt".to_string(), Value::Float(5.0)),
            ]
            .into_iter()
            .collect(),
        };
        use crate::workload::analyzed::Route;
        assert_eq!(app.route(&mk(iid_same), n), Route::LocalAt(2));
        assert!(app.route(&mk(iid_cross), n).is_global());
    }

    #[test]
    fn frequencies_match_paper() {
        // Conflict-only classification: the paper's Table 1 frequency
        // split counts the three now-confluent writers as L/G.
        let app = analyzed_no_confluence();
        let total: f64 = app.spec.txns.iter().map(|t| t.weight).sum();
        let freq = |class: OpClass| -> f64 {
            app.spec
                .txns
                .iter()
                .zip(&app.classification.classes)
                .filter(|(_, c)| **c == class)
                .map(|(t, _)| t.weight)
                .sum::<f64>()
                / total
        };
        // L/G templates count toward L here (at the paper's 80% co-location
        // they mostly execute locally).
        let l = freq(OpClass::Local) + freq(OpClass::LocalGlobal);
        let g = freq(OpClass::Global);
        let c = freq(OpClass::Commutative);
        assert!((l - 0.64).abs() < 0.02, "L freq {l}");
        assert!((g - 0.08).abs() < 0.02, "G freq {g}");
        assert!((c - 0.28).abs() < 0.02, "C freq {c}");
        let w: f64 = app
            .spec
            .txns
            .iter()
            .filter(|t| !t.is_read_only())
            .map(|t| t.weight)
            .sum::<f64>()
            / total;
        assert!((w - 0.15).abs() < 0.02, "write freq {w} (bidding mix)");
    }

    #[test]
    fn seed_and_execute_bid_flow() {
        let app = analyzed();
        let db = Db::new(app.spec.schema.clone());
        seed(&db, RubisScale { users: 20, items: 30, categories: 5, regions: 4 });
        let run = |name: &str, args: Bindings| {
            let t = app.spec.txn_index(name).unwrap();
            let tpl = &app.spec.txns[t];
            let stmts = tpl.prepared_map(&app.spec.schema);
            let mut h = db.begin();
            let mut ctx = crate::workload::spec::TxnCtx::new(&mut h, &stmts);
            let r = (tpl.body.as_ref().unwrap())(&mut ctx, &args).unwrap();
            h.commit().unwrap();
            r
        };
        run(
            "storeBid",
            b(vec![
                ("uid", Value::Int(3)),
                ("iid", Value::Int(7)),
                ("bseq", Value::Int(100)),
                ("amt", Value::Float(42.0)),
            ]),
        );
        let hist = run("viewBidHistory", b(vec![("iid", Value::Int(7))]));
        assert_eq!(hist.len(), 1);
        let user = run("viewUserInfo", b(vec![("uid", Value::Int(3))]));
        assert_eq!(user.len(), 1);
        let item = run("viewItem", b(vec![("iid", Value::Int(7))]));
        assert_eq!(item.row(0)[4], Value::Float(42.0)); // I_MAX_BID
        // Buy-now reduces quantity.
        run(
            "storeBuyNow",
            b(vec![
                ("uid", Value::Int(3)),
                ("iid", Value::Int(7)),
                ("bnseq", Value::Int(101)),
                ("qty", Value::Int(2)),
            ]),
        );
        let item = run("viewItem", b(vec![("iid", Value::Int(7))]));
        assert_eq!(item.row(0)[2], Value::Int(8)); // I_QTY
        let stats = run("dailyStats", Bindings::new());
        assert_eq!(stats.scalar(), Some(&Value::Int(1))); // one buy-now
    }

    #[test]
    fn generator_runtime_global_fraction_is_small() {
        let app = analyzed();
        let mut g = RubisGenerator::new(&app, RubisScale::default());
        let mut rng = Rng::new(3);
        let (mut global, mut total) = (0usize, 0usize);
        for i in 0..4000 {
            let op = g.next_op(&mut rng, i % 3, 3);
            total += 1;
            if app.route(&op, 3).is_global() {
                global += 1;
            }
        }
        let frac = global as f64 / total as f64;
        // Paper Table 1: ~8% global operations. With 80% co-location the
        // runtime-global share of L/G ops stays small.
        assert!(frac > 0.04 && frac < 0.20, "global fraction {frac}");
    }
}
