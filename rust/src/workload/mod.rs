//! Workloads: application specifications (transaction templates), the
//! TPC-W and RUBiS benchmarks, the RQ3 microbenchmark, and client
//! generators.

pub mod analyzed;
pub mod generator;
pub mod micro;
pub mod rubis;
pub mod spec;
pub mod tpcw;

pub use analyzed::{AnalyzedApp, Route};
pub use generator::{OpGenerator, ServiceModel};
pub use spec::{AppSpec, Operation, Reply, TxnBody, TxnCtx, TxnTemplate};
