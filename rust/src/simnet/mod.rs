//! Discrete-event, virtual-time simulation of a multi-site deployment.
//!
//! This substitutes the paper's EC2 testbed (DESIGN.md §1, substitution 3):
//! servers are W-worker FIFO queueing stations (T2.medium ⇒ W = 2),
//! message delivery follows the paper's Table 2 inter-site latency matrix,
//! and per-operation service times are configurable (5 ms in the paper's
//! microbenchmark). Virtual time makes hour-long WAN experiments run in
//! milliseconds, deterministically.
//!
//! The module provides the shared building blocks:
//! * [`events`] — the event queue and virtual clock,
//! * [`latency`] — site topologies and the Table 2 matrix,
//! * [`station`] — the W-worker server station model,
//! * [`clients`] — closed- and open-loop client pools with think times
//!   and Poisson arrivals, plus the shared client tier — sharded into
//!   deterministic [`clients::ClientGroups`] — every simulator runs on,
//! * [`metrics`] — latency/throughput collection over a warm-up window,
//! * [`crash`] — freeze-then-replay server crash/recovery modeling
//!   ([`crash::CrashConfig`]/[`crash::CrashOutcome`]) shared by the sims,
//! * [`parallel`] — the conservative-window parallel engine
//!   ([`parallel::WindowGroup`] + [`parallel::GroupCore`] +
//!   [`parallel::run_windows`], fanned out over a persistent
//!   [`parallel::WorkerPool`]) every simulator executes on.
//!
//! The system models built on top live in sibling modules:
//! [`crate::conveyor`] (Eliá), [`crate::cluster`] (MySQL-Cluster-like data
//! partitioning + 2PC) and [`crate::baselines`] (centralized, read-only
//! optimization).
#![cfg_attr(doc, warn(missing_docs))]

pub mod clients;
pub mod crash;
pub mod events;
pub mod latency;
pub mod metrics;
pub mod parallel;
pub mod station;

pub use clients::{
    ClientEv, ClientGroups, ClientPool, ClientTier, ClientsConfig, IssueReply, IssueRouter,
};
pub use crash::{CrashConfig, CrashOutcome};
pub use events::{EventQueue, Schedulable};
pub use latency::{LatencyMatrix, Site, Topology};
pub use metrics::{LatencyStat, SimMetrics};
pub use parallel::{
    client_group_target, run_windows, CrossSend, GroupCore, WindowGroup, WorkerPool,
};
pub use station::Station;

// The conservative-window parallel execution mode built from these
// pieces (per-group event queues, deterministic cross-send merge,
// per-server RNG streams) lives in [`parallel`] and is documented in
// `src/simnet/README.md`; all three system models run on it.
