//! The server station model: a FIFO run queue served by `W` workers
//! (the paper's T2.medium nodes have two virtual cores; Tomcat's thread
//! pool multiplexes onto them, so a 2-worker queueing station is the
//! right fidelity for throughput saturation).
//!
//! The station is a pure bookkeeping object: the owning simulation calls
//! [`Station::submit`] with a job and its service time; the station
//! returns jobs to *start* now; on every completion the simulation calls
//! [`Station::complete`] to learn what starts next. Priorities: jobs
//! submitted with `priority = true` (token work) jump the queue.

use crate::util::VTime;
use std::collections::VecDeque;

/// A job accepted by the station, tagged with the caller's payload.
#[derive(Debug, Clone)]
pub struct Job<P> {
    /// Caller-defined continuation data.
    pub payload: P,
    /// Service demand of this job.
    pub service: VTime,
    /// When the job was submitted (queueing-delay accounting).
    pub enqueued_at: VTime,
}

/// A `W`-worker FIFO queueing station (see module docs).
#[derive(Debug)]
pub struct Station<P> {
    workers: usize,
    busy: usize,
    queue: VecDeque<Job<P>>,
    /// Cumulative busy worker-time (utilization accounting).
    busy_time: VTime,
    last_change: VTime,
    completed: u64,
}

impl<P> Station<P> {
    /// A station with `workers` parallel workers (min 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Station {
            workers,
            busy: 0,
            queue: VecDeque::new(),
            busy_time: VTime::ZERO,
            last_change: VTime::ZERO,
            completed: 0,
        }
    }

    fn account(&mut self, now: VTime) {
        let dt = now.saturating_sub(self.last_change);
        self.busy_time += VTime::from_micros(dt.as_micros() * self.busy as u64);
        self.last_change = now;
    }

    /// Submit a job. Returns `Some(job)` if a worker is free and it starts
    /// immediately, `None` if it queued.
    pub fn submit(&mut self, now: VTime, payload: P, service: VTime, priority: bool) -> Option<Job<P>> {
        self.account(now);
        let job = Job { payload, service, enqueued_at: now };
        if self.busy < self.workers {
            self.busy += 1;
            Some(job)
        } else {
            if priority {
                self.queue.push_front(job);
            } else {
                self.queue.push_back(job);
            }
            None
        }
    }

    /// A running job finished; returns the next job to start, if any.
    pub fn complete(&mut self, now: VTime) -> Option<Job<P>> {
        self.account(now);
        self.completed += 1;
        debug_assert!(self.busy > 0);
        if let Some(next) = self.queue.pop_front() {
            // Worker moves straight to the next job; busy count unchanged.
            Some(next)
        } else {
            self.busy -= 1;
            None
        }
    }

    /// Number of queued (not yet started) jobs.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Number of busy workers.
    pub fn busy(&self) -> usize {
        self.busy
    }

    /// Number of completed jobs.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Average utilization in [0, 1] over `[0, now]`. Read-only: the
    /// interval since the last state change is folded in on the fly, so
    /// report code can query stations without `&mut` access.
    pub fn utilization(&self, now: VTime) -> f64 {
        if now == VTime::ZERO {
            return 0.0;
        }
        let dt = now.saturating_sub(self.last_change);
        let busy = self.busy_time + VTime::from_micros(dt.as_micros() * self.busy as u64);
        busy.as_micros() as f64 / (now.as_micros() as f64 * self.workers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_immediately_when_idle() {
        let mut s: Station<u32> = Station::new(2);
        assert!(s.submit(VTime::ZERO, 1, VTime::from_millis(5), false).is_some());
        assert!(s.submit(VTime::ZERO, 2, VTime::from_millis(5), false).is_some());
        assert_eq!(s.busy(), 2);
        // Third job queues.
        assert!(s.submit(VTime::ZERO, 3, VTime::from_millis(5), false).is_none());
        assert_eq!(s.queue_len(), 1);
    }

    #[test]
    fn completion_dequeues_fifo() {
        let mut s: Station<u32> = Station::new(1);
        s.submit(VTime::ZERO, 1, VTime::from_millis(5), false);
        s.submit(VTime::ZERO, 2, VTime::from_millis(5), false);
        s.submit(VTime::ZERO, 3, VTime::from_millis(5), false);
        let next = s.complete(VTime::from_millis(5)).unwrap();
        assert_eq!(next.payload, 2);
        let next = s.complete(VTime::from_millis(10)).unwrap();
        assert_eq!(next.payload, 3);
        assert!(s.complete(VTime::from_millis(15)).is_none());
        assert_eq!(s.busy(), 0);
        assert_eq!(s.completed(), 3);
    }

    #[test]
    fn priority_jobs_jump_the_queue() {
        let mut s: Station<u32> = Station::new(1);
        s.submit(VTime::ZERO, 1, VTime::from_millis(5), false);
        s.submit(VTime::ZERO, 2, VTime::from_millis(5), false);
        s.submit(VTime::ZERO, 9, VTime::from_millis(5), true);
        let next = s.complete(VTime::from_millis(5)).unwrap();
        assert_eq!(next.payload, 9, "priority job first");
    }

    #[test]
    fn utilization_accounting() {
        let mut s: Station<u32> = Station::new(2);
        s.submit(VTime::ZERO, 1, VTime::from_millis(10), false);
        // One of two workers busy for 10ms, then idle until 20ms.
        s.complete(VTime::from_millis(10));
        let u = s.utilization(VTime::from_millis(20));
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
    }
}
