//! The conservative-window parallel engine shared by every simulator.
//!
//! Two layers live here:
//!
//! * [`fan_out_mut`] — scoped fan-out: "run f over every server's state,
//!   using up to N OS threads, with no shared mutable state". The item
//!   slice is split into one contiguous chunk per thread, each chunk is
//!   processed sequentially on its thread, and the call returns once
//!   every chunk is done.
//! * [`run_windows`] — the window driver built on top of it: a set of
//!   isolated [`WindowGroup`]s (one per server plus a client tier), each
//!   owning its own event queue and state, advanced in conservative
//!   lookahead windows with a canonical cross-group merge. This is the
//!   engine `ConveyorSim`, `ClusterSim` and `BaselineSim` all run on;
//!   the full determinism argument is in `simnet/README.md`.
//!
//! Determinism: `f` receives disjoint `&mut` items and (by the `Sync`
//! bound) only shared immutable context, so the *result* of a fan-out is
//! independent of the thread count and of OS scheduling — threads decide
//! only *where* each item is processed, never in what order effects are
//! observed (items do not observe each other at all).

use crate::simnet::events::EventQueue;
use crate::util::VTime;

/// Number of worker threads a `parallel = 0` ("auto") knob resolves to.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing `parallel` knob: `0` means "all available
/// cores", anything else is taken literally (min 1).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Apply `f` to every item of `items`, fanning out across at most
/// `threads` scoped OS threads. With `threads <= 1` (or a single item)
/// this degrades to a plain sequential loop on the calling thread — the
/// effects are identical either way.
pub fn fan_out_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = threads.min(items.len()).max(1);
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f; // shared by reference; `move` below copies the reference
    std::thread::scope(|scope| {
        for slice in items.chunks_mut(chunk) {
            scope.spawn(move || {
                for it in slice.iter_mut() {
                    f(it);
                }
            });
        }
    });
}

/// Pseudo group id of the client tier in cross-send targets (servers are
/// `0..n`; in the canonical merge order the client tier ranks after all
/// of them).
pub const CLIENT_TIER: usize = usize::MAX;

/// A cross-group event emission, buffered in the source group's out
/// vector during a window and merged into the target group's queue
/// afterwards in canonical order. `at` is the *absolute* arrival time
/// (emission time plus the network latency the message pays).
#[derive(Debug)]
pub struct CrossSend<E> {
    /// Target group id (`0..n` = servers, [`CLIENT_TIER`] = client tier).
    pub target: usize,
    /// Absolute arrival time at the target.
    pub at: VTime,
    /// The event to deliver.
    pub ev: E,
}

/// One isolated group of a window-parallel simulation: it owns an event
/// queue plus whatever mutable state its events touch, and interacts
/// with other groups only through buffered [`CrossSend`]s. `Ctx` is the
/// simulation's shared immutable context (config, topology, app), the
/// same reference handed to every group of a window.
///
/// Implementors supply the queue/out-buffer accessors and [`handle`]
/// (the group's event semantics); the window mechanics — `peek`,
/// `drain`, `deliver` — are provided once here.
///
/// [`handle`]: WindowGroup::handle
pub trait WindowGroup<Ctx> {
    /// The event payload type shared by every group of the simulation.
    type Ev: Send;
    /// The group's event queue.
    fn queue(&self) -> &EventQueue<Self::Ev>;
    /// Mutable access to the group's event queue.
    fn queue_mut(&mut self) -> &mut EventQueue<Self::Ev>;
    /// The window's buffered cross-group sends, in emission order.
    fn out(&mut self) -> &mut Vec<CrossSend<Self::Ev>>;
    /// Process one event: may schedule intra-group events and buffer
    /// cross-group sends, but must never touch another group's state.
    fn handle(&mut self, ev: Self::Ev, ctx: &Ctx);

    /// Earliest pending event in this group's queue.
    fn peek(&self) -> Option<VTime> {
        self.queue().peek_time()
    }

    /// Process own events strictly before `cut` (the window bound).
    fn drain(&mut self, cut: VTime, ctx: &Ctx) {
        while let Some((_, ev)) = self.queue_mut().pop_before(cut) {
            self.handle(ev, ctx);
        }
    }

    /// Insert a merged cross-group event into this group's queue.
    fn deliver(&mut self, at: VTime, ev: Self::Ev) {
        self.queue_mut().schedule_at(at, ev);
    }
}

/// Buffered cross-send tagged with its canonical merge rank.
struct MergeEntry<E> {
    at: VTime,
    /// Source group rank: server id, or `n` for the client tier.
    src: u32,
    /// Emission number within the source group's window.
    idx: u32,
    target: usize,
    ev: E,
}

/// Drive a set of window groups to `horizon`: repeatedly take the
/// earliest pending event time `T` across all groups, drain every group
/// independently over the window `[T, T + lookahead)` — servers fanned
/// out over at most `threads` scoped threads, the client tier on the
/// driving thread — then merge the buffered cross-group sends back in
/// canonical `(arrival time, source rank, emission number)` order.
///
/// `lookahead` must be a lower bound on the latency any cross-group
/// message pays; a zero lookahead (degenerate topology) falls back to
/// single-tick windows, which stay correct — zero-delay cross sends are
/// merged after the round and processed at the same virtual time in the
/// next one. Results are bit-identical for every thread count (see
/// `simnet/README.md` for the induction).
pub fn run_windows<Ctx, S, C>(
    threads: usize,
    lookahead: VTime,
    horizon: VTime,
    ctx: &Ctx,
    servers: &mut [S],
    client: &mut C,
) where
    Ctx: Sync,
    S: WindowGroup<Ctx> + Send,
    C: WindowGroup<Ctx, Ev = S::Ev>,
{
    let n = servers.len();
    // Reused across rounds: steady state allocates nothing per window.
    let mut merge_buf: Vec<MergeEntry<S::Ev>> = Vec::new();
    loop {
        // T = earliest pending event anywhere; stop past the horizon.
        let mut t_min = client.peek();
        for s in servers.iter() {
            if let Some(t) = s.peek() {
                t_min = Some(t_min.map_or(t, |m| m.min(t)));
            }
        }
        let Some(t) = t_min else { break };
        if t > horizon {
            break;
        }
        // Exclusive processing cut: [T, T+L) ∩ [0, horizon].
        let width = if lookahead == VTime::ZERO {
            VTime::from_micros(1)
        } else {
            lookahead
        };
        let cut = VTime::from_micros((t + width).as_micros().min(horizon.as_micros() + 1));

        // Client tier on the driving thread, then the servers fan out.
        // Groups cannot interact inside a window, so this order is a
        // scheduling choice, not a semantic one.
        client.drain(cut, ctx);
        // Spawn when at least two servers have work *inside this window*
        // (queued future events don't count): sparse windows stay on the
        // driving thread. Both paths are identical, so this is purely a
        // spawn-overhead heuristic.
        let busy = servers
            .iter()
            .filter(|s| s.peek().is_some_and(|pt| pt < cut))
            .count();
        if threads > 1 && busy >= 2 {
            fan_out_mut(threads, servers, |s| s.drain(cut, ctx));
        } else {
            for s in servers.iter_mut() {
                s.drain(cut, ctx);
            }
        }

        // Deterministic merge: the canonical order fixes the target
        // queues' FIFO tie-break sequence numbers independently of which
        // thread produced what.
        for (src, s) in servers.iter_mut().enumerate() {
            for (idx, m) in s.out().drain(..).enumerate() {
                merge_buf.push(MergeEntry {
                    at: m.at,
                    src: src as u32,
                    idx: idx as u32,
                    target: m.target,
                    ev: m.ev,
                });
            }
        }
        for (idx, m) in client.out().drain(..).enumerate() {
            merge_buf.push(MergeEntry {
                at: m.at,
                src: n as u32,
                idx: idx as u32,
                target: m.target,
                ev: m.ev,
            });
        }
        merge_buf.sort_by_key(|e| (e.at, e.src, e.idx));
        for e in merge_buf.drain(..) {
            if e.target == CLIENT_TIER {
                client.deliver(e.at, e.ev);
            } else {
                servers[e.target].deliver(e.at, e.ev);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_auto_and_literal() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn fan_out_touches_every_item_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            let mut xs: Vec<u64> = (0..37).collect();
            fan_out_mut(threads, &mut xs, |x| *x += 1000);
            let expect: Vec<u64> = (0..37).map(|i| i + 1000).collect();
            assert_eq!(xs, expect, "threads={threads}");
        }
    }

    #[test]
    fn fan_out_result_is_thread_count_independent() {
        // Each item's result depends only on the item itself, so any
        // thread count must produce bit-identical output.
        let run = |threads: usize| {
            let mut xs: Vec<u64> = (0..101).collect();
            fan_out_mut(threads, &mut xs, |x| {
                let mut r = crate::util::Rng::new(*x);
                for _ in 0..10 {
                    *x = x.wrapping_add(r.next_u64());
                }
            });
            xs
        };
        let base = run(1);
        for threads in [2usize, 4, 7, 16] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn empty_slice_is_fine() {
        let mut xs: Vec<u32> = vec![];
        fan_out_mut(4, &mut xs, |_| unreachable!());
    }

    // ---- generic window driver ----

    use crate::simnet::events::EventQueue;
    use crate::util::Rng;

    /// Toy protocol: the client pings a random server; the server works
    /// for an RNG-drawn local delay (intra-group events), then pongs
    /// back; the client counts and pings again. Cross sends always pay
    /// `LAT`, intra-group events may be sub-lookahead.
    const LAT: VTime = VTime(5_000);

    #[derive(Debug)]
    enum TEv {
        Ping(u32),
        Work(u32),
        Pong,
    }

    struct TServer {
        rng: Rng,
        sum: u64,
        q: EventQueue<TEv>,
        out: Vec<CrossSend<TEv>>,
    }

    impl WindowGroup<()> for TServer {
        type Ev = TEv;
        fn queue(&self) -> &EventQueue<TEv> {
            &self.q
        }
        fn queue_mut(&mut self) -> &mut EventQueue<TEv> {
            &mut self.q
        }
        fn out(&mut self) -> &mut Vec<CrossSend<TEv>> {
            &mut self.out
        }
        fn handle(&mut self, ev: TEv, _ctx: &()) {
            match ev {
                TEv::Ping(x) => {
                    let d = VTime::from_micros(self.rng.gen_range(2_000));
                    self.q.schedule(d, TEv::Work(x));
                }
                TEv::Work(x) => {
                    self.sum = self.sum.wrapping_add(x as u64 ^ self.q.now().as_micros());
                    self.out.push(CrossSend {
                        target: CLIENT_TIER,
                        at: self.q.now() + LAT,
                        ev: TEv::Pong,
                    });
                }
                TEv::Pong => unreachable!(),
            }
        }
    }

    struct TClient {
        rng: Rng,
        n_servers: usize,
        pongs: u64,
        q: EventQueue<TEv>,
        out: Vec<CrossSend<TEv>>,
    }

    impl WindowGroup<()> for TClient {
        type Ev = TEv;
        fn queue(&self) -> &EventQueue<TEv> {
            &self.q
        }
        fn queue_mut(&mut self) -> &mut EventQueue<TEv> {
            &mut self.q
        }
        fn out(&mut self) -> &mut Vec<CrossSend<TEv>> {
            &mut self.out
        }
        fn handle(&mut self, ev: TEv, _ctx: &()) {
            match ev {
                TEv::Pong => {
                    self.pongs += 1;
                    let t = self.rng.range(0, self.n_servers);
                    self.out.push(CrossSend {
                        target: t,
                        at: self.q.now() + LAT,
                        ev: TEv::Ping(self.pongs as u32),
                    });
                }
                _ => unreachable!(),
            }
        }
    }

    fn drive(threads: usize) -> (u64, Vec<u64>, u64) {
        let n = 4;
        let mut servers: Vec<TServer> = (0..n)
            .map(|i| TServer {
                rng: Rng::stream(9, i as u64),
                sum: 0,
                q: EventQueue::new(),
                out: Vec::new(),
            })
            .collect();
        let mut client = TClient {
            rng: Rng::new(3),
            n_servers: n,
            pongs: 0,
            q: EventQueue::new(),
            out: Vec::new(),
        };
        for c in 0..8u64 {
            client.q.schedule_at(VTime::from_micros(c * 7), TEv::Pong);
        }
        run_windows(threads, LAT, VTime::from_secs(2), &(), &mut servers, &mut client);
        let events =
            client.q.processed() + servers.iter().map(|s| s.q.processed()).sum::<u64>();
        (client.pongs, servers.iter().map(|s| s.sum).collect(), events)
    }

    #[test]
    fn window_driver_is_thread_count_invariant() {
        let base = drive(1);
        assert!(base.0 > 1000, "pongs={}", base.0);
        for threads in [2usize, 3, 8] {
            assert_eq!(drive(threads), base, "threads={threads}");
        }
    }
}
