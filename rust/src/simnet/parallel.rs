//! The conservative-window parallel engine shared by every simulator.
//!
//! Three layers live here:
//!
//! * [`fan_out_mut`] — scoped fan-out: "run f over every server's state,
//!   using up to N OS threads, with no shared mutable state". The item
//!   slice is split into one contiguous chunk per thread, each chunk is
//!   processed sequentially on its thread, and the call returns once
//!   every chunk is done. Retained as the spawn-per-call reference
//!   implementation (and for one-shot fan-outs outside the window loop).
//! * [`WorkerPool`] — the persistent variant: worker threads created
//!   once, parked on a channel `recv` between dispatches, fed chunk
//!   assignments over round-trip channels. Identical chunking, identical
//!   results; per-dispatch cost is a park/unpark instead of an OS thread
//!   spawn.
//! * [`run_windows`] — the window driver built on top: a set of isolated
//!   [`WindowGroup`]s (one per server plus K client groups), each owning
//!   its own event queue and state (a [`GroupCore`]), advanced in
//!   conservative lookahead windows with a canonical cross-group merge.
//!   Both tiers fan out over the pool. This is the engine `ConveyorSim`,
//!   `ClusterSim` and `BaselineSim` all run on; the full determinism
//!   argument is in `simnet/README.md`.
//!
//! Determinism: `f` receives disjoint `&mut` items and (by the `Sync`
//! bound) only shared immutable context, so the *result* of a fan-out is
//! independent of the thread count and of OS scheduling — threads decide
//! only *where* each item is processed, never in what order effects are
//! observed (items do not observe each other at all). The worker pool
//! changes who runs a chunk, never what a chunk contains.

use crate::simnet::events::EventQueue;
use crate::util::VTime;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Number of worker threads a `parallel = 0` ("auto") knob resolves to.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing `parallel` knob: `0` means "all available
/// cores", anything else is taken literally (min 1).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Split `len` items over at most `threads` workers the way both fan-out
/// paths do: one contiguous chunk per worker, `ceil(len / workers)`
/// items each. Returns the chunk size (callers derive the chunk count).
fn chunk_size(threads: usize, len: usize) -> usize {
    let threads = threads.min(len).max(1);
    len.div_ceil(threads)
}

/// Apply `f` to every item of `items`, fanning out across at most
/// `threads` scoped OS threads. With `threads <= 1` (or a single item)
/// this degrades to a plain sequential loop on the calling thread — the
/// effects are identical either way.
pub fn fan_out_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = threads.min(items.len()).max(1);
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = chunk_size(threads, items.len());
    let f = &f; // shared by reference; `move` below copies the reference
    std::thread::scope(|scope| {
        for slice in items.chunks_mut(chunk) {
            scope.spawn(move || {
                for it in slice.iter_mut() {
                    f(it);
                }
            });
        }
    });
}

/// A type-erased chunk assignment executed by a parked worker. The boxed
/// closure borrows the dispatching call's stack (its chunk and the
/// shared `f`); the lifetime erasure is sound because every dispatch is
/// joined over the round-trip channel before
/// [`WorkerPool::fan_out_mut`] returns — on the panic path included.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// A persistent fan-out pool: `threads - 1` worker OS threads created
/// once (the dispatching thread is the remaining worker), parked on a
/// channel `recv` between dispatches. [`fan_out_mut`](Self::fan_out_mut)
/// splits the item slice into the same contiguous chunks as the scoped
/// [`fan_out_mut`](crate::simnet::parallel::fan_out_mut) free function
/// and round-trips one message pair per chunk, so a window costs a
/// park/unpark per busy worker instead of an OS thread spawn — the cost
/// note in `simnet/README.md`.
///
/// Results are bit-identical to the scoped and sequential paths for any
/// thread count: chunking is deterministic and chunks are disjoint
/// `&mut` ranges that never observe each other.
pub struct WorkerPool {
    /// Upper bound on concurrent chunks (workers + the dispatcher).
    threads: usize,
    /// One task channel per parked worker.
    senders: Vec<Sender<Task>>,
    /// Round-trip completions (one message per dispatched task).
    done_rx: Receiver<std::thread::Result<()>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool that fans out over at most `threads` concurrent
    /// chunks: `threads - 1` parked workers plus the dispatching thread.
    /// `threads <= 1` spawns nothing — every dispatch runs inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (done_tx, done_rx) = channel();
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for _ in 1..threads {
            let (tx, rx) = channel::<Task>();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || {
                // Parked here between windows; a task arrival unparks.
                while let Ok(task) = rx.recv() {
                    let r = catch_unwind(AssertUnwindSafe(task));
                    if done.send(r).is_err() {
                        break;
                    }
                }
            }));
            senders.push(tx);
        }
        WorkerPool { threads, senders, done_rx, handles }
    }

    /// Maximum number of concurrent chunks this pool fans out over.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item of `items` using the parked workers, with
    /// exactly the chunking of the scoped
    /// [`fan_out_mut`](crate::simnet::parallel::fan_out_mut): the
    /// dispatching thread runs the first chunk, workers run the rest.
    /// Blocks until every chunk is done; a panic in any chunk is
    /// re-raised here after all chunks have been joined.
    ///
    /// Takes `&mut self` deliberately: the lifetime-erased dispatch
    /// below is sound only if completions on the shared `done_rx`
    /// belong to *this* call, so re-entrant dispatch on one pool must
    /// be unrepresentable, not merely unconventional.
    pub fn fan_out_mut<T, F>(&mut self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        let threads = self.threads.min(items.len()).max(1);
        if threads <= 1 || self.senders.is_empty() {
            for it in items.iter_mut() {
                f(it);
            }
            return;
        }
        let chunk = chunk_size(threads, items.len());
        let f = &f;
        let mut chunks = items.chunks_mut(chunk);
        let own = chunks.next();
        let mut sent = 0usize;
        for (i, slice) in chunks.enumerate() {
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                for it in slice.iter_mut() {
                    f(it);
                }
            });
            // SAFETY: the task borrows `items` and `f` from this call's
            // stack. We erase the lifetime to send it to a pool thread,
            // and re-establish soundness by receiving exactly one `done`
            // message per sent task below — on every path, including the
            // own-chunk panic path — before returning. No borrow ever
            // outlives this call, and `&mut self` guarantees no other
            // dispatch can interleave on `done_rx` and steal this
            // call's completions.
            let task: Task = unsafe { std::mem::transmute(task) };
            self.senders[i % self.senders.len()]
                .send(task)
                .expect("worker pool thread died");
            sent += 1;
        }
        // The dispatcher works its own chunk while the workers run;
        // unwinding is deferred until every outstanding chunk is joined
        // (the borrows above must not outlive an unwound frame).
        let own_result = catch_unwind(AssertUnwindSafe(|| {
            if let Some(slice) = own {
                for it in slice.iter_mut() {
                    f(it);
                }
            }
        }));
        let mut worker_panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..sent {
            match self.done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(p)) => worker_panic = Some(p),
                Err(_) => worker_panic = Some(Box::new("worker pool thread died")),
            }
        }
        if let Err(p) = own_result {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the task channels: parked workers' `recv` errors
        // and their loops exit. No task can be in flight here — every
        // dispatch joined before `fan_out_mut` returned.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pseudo group id of client group 0 in cross-send targets (servers are
/// `0..n`; in the canonical merge order client groups rank after all of
/// them). Client group `g` is addressed as `CLIENT_TIER - g` — use
/// [`client_group_target`] to compute the id for a client.
pub const CLIENT_TIER: usize = usize::MAX;

/// The cross-send target id for a reply to `client` in a tier sharded
/// into `groups` client groups: client `c` lives in group `c % groups`,
/// addressed as [`CLIENT_TIER`]` - group`. With `groups <= 1` this is
/// exactly [`CLIENT_TIER`], so single-group callers are unchanged.
pub fn client_group_target(client: usize, groups: usize) -> usize {
    CLIENT_TIER - (client % groups.max(1))
}

/// A cross-group event emission, buffered in the source group's out
/// vector during a window and merged into the target group's queue
/// afterwards in canonical order. `at` is the *absolute* arrival time
/// (emission time plus the network latency the message pays).
#[derive(Debug)]
pub struct CrossSend<E> {
    /// Target group id (`0..n` = servers, `CLIENT_TIER - g` = client
    /// group `g`; see [`client_group_target`]).
    pub target: usize,
    /// Absolute arrival time at the target.
    pub at: VTime,
    /// Canonical merge rank within `(time, source)` ties, overriding the
    /// source group's emission counter. Client groups tag issue sends
    /// with the client's global id so the merged order is independent of
    /// how clients are sharded into groups; `None` (the default) falls
    /// back to emission order.
    pub tag: Option<u32>,
    /// The event to deliver.
    pub ev: E,
}

/// The window-engine state every group owns: its event queue (and with
/// it the group's virtual clock) plus the per-window cross-send buffer.
/// Embedding one of these and pointing [`WindowGroup::core`] /
/// [`WindowGroup::core_mut`] at it is all a group supplies — the
/// `queue()`/`queue_mut()`/`out()` accessors and the window mechanics
/// (`peek`/`drain`/`deliver`) are provided once by the trait, instead of
/// being repeated by every group struct of every simulator.
#[derive(Debug)]
pub struct GroupCore<E> {
    /// The group's event queue.
    pub q: EventQueue<E>,
    /// Cross-group sends buffered during the current window, in emission
    /// order (merged canonically by [`run_windows`] after the window).
    pub out: Vec<CrossSend<E>>,
}

impl<E> Default for GroupCore<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> GroupCore<E> {
    /// An empty core at virtual time zero.
    pub fn new() -> Self {
        GroupCore { q: EventQueue::new(), out: Vec::new() }
    }

    /// The group's current virtual time.
    pub fn now(&self) -> VTime {
        self.q.now()
    }

    /// Buffer a cross-group send: deliver `ev` to group `target`
    /// (servers `0..n`, `CLIENT_TIER - g` = client group `g`) at
    /// absolute time `at`, merge-ranked by emission order.
    pub fn send(&mut self, target: usize, at: VTime, ev: E) {
        self.out.push(CrossSend { target, at, tag: None, ev });
    }

    /// Buffer a cross-group send with an explicit canonical merge rank
    /// (see [`CrossSend::tag`]): client groups pass the issuing client's
    /// global id, which makes the merged delivery order independent of
    /// the client-group count.
    pub fn send_tagged(&mut self, target: usize, at: VTime, tag: u32, ev: E) {
        self.out.push(CrossSend { target, at, tag: Some(tag), ev });
    }
}

/// One isolated group of a window-parallel simulation: it owns an event
/// queue plus whatever mutable state its events touch, and interacts
/// with other groups only through buffered [`CrossSend`]s. `Ctx` is the
/// simulation's shared immutable context (config, topology, app), the
/// same reference handed to every group of a window.
///
/// Implementors supply the [`GroupCore`] accessors and [`handle`] (the
/// group's event semantics); the accessor boilerplate — `queue`,
/// `queue_mut`, `out` — and the window mechanics — `peek`, `drain`,
/// `deliver` — are provided once here.
///
/// [`handle`]: WindowGroup::handle
pub trait WindowGroup<Ctx> {
    /// The event payload type shared by every group of the simulation.
    type Ev: Send;
    /// The group's engine state (queue + cross-send buffer).
    fn core(&self) -> &GroupCore<Self::Ev>;
    /// Mutable access to the group's engine state.
    fn core_mut(&mut self) -> &mut GroupCore<Self::Ev>;
    /// Process one event: may schedule intra-group events and buffer
    /// cross-group sends, but must never touch another group's state.
    fn handle(&mut self, ev: Self::Ev, ctx: &Ctx);

    /// The group's event queue.
    fn queue(&self) -> &EventQueue<Self::Ev> {
        &self.core().q
    }

    /// Mutable access to the group's event queue.
    fn queue_mut(&mut self) -> &mut EventQueue<Self::Ev> {
        &mut self.core_mut().q
    }

    /// The window's buffered cross-group sends, in emission order.
    fn out(&mut self) -> &mut Vec<CrossSend<Self::Ev>> {
        &mut self.core_mut().out
    }

    /// Earliest pending event in this group's queue.
    fn peek(&self) -> Option<VTime> {
        self.core().q.peek_time()
    }

    /// Process own events at times up to and including `cut` (the
    /// inclusive window bound).
    fn drain(&mut self, cut: VTime, ctx: &Ctx) {
        while let Some((_, ev)) = self.core_mut().q.pop_through(cut) {
            self.handle(ev, ctx);
        }
    }

    /// Insert a merged cross-group event into this group's queue.
    fn deliver(&mut self, at: VTime, ev: Self::Ev) {
        self.core_mut().q.schedule_at(at, ev);
    }
}

/// Buffered cross-send tagged with its canonical merge rank.
struct MergeEntry<E> {
    at: VTime,
    /// Source group rank: server id, or `n` for *every* client group —
    /// client groups share one rank so the canonical order does not
    /// depend on how clients are sharded; their sends disambiguate by
    /// client-id tag instead.
    src: u32,
    /// Emission rank: the send's [`CrossSend::tag`] if set (client
    /// groups tag with the global client id), else the emission number
    /// within the source group's window.
    idx: u32,
    target: usize,
    ev: E,
}

/// Drive a set of window groups to `horizon`: repeatedly take the
/// earliest pending event time `T` across all groups, drain every group
/// independently over the window `[T, T + lookahead)` — server groups
/// *and* client groups fanned out over a [`WorkerPool`] of at most
/// `threads` parked workers — then merge the buffered cross-group sends
/// back in canonical `(arrival time, source rank, emission rank)` order.
/// Returns the number of windows executed.
///
/// `lookahead` must be a lower bound on the latency any cross-group
/// message pays; a zero lookahead (degenerate topology) falls back to
/// single-tick windows, which stay correct — zero-delay cross sends are
/// merged after the round and processed at the same virtual time in the
/// next one. Results are bit-identical for every thread count *and*
/// every client-group count (see `simnet/README.md` for the induction;
/// the group-count half additionally needs the client groups to tag
/// their sends with client ids, which [`ClientTier`]'s router contract
/// requires).
///
/// Ties `(at, src, idx)` can only arise within one source group — a
/// client's issues have strictly increasing times and its id tags are
/// unique — and the sort is stable, so such ties keep their emission
/// order, which is itself deterministic.
///
/// [`ClientTier`]: crate::simnet::clients::ClientTier
pub fn run_windows<Ctx, S, C>(
    threads: usize,
    lookahead: VTime,
    horizon: VTime,
    ctx: &Ctx,
    servers: &mut [S],
    clients: &mut [C],
) -> u64
where
    Ctx: Sync,
    S: WindowGroup<Ctx> + Send,
    C: WindowGroup<Ctx, Ev = S::Ev> + Send,
{
    let n = servers.len();
    let k = clients.len();
    // The pool outlives the whole run: workers are created once and
    // parked between windows, so per-window coordination is a channel
    // round-trip per busy worker, not an OS thread spawn. Sized by the
    // wider of the two tiers — each fans out separately.
    let mut pool = if threads > 1 && n.max(k) > 1 {
        Some(WorkerPool::new(threads.min(n.max(k))))
    } else {
        None
    };
    // Reused across rounds: steady state allocates nothing per window.
    let mut merge_buf: Vec<MergeEntry<S::Ev>> = Vec::new();
    let mut peeks: Vec<Option<VTime>> = vec![None; n];
    let mut cpeeks: Vec<Option<VTime>> = vec![None; k];
    let mut windows = 0u64;
    loop {
        // One pass over the heads of all queues: record every group's
        // earliest pending time (reused below for the dispatch
        // heuristics) while deriving T = the earliest pending event
        // anywhere.
        let mut t_min: Option<VTime> = None;
        for (p, c) in cpeeks.iter_mut().zip(clients.iter()) {
            *p = c.peek();
            if let Some(t) = *p {
                t_min = Some(t_min.map_or(t, |m| m.min(t)));
            }
        }
        for (p, s) in peeks.iter_mut().zip(servers.iter()) {
            *p = s.peek();
            if let Some(t) = *p {
                t_min = Some(t_min.map_or(t, |m| m.min(t)));
            }
        }
        let Some(t) = t_min else { break };
        if t > horizon {
            break;
        }
        windows += 1;
        let width = if lookahead == VTime::ZERO {
            VTime::from_micros(1)
        } else {
            lookahead
        };
        // Inclusive processing cut: [T, T+L) ∩ [0, horizon], expressed
        // as "events at times <= cut". `width >= 1`, so the exclusive
        // bound `T + L` becomes the inclusive `T + (L-1)`; the
        // saturating add keeps windows near VTime's maximum exact (the
        // old exclusive `horizon + 1` bound overflowed in debug builds)
        // — a sum clamped to u64::MAX covers all representable time,
        // which is precisely the right window there.
        let cut = VTime::from_micros(
            t.as_micros()
                .saturating_add(width.as_micros() - 1)
                .min(horizon.as_micros()),
        );

        // Dispatch a tier to the pool when at least two of its groups
        // have work *inside this window* (queued future events don't
        // count): sparse windows stay on the driving thread. Both paths
        // are identical, so this is purely a coordination-overhead
        // heuristic. The peek vectors were filled above — no second
        // heap sweep. Client groups first, then servers; groups cannot
        // interact inside a window, so the order is a scheduling
        // choice, not a semantic one.
        let cbusy = cpeeks.iter().filter(|p| p.is_some_and(|pt| pt <= cut)).count();
        match &mut pool {
            Some(pool) if cbusy >= 2 => pool.fan_out_mut(clients, |c| c.drain(cut, ctx)),
            _ => {
                for c in clients.iter_mut() {
                    c.drain(cut, ctx);
                }
            }
        }
        let busy = peeks.iter().filter(|p| p.is_some_and(|pt| pt <= cut)).count();
        match &mut pool {
            Some(pool) if busy >= 2 => pool.fan_out_mut(servers, |s| s.drain(cut, ctx)),
            _ => {
                for s in servers.iter_mut() {
                    s.drain(cut, ctx);
                }
            }
        }

        // Deterministic merge: the canonical order fixes the target
        // queues' FIFO tie-break sequence numbers independently of which
        // thread produced what. All client groups enter at source rank
        // `n` with client-id tags, so the order is also independent of
        // the client-group count.
        for (src, s) in servers.iter_mut().enumerate() {
            for (idx, m) in s.out().drain(..).enumerate() {
                merge_buf.push(MergeEntry {
                    at: m.at,
                    src: src as u32,
                    idx: m.tag.unwrap_or(idx as u32),
                    target: m.target,
                    ev: m.ev,
                });
            }
        }
        for c in clients.iter_mut() {
            for (idx, m) in c.out().drain(..).enumerate() {
                merge_buf.push(MergeEntry {
                    at: m.at,
                    src: n as u32,
                    idx: m.tag.unwrap_or(idx as u32),
                    target: m.target,
                    ev: m.ev,
                });
            }
        }
        merge_buf.sort_by_key(|e| (e.at, e.src, e.idx));
        for e in merge_buf.drain(..) {
            let g = CLIENT_TIER - e.target;
            if g < k {
                clients[g].deliver(e.at, e.ev);
            } else {
                servers[e.target].deliver(e.at, e.ev);
            }
        }
    }
    windows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_auto_and_literal() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn fan_out_touches_every_item_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            let mut xs: Vec<u64> = (0..37).collect();
            fan_out_mut(threads, &mut xs, |x| *x += 1000);
            let expect: Vec<u64> = (0..37).map(|i| i + 1000).collect();
            assert_eq!(xs, expect, "threads={threads}");
        }
    }

    fn scramble(x: &mut u64) {
        let mut r = crate::util::Rng::new(*x);
        for _ in 0..10 {
            *x = x.wrapping_add(r.next_u64());
        }
    }

    #[test]
    fn fan_out_result_is_thread_count_independent() {
        // Each item's result depends only on the item itself, so any
        // thread count must produce bit-identical output.
        let run = |threads: usize| {
            let mut xs: Vec<u64> = (0..101).collect();
            fan_out_mut(threads, &mut xs, scramble);
            xs
        };
        let base = run(1);
        for threads in [2usize, 4, 7, 16] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    /// Satellite: the persistent pool is chunk-for-chunk equivalent to
    /// the scoped fan-out (and hence to the sequential loop) at every
    /// thread count, including pools wider than the item slice, reused
    /// across many dispatches.
    #[test]
    fn pool_fan_out_matches_scoped_fan_out() {
        let scoped = |threads: usize| {
            let mut xs: Vec<u64> = (0..101).collect();
            fan_out_mut(threads, &mut xs, scramble);
            xs
        };
        for threads in [1usize, 2, 4, 7, 16, 128] {
            let mut pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads.max(1));
            let expect = scoped(threads);
            // Reuse the same pool for several rounds: parked workers
            // must behave identically on every dispatch.
            for round in 0..3 {
                let mut xs: Vec<u64> = (0..101).collect();
                pool.fan_out_mut(&mut xs, scramble);
                assert_eq!(xs, expect, "threads={threads} round={round}");
            }
        }
    }

    #[test]
    fn empty_slice_is_fine() {
        let mut xs: Vec<u32> = vec![];
        fan_out_mut(4, &mut xs, |_| unreachable!());
        WorkerPool::new(4).fan_out_mut(&mut xs, |_: &mut u32| unreachable!());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn pool_propagates_worker_panics() {
        let mut pool = WorkerPool::new(4);
        let mut xs: Vec<u64> = (0..8).collect();
        // Item 7 lands in a worker's chunk (the dispatcher runs chunk 0);
        // the panic must re-raise on the dispatching thread — after all
        // chunks joined, so no borrow outlives the unwound frame.
        pool.fan_out_mut(&mut xs, |x| {
            if *x == 7 {
                panic!("boom");
            }
        });
    }

    // ---- generic window driver ----

    use crate::util::Rng;

    /// Toy protocol: 8 independent ping chains (stand-ins for clients,
    /// sharded over K client groups by `chain % K`) each ping a random
    /// server; the server works for an RNG-drawn local delay
    /// (intra-group events), then pongs back; the chain counts and pings
    /// again. Cross sends always pay `LAT`, intra-group events may be
    /// sub-lookahead. Each chain draws from `Rng::stream(3, chain)` and
    /// tags its pings with its chain id — the same discipline the real
    /// client tier follows — so results must be bit-identical across
    /// both thread count and group count. The shared context is the
    /// group count K (servers need it to address reply targets).
    const LAT: VTime = VTime(5_000);
    const CHAINS: u32 = 8;

    #[derive(Debug)]
    enum TEv {
        Ping { chain: u32, x: u32 },
        Work { chain: u32, x: u32 },
        Pong { chain: u32 },
    }

    struct TServer {
        rng: Rng,
        sum: u64,
        core: GroupCore<TEv>,
    }

    impl WindowGroup<usize> for TServer {
        type Ev = TEv;
        fn core(&self) -> &GroupCore<TEv> {
            &self.core
        }
        fn core_mut(&mut self) -> &mut GroupCore<TEv> {
            &mut self.core
        }
        fn handle(&mut self, ev: TEv, k: &usize) {
            match ev {
                TEv::Ping { chain, x } => {
                    let d = VTime::from_micros(self.rng.gen_range(2_000));
                    self.core.q.schedule(d, TEv::Work { chain, x });
                }
                TEv::Work { chain, x } => {
                    self.sum = self.sum.wrapping_add(x as u64 ^ self.core.q.now().as_micros());
                    self.core.send(
                        client_group_target(chain as usize, *k),
                        self.core.q.now() + LAT,
                        TEv::Pong { chain },
                    );
                }
                TEv::Pong { .. } => unreachable!(),
            }
        }
    }

    struct TClient {
        /// Chains `c` with `c % k == group`, indexed by `c / k`.
        rngs: Vec<Rng>,
        counts: Vec<u64>,
        k: usize,
        n_servers: usize,
        core: GroupCore<TEv>,
    }

    impl WindowGroup<usize> for TClient {
        type Ev = TEv;
        fn core(&self) -> &GroupCore<TEv> {
            &self.core
        }
        fn core_mut(&mut self) -> &mut GroupCore<TEv> {
            &mut self.core
        }
        fn handle(&mut self, ev: TEv, _k: &usize) {
            match ev {
                TEv::Pong { chain } => {
                    let local = chain as usize / self.k;
                    self.counts[local] += 1;
                    let x = self.counts[local] as u32;
                    let t = self.rngs[local].range(0, self.n_servers);
                    self.core.send_tagged(
                        t,
                        self.core.q.now() + LAT,
                        chain,
                        TEv::Ping { chain, x },
                    );
                }
                _ => unreachable!(),
            }
        }
    }

    fn drive(threads: usize, k: usize) -> (u64, Vec<u64>, u64, u64) {
        let n = 4;
        let mut servers: Vec<TServer> = (0..n)
            .map(|i| TServer {
                rng: Rng::stream(9, i as u64),
                sum: 0,
                core: GroupCore::new(),
            })
            .collect();
        let mut clients: Vec<TClient> = (0..k)
            .map(|g| {
                let rngs: Vec<Rng> = (g as u32..CHAINS)
                    .step_by(k)
                    .map(|c| Rng::stream(3, c as u64))
                    .collect();
                let counts = vec![0; rngs.len()];
                TClient { rngs, counts, k, n_servers: n, core: GroupCore::new() }
            })
            .collect();
        for c in 0..CHAINS {
            clients[c as usize % k]
                .core
                .q
                .schedule_at(VTime::from_micros(c as u64 * 7), TEv::Pong { chain: c });
        }
        let windows =
            run_windows(threads, LAT, VTime::from_secs(2), &k, &mut servers, &mut clients);
        let events = clients.iter().map(|c| c.core.q.processed()).sum::<u64>()
            + servers.iter().map(|s| s.core.q.processed()).sum::<u64>();
        let pongs = clients.iter().flat_map(|c| c.counts.iter()).sum::<u64>();
        (pongs, servers.iter().map(|s| s.sum).collect(), events, windows)
    }

    /// Satellite: the toy protocol driven through the worker pool
    /// (threads >= 2) is bit-identical to the retained sequential path
    /// (threads = 1, which never constructs a pool) — pongs, per-server
    /// sums, event counts and window counts all match.
    #[test]
    fn window_driver_pool_matches_sequential_path() {
        let base = drive(1, 1);
        assert!(base.0 > 1000, "pongs={}", base.0);
        assert!(base.3 > 100, "windows={}", base.3);
        for threads in [2usize, 3, 8] {
            assert_eq!(drive(threads, 1), base, "threads={threads}");
        }
    }

    /// Tentpole invariant at the engine level: sharding the chains over
    /// K client groups — for any K, crossed with any thread count — is
    /// bit-identical to the single-group run, because per-chain RNG
    /// streams are keyed by global chain id and client sends merge at
    /// one source rank ordered by chain tag.
    #[test]
    fn client_group_count_does_not_change_results() {
        let base = drive(1, 1);
        for (threads, k) in [(1usize, 2usize), (2, 2), (1, 3), (4, 4), (8, 8), (3, 5)] {
            assert_eq!(drive(threads, k), base, "threads={threads} k={k}");
        }
    }

    /// A group that only counts deliveries — for window-bound edge cases.
    struct NullGroup {
        seen: u64,
        core: GroupCore<u8>,
    }

    impl NullGroup {
        fn new() -> Self {
            NullGroup { seen: 0, core: GroupCore::new() }
        }
    }

    impl WindowGroup<()> for NullGroup {
        type Ev = u8;
        fn core(&self) -> &GroupCore<u8> {
            &self.core
        }
        fn core_mut(&mut self) -> &mut GroupCore<u8> {
            &mut self.core
        }
        fn handle(&mut self, _ev: u8, _ctx: &()) {
            self.seen += 1;
        }
    }

    /// Satellite bugfix regression: a horizon at (or next to) VTime's
    /// maximum used to overflow the exclusive window cut
    /// (`horizon + 1`), panicking in debug builds. The saturating
    /// inclusive cut processes every event at or below the horizon and
    /// terminates.
    #[test]
    fn max_horizon_window_does_not_overflow() {
        let max = u64::MAX;
        let mut s = NullGroup::new();
        let mut c = NullGroup::new();
        for dt in [2u64, 1, 0] {
            s.core.q.schedule_at(VTime::from_micros(max - dt), 0);
        }
        c.core.q.schedule_at(VTime::from_micros(max), 0);
        let w = run_windows(
            1,
            VTime::from_millis(10),
            VTime::from_micros(max),
            &(),
            std::slice::from_mut(&mut s),
            std::slice::from_mut(&mut c),
        );
        assert_eq!(w, 1, "one saturated window covers the top of the range");
        assert_eq!(s.seen, 3);
        assert_eq!(c.seen, 1);

        // An event strictly past a near-max horizon still stays queued.
        let mut s = NullGroup::new();
        let mut c = NullGroup::new();
        s.core.q.schedule_at(VTime::from_micros(max - 1), 0);
        s.core.q.schedule_at(VTime::from_micros(max), 0);
        run_windows(
            1,
            VTime::from_millis(10),
            VTime::from_micros(max - 1),
            &(),
            std::slice::from_mut(&mut s),
            std::slice::from_mut(&mut c),
        );
        assert_eq!(s.seen, 1, "the event at the horizon is processed");
        assert_eq!(s.core.q.len(), 1, "the event past the horizon is not");
    }
}
