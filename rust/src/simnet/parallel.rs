//! Scoped fan-out used by the parallel simulators.
//!
//! The window-parallel engines (see `simnet/README.md`) repeatedly need
//! "run f over every server's state, using up to N OS threads, with no
//! shared mutable state". [`fan_out_mut`] does exactly that with
//! `std::thread::scope`: the item slice is split into one contiguous
//! chunk per thread, each chunk is processed sequentially on its thread,
//! and the call returns once every chunk is done.
//!
//! Determinism: `f` receives disjoint `&mut` items and (by the `Sync`
//! bound) only shared immutable context, so the *result* of a fan-out is
//! independent of the thread count and of OS scheduling — threads decide
//! only *where* each item is processed, never in what order effects are
//! observed (items do not observe each other at all).

/// Number of worker threads a `parallel = 0` ("auto") knob resolves to.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing `parallel` knob: `0` means "all available
/// cores", anything else is taken literally (min 1).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Apply `f` to every item of `items`, fanning out across at most
/// `threads` scoped OS threads. With `threads <= 1` (or a single item)
/// this degrades to a plain sequential loop on the calling thread — the
/// effects are identical either way.
pub fn fan_out_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let threads = threads.min(items.len()).max(1);
    if threads <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = items.len().div_ceil(threads);
    let f = &f; // shared by reference; `move` below copies the reference
    std::thread::scope(|scope| {
        for slice in items.chunks_mut(chunk) {
            scope.spawn(move || {
                for it in slice.iter_mut() {
                    f(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolves_auto_and_literal() {
        assert!(available_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn fan_out_touches_every_item_exactly_once() {
        for threads in [1usize, 2, 3, 8, 64] {
            let mut xs: Vec<u64> = (0..37).collect();
            fan_out_mut(threads, &mut xs, |x| *x += 1000);
            let expect: Vec<u64> = (0..37).map(|i| i + 1000).collect();
            assert_eq!(xs, expect, "threads={threads}");
        }
    }

    #[test]
    fn fan_out_result_is_thread_count_independent() {
        // Each item's result depends only on the item itself, so any
        // thread count must produce bit-identical output.
        let run = |threads: usize| {
            let mut xs: Vec<u64> = (0..101).collect();
            fan_out_mut(threads, &mut xs, |x| {
                let mut r = crate::util::Rng::new(*x);
                for _ in 0..10 {
                    *x = x.wrapping_add(r.next_u64());
                }
            });
            xs
        };
        let base = run(1);
        for threads in [2usize, 4, 7, 16] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn empty_slice_is_fine() {
        let mut xs: Vec<u32> = vec![];
        fan_out_mut(4, &mut xs, |_| unreachable!());
    }
}
