//! Site topologies and inter-site latencies (the paper's Table 2).

use crate::util::VTime;

/// The five sites of the paper's WAN experiments, in deployment order
/// ("We add these locations in the aforementioned order").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Site {
    /// EC2 eu-central (Frankfurt).
    Germany,
    /// EC2 ap-northeast (Tokyo).
    Japan,
    /// EC2 us-east (Virginia).
    UsEast,
    /// EC2 sa-east (São Paulo).
    Brazil,
    /// EC2 ap-southeast (Sydney).
    Australia,
}

/// The paper's five WAN sites in deployment order.
pub const WAN_SITES: [Site; 5] =
    [Site::Germany, Site::Japan, Site::UsEast, Site::Brazil, Site::Australia];

impl Site {
    /// One/two-letter label used in topology names and figures.
    pub fn short(&self) -> &'static str {
        match self {
            Site::Germany => "G",
            Site::Japan => "J",
            Site::UsEast => "US",
            Site::Brazil => "B",
            Site::Australia => "A",
        }
    }

    #[allow(dead_code)]
    fn index(&self) -> usize {
        WAN_SITES.iter().position(|s| s == self).unwrap()
    }
}

/// Paper Table 2: inter-site round-trip latencies in milliseconds.
/// `TABLE2_RTT_MS[i][j]` for the site order G, J, US, B, A. The diagonal
/// is the intra-site latency (~20 ms, paper §7).
pub const TABLE2_RTT_MS: [[f64; 5]; 5] = [
    [20.0, 253.0, 92.0, 193.0, 314.0],
    [253.0, 20.0, 153.0, 282.0, 188.0],
    [92.0, 153.0, 20.0, 145.0, 229.0],
    [193.0, 282.0, 145.0, 20.0, 322.0],
    [314.0, 188.0, 229.0, 322.0, 20.0],
];

/// One-way message latencies between N endpoints.
#[derive(Debug, Clone)]
pub struct LatencyMatrix {
    n: usize,
    /// One-way latency in micros, row-major.
    one_way: Vec<u64>,
}

impl LatencyMatrix {
    /// Build from a square RTT matrix in milliseconds (one-way = RTT/2).
    pub fn from_rtt_ms(rtt: &[Vec<f64>]) -> Self {
        let n = rtt.len();
        let mut one_way = vec![0u64; n * n];
        for (i, row) in rtt.iter().enumerate() {
            assert_eq!(row.len(), n);
            for (j, &ms) in row.iter().enumerate() {
                one_way[i * n + j] = ((ms / 2.0) * 1000.0).round() as u64;
            }
        }
        LatencyMatrix { n, one_way }
    }

    /// Uniform matrix (LAN): every pair has the same RTT.
    pub fn uniform(n: usize, rtt_ms: f64) -> Self {
        LatencyMatrix::from_rtt_ms(&vec![vec![rtt_ms; n]; n])
    }

    /// Number of endpoints.
    pub fn n(&self) -> usize {
        self.n
    }

    /// One-way delivery latency from `a` to `b`.
    pub fn one_way(&self, a: usize, b: usize) -> VTime {
        VTime::from_micros(self.one_way[a * self.n + b])
    }

    /// Round-trip latency between `a` and `b`.
    pub fn rtt(&self, a: usize, b: usize) -> VTime {
        VTime::from_micros(2 * self.one_way[a * self.n + b])
    }

    /// Smallest one-way entry in the matrix — the conservative lookahead
    /// bound of the window-parallel engine: every message between two
    /// endpoints of this matrix pays at least this much (see
    /// `simnet/README.md`; an empty matrix degenerates to zero, which
    /// the engine handles with single-tick windows).
    pub fn min_one_way(&self) -> VTime {
        VTime::from_micros(self.one_way.iter().copied().min().unwrap_or(0))
    }
}

/// A deployment topology: server sites plus the latency matrix between
/// servers (clients are co-located with a server site and reach it at
/// intra-site latency).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Human-readable site labels, one per server.
    pub labels: Vec<String>,
    /// Server-to-server latency matrix.
    pub servers: LatencyMatrix,
    /// Intra-site client<->server RTT.
    pub client_rtt: VTime,
}

impl Topology {
    /// LAN: `n` servers in one datacenter (paper §7.1).
    pub fn lan(n: usize) -> Self {
        Topology {
            labels: (0..n).map(|i| format!("lan{i}")).collect(),
            servers: LatencyMatrix::uniform(n, 20.0),
            client_rtt: VTime::from_millis(20),
        }
    }

    /// WAN with the first `n` paper sites (paper §7.2, Table 2).
    pub fn wan(n: usize) -> Self {
        assert!(n >= 1 && n <= 5, "paper WAN has 1..=5 sites");
        let rtt: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| TABLE2_RTT_MS[i][j]).collect())
            .collect();
        Topology {
            labels: WAN_SITES[..n].iter().map(|s| s.short().to_string()).collect(),
            servers: LatencyMatrix::from_rtt_ms(&rtt),
            client_rtt: VTime::from_millis(20),
        }
    }

    /// WAN latency from a *client site* to an arbitrary server. For the
    /// centralized baselines clients stay at all five sites even when
    /// there is a single server — this gives the paper's "clients direct
    /// requests to the closest server" setup its remote costs.
    pub fn wan_full_client(n_client_sites: usize) -> LatencyMatrix {
        let rtt: Vec<Vec<f64>> = (0..n_client_sites)
            .map(|i| (0..n_client_sites).map(|j| TABLE2_RTT_MS[i][j]).collect())
            .collect();
        LatencyMatrix::from_rtt_ms(&rtt)
    }

    /// Number of servers.
    pub fn n(&self) -> usize {
        self.servers.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_is_symmetric() {
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(TABLE2_RTT_MS[i][j], TABLE2_RTT_MS[j][i]);
            }
        }
    }

    #[test]
    fn one_way_is_half_rtt() {
        let m = LatencyMatrix::from_rtt_ms(&vec![vec![20.0, 92.0], vec![92.0, 20.0]]);
        assert_eq!(m.one_way(0, 1), VTime::from_millis(46));
        assert_eq!(m.rtt(0, 1), VTime::from_millis(92));
        assert_eq!(m.one_way(0, 0), VTime::from_millis(10));
        assert_eq!(m.min_one_way(), VTime::from_millis(10));
    }

    #[test]
    fn min_one_way_is_the_table2_diagonal() {
        // Every paper topology's tightest leg is the 20 ms intra-site
        // RTT — the ≥10 ms lookahead every window engine relies on.
        for n in 1..=5 {
            assert_eq!(Topology::wan(n).servers.min_one_way(), VTime::from_millis(10));
        }
        assert_eq!(Topology::lan(8).servers.min_one_way(), VTime::from_millis(10));
        assert_eq!(Topology::wan_full_client(5).min_one_way(), VTime::from_millis(10));
    }

    #[test]
    fn wan_topology_grows_in_paper_order() {
        let t3 = Topology::wan(3);
        assert_eq!(t3.labels, vec!["G", "J", "US"]);
        // G <-> US one-way 46ms.
        assert_eq!(t3.servers.one_way(0, 2), VTime::from_millis(46));
        let t5 = Topology::wan(5);
        assert_eq!(t5.labels.last().unwrap(), "A");
    }

    #[test]
    fn lan_topology_uniform() {
        let t = Topology::lan(4);
        assert_eq!(t.n(), 4);
        assert_eq!(t.servers.one_way(1, 3), VTime::from_millis(10));
        assert_eq!(t.client_rtt, VTime::from_millis(20));
    }

    #[test]
    fn site_shorthand() {
        assert_eq!(Site::UsEast.short(), "US");
        assert_eq!(Site::Germany.index(), 0);
    }
}
