//! Closed-loop client pools.
//!
//! Each simulated client sits at a site, issues one operation, waits for
//! the reply, thinks for an exponentially distributed time, and repeats —
//! the standard closed-loop model matching the paper's "we intensify the
//! workload by increasing the number of clients".

use crate::util::{Rng, VTime};

/// Client-tier configuration.
#[derive(Debug, Clone)]
pub struct ClientsConfig {
    /// Number of clients.
    pub n: usize,
    /// Mean think time between reply and next request (ms). 0 = replay
    /// as fast as possible (stress).
    pub think_ms: f64,
    /// Number of client sites; clients are assigned round-robin
    /// ("we equally distribute client threads across client nodes").
    pub sites: usize,
    /// Seed for the per-client forked RNGs.
    pub seed: u64,
}

impl Default for ClientsConfig {
    fn default() -> Self {
        ClientsConfig { n: 1, think_ms: 0.0, sites: 1, seed: 0xC11E }
    }
}

/// The closed-loop client pool: per-client forked RNGs plus issue
/// counters.
#[derive(Debug)]
pub struct ClientPool {
    cfg: ClientsConfig,
    rngs: Vec<Rng>,
    issued: Vec<u64>,
}

impl ClientPool {
    /// Build the pool, forking one RNG per client from `cfg.seed`.
    pub fn new(cfg: ClientsConfig) -> Self {
        let mut meta = Rng::new(cfg.seed);
        let rngs = (0..cfg.n).map(|_| meta.fork()).collect();
        let issued = vec![0; cfg.n];
        ClientPool { cfg, rngs, issued }
    }

    /// Number of clients.
    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// The site a client lives at (round-robin over sites).
    pub fn site(&self, client: usize) -> usize {
        client % self.cfg.sites
    }

    /// Per-client deterministic RNG (workload generation).
    pub fn rng(&mut self, client: usize) -> &mut Rng {
        &mut self.rngs[client]
    }

    /// Record an issue and return the think delay to apply *before* it
    /// (exponential; zero-mean collapses to zero).
    pub fn think(&mut self, client: usize) -> VTime {
        self.issued[client] += 1;
        if self.cfg.think_ms <= 0.0 {
            return VTime::ZERO;
        }
        let ms = self.rngs[client].exp(self.cfg.think_ms);
        VTime::from_millis_f64(ms)
    }

    /// Operations issued by one client so far.
    pub fn issued(&self, client: usize) -> u64 {
        self.issued[client]
    }

    /// Operations issued by all clients.
    pub fn total_issued(&self) -> u64 {
        self.issued.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_sites() {
        let p = ClientPool::new(ClientsConfig { n: 7, sites: 3, ..Default::default() });
        assert_eq!(p.site(0), 0);
        assert_eq!(p.site(1), 1);
        assert_eq!(p.site(2), 2);
        assert_eq!(p.site(3), 0);
        assert_eq!(p.site(6), 0);
    }

    #[test]
    fn zero_think_time_is_zero() {
        let mut p = ClientPool::new(ClientsConfig { n: 2, think_ms: 0.0, ..Default::default() });
        assert_eq!(p.think(0), VTime::ZERO);
        assert_eq!(p.issued(0), 1);
    }

    #[test]
    fn think_time_mean_roughly_matches() {
        let mut p =
            ClientPool::new(ClientsConfig { n: 1, think_ms: 10.0, ..Default::default() });
        let total: f64 = (0..20_000).map(|_| p.think(0).as_millis_f64()).sum();
        let mean = total / 20_000.0;
        assert!((mean - 10.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn client_rngs_are_independent_and_deterministic() {
        let mut a = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        let mut b = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        assert_eq!(a.rng(0).next_u64(), b.rng(0).next_u64());
        assert_ne!(a.rng(0).next_u64(), a.rng(1).next_u64());
    }
}
