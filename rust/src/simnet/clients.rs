//! Closed-loop client pools, and the one shared client tier every
//! simulator's window engine runs.
//!
//! Each simulated client sits at a site, issues one operation, waits for
//! the reply, thinks for an exponentially distributed time, and repeats —
//! the standard closed-loop model matching the paper's "we intensify the
//! workload by increasing the number of clients".
//!
//! [`ClientTier`] packages the closed loop as a [`WindowGroup`]: the
//! pool, the workload generator, the metrics and the engine state live
//! here once, together with the Reply → metrics → think → next-Issue arm
//! that all three simulators used to duplicate verbatim. A simulator
//! plugs in by mapping its event enum through [`IssueReply`] and routing
//! freshly issued operations through [`IssueRouter`] on its shared
//! context — which is also all a *fourth* simulator needs to do.

use crate::simnet::metrics::SimMetrics;
use crate::simnet::parallel::{GroupCore, WindowGroup};
use crate::util::{Rng, VTime};
use crate::workload::generator::OpGenerator;

/// Client-tier configuration.
#[derive(Debug, Clone)]
pub struct ClientsConfig {
    /// Number of clients.
    pub n: usize,
    /// Mean think time between reply and next request (ms). 0 = replay
    /// as fast as possible (stress).
    pub think_ms: f64,
    /// Number of client sites; clients are assigned round-robin
    /// ("we equally distribute client threads across client nodes").
    pub sites: usize,
    /// Seed for the per-client forked RNGs.
    pub seed: u64,
}

impl Default for ClientsConfig {
    fn default() -> Self {
        ClientsConfig { n: 1, think_ms: 0.0, sites: 1, seed: 0xC11E }
    }
}

/// The closed-loop client pool: per-client forked RNGs plus issue
/// counters.
#[derive(Debug)]
pub struct ClientPool {
    cfg: ClientsConfig,
    rngs: Vec<Rng>,
    issued: Vec<u64>,
}

impl ClientPool {
    /// Build the pool, forking one RNG per client from `cfg.seed`.
    pub fn new(cfg: ClientsConfig) -> Self {
        let mut meta = Rng::new(cfg.seed);
        let rngs = (0..cfg.n).map(|_| meta.fork()).collect();
        let issued = vec![0; cfg.n];
        ClientPool { cfg, rngs, issued }
    }

    /// Number of clients.
    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// The site a client lives at (round-robin over sites).
    pub fn site(&self, client: usize) -> usize {
        client % self.cfg.sites
    }

    /// Per-client deterministic RNG (workload generation).
    pub fn rng(&mut self, client: usize) -> &mut Rng {
        &mut self.rngs[client]
    }

    /// Record an issue and return the think delay to apply *before* it
    /// (exponential; zero-mean collapses to zero).
    pub fn think(&mut self, client: usize) -> VTime {
        self.issued[client] += 1;
        if self.cfg.think_ms <= 0.0 {
            return VTime::ZERO;
        }
        let ms = self.rngs[client].exp(self.cfg.think_ms);
        VTime::from_millis_f64(ms)
    }

    /// Operations issued by one client so far.
    pub fn issued(&self, client: usize) -> u64 {
        self.issued[client]
    }

    /// Operations issued by all clients.
    pub fn total_issued(&self) -> u64 {
        self.issued.iter().sum()
    }
}

/// A simulator event decomposed into the client tier's view: the two
/// arms the shared tier handles itself, or a server-side event (which a
/// correctly wired simulation never delivers to the tier).
#[derive(Debug)]
pub enum ClientEv<E> {
    /// A client (after thinking) issues its next operation.
    Issue {
        /// The issuing client.
        client: usize,
    },
    /// A server's reply reached the client.
    Reply {
        /// The client the reply is for.
        client: usize,
        /// When the operation was issued (latency = now − issued).
        issued: VTime,
        /// Per-class metrics bucket: `true` = the simulator's expensive
        /// class (global / distributed / write), `false` = the cheap one.
        flag: bool,
    },
    /// Not a client-tier event.
    Other(E),
}

/// How a simulator's event enum maps onto the client tier's two arms.
/// Implemented by each simulation's `Ev` type; everything else about the
/// closed loop is shared.
pub trait IssueReply: Sized + Send {
    /// Decompose an incoming event into the shared client-tier arms.
    fn classify(self) -> ClientEv<Self>;
    /// The Issue event for `client` (scheduled after the think delay).
    fn issue(client: usize) -> Self;
}

/// The per-simulation half of the client tier, implemented on the
/// simulation's shared window context: route one freshly issued
/// operation — draw it from `tier.gen` with the client's RNG, pick the
/// target server, and buffer the `Arrive` cross-send on `tier.core`.
pub trait IssueRouter<E: IssueReply> {
    /// Client `client` (who has finished thinking) issues its next
    /// operation.
    fn route_issue(&self, tier: &mut ClientTier<'_, E>, client: usize);
}

/// The client tier of a window-parallel simulation: client pool,
/// workload generator, metrics and engine state — the sequential "edge"
/// processed as one group on the driving thread. Shared by every
/// simulator; see the module docs for how a simulation plugs in.
pub struct ClientTier<'a, E> {
    /// The closed-loop client pool (sites, per-client RNGs, think times).
    pub clients: ClientPool,
    /// The workload generator operations are drawn from.
    pub gen: Box<dyn OpGenerator + 'a>,
    /// Latency/throughput collection over the measurement window.
    pub metrics: SimMetrics,
    /// The tier's window-engine state (event queue + cross-send buffer).
    pub core: GroupCore<E>,
}

impl<'a, E: IssueReply> ClientTier<'a, E> {
    /// Build the tier: the pool is forked from `cfg` with its site count
    /// overridden to `sites` (simulators derive it from the topology),
    /// and metrics measure `[warmup, horizon]`.
    pub fn new(
        cfg: ClientsConfig,
        sites: usize,
        gen: Box<dyn OpGenerator + 'a>,
        warmup: VTime,
        horizon: VTime,
    ) -> Self {
        ClientTier {
            clients: ClientPool::new(ClientsConfig { sites, ..cfg }),
            gen,
            metrics: SimMetrics::new(warmup, horizon),
            core: GroupCore::new(),
        }
    }

    /// Boot the closed loop: schedule every client's first Issue,
    /// staggered a little to avoid a thundering-herd artifact at t=0.
    pub fn boot(&mut self) {
        for c in 0..self.clients.n() {
            let jitter = VTime::from_micros((c as u64 % 97) * 13);
            self.core.q.schedule_at(jitter, E::issue(c));
        }
    }
}

impl<Ctx, E> WindowGroup<Ctx> for ClientTier<'_, E>
where
    E: IssueReply,
    Ctx: IssueRouter<E>,
{
    type Ev = E;

    fn core(&self) -> &GroupCore<E> {
        &self.core
    }

    fn core_mut(&mut self) -> &mut GroupCore<E> {
        &mut self.core
    }

    fn handle(&mut self, ev: E, ctx: &Ctx) {
        match ev.classify() {
            ClientEv::Issue { client } => ctx.route_issue(self, client),
            ClientEv::Reply { client, issued, flag } => {
                self.metrics.complete(issued, self.core.q.now(), flag);
                let think = self.clients.think(client);
                self.core.q.schedule(think, E::issue(client));
            }
            ClientEv::Other(_) => unreachable!("server event delivered to the client tier"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_sites() {
        let p = ClientPool::new(ClientsConfig { n: 7, sites: 3, ..Default::default() });
        assert_eq!(p.site(0), 0);
        assert_eq!(p.site(1), 1);
        assert_eq!(p.site(2), 2);
        assert_eq!(p.site(3), 0);
        assert_eq!(p.site(6), 0);
    }

    #[test]
    fn zero_think_time_is_zero() {
        let mut p = ClientPool::new(ClientsConfig { n: 2, think_ms: 0.0, ..Default::default() });
        assert_eq!(p.think(0), VTime::ZERO);
        assert_eq!(p.issued(0), 1);
    }

    #[test]
    fn think_time_mean_roughly_matches() {
        let mut p =
            ClientPool::new(ClientsConfig { n: 1, think_ms: 10.0, ..Default::default() });
        let total: f64 = (0..20_000).map(|_| p.think(0).as_millis_f64()).sum();
        let mean = total / 20_000.0;
        assert!((mean - 10.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn client_rngs_are_independent_and_deterministic() {
        let mut a = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        let mut b = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        assert_eq!(a.rng(0).next_u64(), b.rng(0).next_u64());
        assert_ne!(a.rng(0).next_u64(), a.rng(1).next_u64());
    }
}
