//! Client pools (closed- and open-loop), and the shared client tier —
//! sharded into K deterministic groups — that every simulator's window
//! engine runs.
//!
//! Each simulated client sits at a site and either runs the standard
//! closed loop (issue one operation, wait for the reply, think for an
//! exponentially distributed time, repeat — matching the paper's "we
//! intensify the workload by increasing the number of clients") or, with
//! [`ClientsConfig::arrival_rate`] set, an open loop: operations arrive
//! by a per-client Poisson process regardless of replies, the model that
//! exposes overload behaviour a closed loop can never reach (offered
//! load past saturation → unbounded queueing delay).
//!
//! [`ClientTier`] packages one *group* of clients as a
//! [`WindowGroup`]: pool, workload generator, metrics and engine state,
//! together with the Reply → metrics → think → next-Issue arm all three
//! simulators used to duplicate. [`ClientGroups`] shards the tier into K
//! such groups (client `c` lives in group `c % K`) that fan out over the
//! `WorkerPool` like server groups do. Determinism across K rests on
//! three mechanisms, pinned by `tests/parallel_determinism.rs`:
//!
//! * **per-client RNG streams** — every client's RNG is
//!   `Rng::stream(seed, client_id)`, so its draw sequence is identical
//!   no matter which group executes it;
//! * **canonical cross-send order** — groups tag their `Arrive` sends
//!   with the issuing client's global id, and the engine merges all
//!   client groups at one source rank, so the merged order is the
//!   K-independent `(arrival time, client id)` order;
//! * **exactly mergeable metrics** — each group's [`SimMetrics`] merge
//!   by integer addition, bit-identical to a single-group run.
//!
//! A simulator plugs in by mapping its event enum through [`IssueReply`]
//! and routing freshly issued operations through [`IssueRouter`] on its
//! shared context — which is also all a *fourth* simulator needs to do.
//! One constraint inherited by the routing half: per-client draws must
//! come from the client's own RNG (`tier.clients.rng(client)`), never
//! from group-level state, or results cease to be K-invariant.

use crate::simnet::metrics::SimMetrics;
use crate::simnet::parallel::{GroupCore, WindowGroup};
use crate::util::{Rng, VTime};
use crate::workload::generator::OpGenerator;

/// Client-tier configuration.
#[derive(Debug, Clone)]
pub struct ClientsConfig {
    /// Number of clients.
    pub n: usize,
    /// Mean think time between reply and next request (ms). 0 = replay
    /// as fast as possible (stress). Ignored in open-loop mode.
    pub think_ms: f64,
    /// Number of client sites; clients are assigned round-robin
    /// ("we equally distribute client threads across client nodes").
    pub sites: usize,
    /// Seed for the per-client RNG streams.
    pub seed: u64,
    /// Number of client groups the tier is sharded into (each a
    /// [`WindowGroup`] scheduled over the worker pool). `0` = one per
    /// available core. Results are bit-identical for every value.
    pub groups: usize,
    /// Open-loop mode: mean per-client arrival rate in ops/sec (Poisson
    /// arrivals, independent of replies). `None` = closed loop.
    pub arrival_rate: Option<f64>,
    /// Keep only the flat-memory bucketed latency aggregation (no
    /// per-sample vectors) — the million-client scaling mode; see
    /// [`SimMetrics::bucketed`].
    pub bucketed: bool,
}

impl Default for ClientsConfig {
    fn default() -> Self {
        ClientsConfig {
            n: 1,
            think_ms: 0.0,
            sites: 1,
            seed: 0xC11E,
            groups: 1,
            arrival_rate: None,
            bucketed: false,
        }
    }
}

impl ClientsConfig {
    /// The effective group count: `0` resolves to the available cores,
    /// and the count never exceeds the number of clients.
    pub fn resolved_groups(&self) -> usize {
        let k = if self.groups == 0 {
            crate::simnet::parallel::available_threads()
        } else {
            self.groups
        };
        k.min(self.n.max(1)).max(1)
    }
}

/// One group's slice of the client pool: the per-client RNG streams and
/// issue accounting for every client `c` with `c % groups == group`.
///
/// RNGs are derived as `Rng::stream(cfg.seed, c)` — a pure function of
/// the *global* client id — so a client's draw sequence does not depend
/// on the group count. All client-facing accessors take global ids.
#[derive(Debug)]
pub struct ClientPool {
    cfg: ClientsConfig,
    group: usize,
    groups: usize,
    /// Indexed by local position `c / groups`.
    rngs: Vec<Rng>,
    /// Group-level running total (the per-client `issued` Vec of earlier
    /// revisions is gone: per-client detail was unused, and the running
    /// total makes [`total_issued`](Self::total_issued) O(1) instead of
    /// an O(n) sum — at a million clients that sum was a real cost).
    issued: u64,
}

impl ClientPool {
    /// A pool holding *all* clients as a single group.
    pub fn new(cfg: ClientsConfig) -> Self {
        Self::for_group(cfg, 0, 1)
    }

    /// The pool slice for `group` of `groups` (clients `c` with
    /// `c % groups == group`).
    pub fn for_group(cfg: ClientsConfig, group: usize, groups: usize) -> Self {
        assert!(groups >= 1 && group < groups, "group {group} of {groups}");
        let rngs = (group..cfg.n)
            .step_by(groups)
            .map(|c| Rng::stream(cfg.seed, c as u64))
            .collect();
        ClientPool { cfg, group, groups, rngs, issued: 0 }
    }

    /// Total number of clients across all groups.
    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// Number of clients in *this* group.
    pub fn members(&self) -> usize {
        self.rngs.len()
    }

    /// This pool's group id.
    pub fn group(&self) -> usize {
        self.group
    }

    /// The total group count this pool was sliced for.
    pub fn groups(&self) -> usize {
        self.groups
    }

    /// Whether arrivals are open-loop (rate-driven) rather than
    /// closed-loop (reply-driven).
    pub fn is_open_loop(&self) -> bool {
        self.cfg.arrival_rate.is_some()
    }

    /// The site a client lives at (round-robin over sites, by global id —
    /// independent of the group count).
    pub fn site(&self, client: usize) -> usize {
        client % self.cfg.sites
    }

    /// Per-client deterministic RNG (workload generation), by global id.
    pub fn rng(&mut self, client: usize) -> &mut Rng {
        debug_assert_eq!(client % self.groups, self.group, "client {client} not in this group");
        &mut self.rngs[client / self.groups]
    }

    /// Record one issued operation (O(1) group-level counter).
    pub fn note_issue(&mut self) {
        self.issued += 1;
    }

    /// The think delay before a client's next issue (exponential;
    /// zero-mean collapses to zero).
    pub fn think(&mut self, client: usize) -> VTime {
        if self.cfg.think_ms <= 0.0 {
            return VTime::ZERO;
        }
        let ms = self.rng(client).exp(self.cfg.think_ms);
        VTime::from_millis_f64(ms)
    }

    /// Open-loop inter-arrival delay for a client's next issue: `None`
    /// in closed-loop mode, else an exponential draw with mean
    /// `1000 / arrival_rate` ms, floored at 1 µs so one client's issue
    /// times are strictly increasing.
    pub fn next_arrival(&mut self, client: usize) -> Option<VTime> {
        let rate = self.cfg.arrival_rate?;
        let ms = self.rng(client).exp(1_000.0 / rate.max(f64::MIN_POSITIVE));
        Some(VTime::from_millis_f64(ms).max(VTime::from_micros(1)))
    }

    /// A client's first issue time, drawn from *its own* RNG stream (the
    /// first draw of the stream, so it is identical at any group count).
    /// Closed loop: uniform over one think window (or 1 ms under zero
    /// think time) — replacing the old `(c % 97) * 13` µs pattern that
    /// landed ~n/97 clients on each of 97 distinct microseconds at large
    /// n. Open loop: one exponential inter-arrival.
    pub fn first_issue(&mut self, client: usize) -> VTime {
        match self.next_arrival(client) {
            Some(dt) => dt,
            None => {
                let span_ms = self.cfg.think_ms.max(1.0);
                let ms = self.rng(client).f64() * span_ms;
                VTime::from_millis_f64(ms)
            }
        }
    }

    /// Operations issued by this group's clients so far (O(1)).
    pub fn total_issued(&self) -> u64 {
        self.issued
    }
}

/// A simulator event decomposed into the client tier's view: the two
/// arms the shared tier handles itself, or a server-side event (which a
/// correctly wired simulation never delivers to the tier).
#[derive(Debug)]
pub enum ClientEv<E> {
    /// A client issues its next operation (after thinking, in the closed
    /// loop; by Poisson arrival, in the open loop).
    Issue {
        /// The issuing client.
        client: usize,
    },
    /// A server's reply reached the client.
    Reply {
        /// The client the reply is for.
        client: usize,
        /// When the operation was issued (latency = now − issued).
        issued: VTime,
        /// Per-class metrics bucket: `true` = the simulator's expensive
        /// class (global / distributed / write), `false` = the cheap one.
        flag: bool,
    },
    /// Not a client-tier event.
    Other(E),
}

/// How a simulator's event enum maps onto the client tier's two arms.
/// Implemented by each simulation's `Ev` type; everything else about the
/// client loop is shared.
pub trait IssueReply: Sized + Send {
    /// Decompose an incoming event into the shared client-tier arms.
    fn classify(self) -> ClientEv<Self>;
    /// The Issue event for `client` (scheduled after the think delay or
    /// inter-arrival gap).
    fn issue(client: usize) -> Self;
}

/// The per-simulation half of the client tier, implemented on the
/// simulation's shared window context: route one freshly issued
/// operation — draw it from `tier.gen` with the client's RNG, pick the
/// target server, and buffer the `Arrive` cross-send on `tier.core`
/// (via [`GroupCore::send_tagged`] with the client's global id, so the
/// engine's merge order is group-count-independent).
pub trait IssueRouter<E: IssueReply> {
    /// Client `client` issues its next operation.
    fn route_issue(&self, tier: &mut ClientTier<'_, E>, client: usize);
}

/// One client group of a window-parallel simulation: a slice of the
/// client pool, a workload generator, per-group metrics and engine
/// state. Groups are first-class [`WindowGroup`]s, fanned out over the
/// worker pool alongside server groups; [`ClientGroups`] owns the K of
/// them. Shared by every simulator; see the module docs for how a
/// simulation plugs in.
pub struct ClientTier<'a, E> {
    /// This group's slice of the client pool (sites, per-client RNG
    /// streams, think times).
    pub clients: ClientPool,
    /// The workload generator operations are drawn from (one instance
    /// per group; stateful generators should be constructed per-group
    /// via the factory passed to [`ClientGroups::new`]).
    pub gen: Box<dyn OpGenerator + 'a>,
    /// Latency/throughput collection over the measurement window (merged
    /// across groups by [`ClientGroups::metrics`]).
    pub metrics: SimMetrics,
    /// The group's window-engine state (event queue + cross-send buffer).
    pub core: GroupCore<E>,
    /// Lazily released first-issue schedule: `(time µs, client)` sorted
    /// ascending, drained into the event queue window by window — a
    /// million-client boot allocates 12 B/client here instead of
    /// pre-scheduling a million queue events.
    boot: Vec<(u64, u32)>,
    boot_next: usize,
}

impl<'a, E: IssueReply> ClientTier<'a, E> {
    /// Build a single-group tier over all clients: the pool is built
    /// from `cfg` with its site count overridden to `sites` (simulators
    /// derive it from the topology), and metrics measure
    /// `[warmup, horizon]`.
    pub fn new(
        cfg: ClientsConfig,
        sites: usize,
        gen: Box<dyn OpGenerator + 'a>,
        warmup: VTime,
        horizon: VTime,
    ) -> Self {
        Self::for_group(ClientsConfig { sites, ..cfg }, 0, 1, gen, warmup, horizon)
    }

    /// Build group `group` of `groups` (cfg's site count already set).
    pub fn for_group(
        cfg: ClientsConfig,
        group: usize,
        groups: usize,
        gen: Box<dyn OpGenerator + 'a>,
        warmup: VTime,
        horizon: VTime,
    ) -> Self {
        let metrics = if cfg.bucketed {
            SimMetrics::bucketed(warmup, horizon)
        } else {
            SimMetrics::new(warmup, horizon)
        };
        ClientTier {
            clients: ClientPool::for_group(cfg, group, groups),
            gen,
            metrics,
            core: GroupCore::new(),
            boot: Vec::new(),
            boot_next: 0,
        }
    }

    /// Boot this group's clients: draw every member's first-issue time
    /// from its own RNG stream and stage the sorted list for lazy
    /// release (entries enter the event queue only as the window
    /// crosses them).
    pub fn boot(&mut self) {
        let (group, groups) = (self.clients.group(), self.clients.groups());
        let mut entries = Vec::with_capacity(self.clients.members());
        for local in 0..self.clients.members() {
            let c = group + local * groups;
            let at = self.clients.first_issue(c);
            entries.push((at.as_micros(), c as u32));
        }
        // Ties sort by client id: deterministic, group-independent.
        entries.sort_unstable();
        self.boot = entries;
        self.boot_next = 0;
    }

    /// Release staged first issues at or before `cut` into the queue.
    /// Sound w.r.t. the queue's "never schedule into the past" check:
    /// entries beyond a window's cut stay staged, so anything released
    /// later is above the previous cut ≥ the queue's clock.
    fn release_boot(&mut self, cut: VTime) {
        while let Some(&(at, c)) = self.boot.get(self.boot_next) {
            let at = VTime::from_micros(at);
            if at > cut {
                return;
            }
            self.boot_next += 1;
            self.core.q.schedule_at(at, E::issue(c as usize));
        }
        // Fully released: drop the staging list.
        self.boot = Vec::new();
        self.boot_next = 0;
    }
}

impl<Ctx, E> WindowGroup<Ctx> for ClientTier<'_, E>
where
    E: IssueReply,
    Ctx: IssueRouter<E>,
{
    type Ev = E;

    fn core(&self) -> &GroupCore<E> {
        &self.core
    }

    fn core_mut(&mut self) -> &mut GroupCore<E> {
        &mut self.core
    }

    fn handle(&mut self, ev: E, ctx: &Ctx) {
        match ev.classify() {
            ClientEv::Issue { client } => {
                self.clients.note_issue();
                ctx.route_issue(self, client);
                // Open loop: the next arrival is time-driven, scheduled
                // at issue; the reply only records metrics.
                if let Some(dt) = self.clients.next_arrival(client) {
                    self.core.q.schedule(dt, E::issue(client));
                }
            }
            ClientEv::Reply { client, issued, flag } => {
                self.metrics.complete(issued, self.core.q.now(), flag);
                if !self.clients.is_open_loop() {
                    let think = self.clients.think(client);
                    self.core.q.schedule(think, E::issue(client));
                }
            }
            ClientEv::Other(_) => unreachable!("server event delivered to the client tier"),
        }
    }

    /// Earliest pending work: the queue head or the next staged boot
    /// entry, whichever is sooner.
    fn peek(&self) -> Option<VTime> {
        let q = self.core.q.peek_time();
        match self.boot.get(self.boot_next) {
            Some(&(at, _)) => {
                let b = VTime::from_micros(at);
                Some(q.map_or(b, |t| t.min(b)))
            }
            None => q,
        }
    }

    /// Release staged boot entries up to `cut`, then drain as usual.
    fn drain(&mut self, cut: VTime, ctx: &Ctx) {
        self.release_boot(cut);
        while let Some((_, ev)) = self.core.q.pop_through(cut) {
            self.handle(ev, ctx);
        }
    }
}

/// The sharded client tier: K [`ClientTier`] groups over one client
/// population. Client `c` lives in group `c % K`; the engine schedules
/// the groups over the worker pool alongside server groups and merges
/// their cross-sends in a canonical order, so every observable result is
/// bit-identical for any K (see the module docs).
pub struct ClientGroups<'a, E> {
    /// The groups, indexed by group id. Pass `&mut groups` straight to
    /// [`run_windows`](crate::simnet::parallel::run_windows).
    pub groups: Vec<ClientTier<'a, E>>,
}

impl<'a, E: IssueReply> ClientGroups<'a, E> {
    /// Shard the tier: `cfg.groups` resolves via
    /// [`ClientsConfig::resolved_groups`], the site count is overridden
    /// to `sites`, and `gen_for(g)` supplies group `g`'s generator
    /// instance (stateful generators get independent per-group state —
    /// construct them with a per-group stream where available).
    pub fn new(
        cfg: ClientsConfig,
        sites: usize,
        warmup: VTime,
        horizon: VTime,
        mut gen_for: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
    ) -> Self {
        let cfg = ClientsConfig { sites, ..cfg };
        let k = cfg.resolved_groups();
        let groups = (0..k)
            .map(|g| ClientTier::for_group(cfg.clone(), g, k, gen_for(g), warmup, horizon))
            .collect();
        ClientGroups { groups }
    }

    /// The group count K.
    pub fn k(&self) -> usize {
        self.groups.len()
    }

    /// Boot every group (stage all first issues).
    pub fn boot(&mut self) {
        for g in &mut self.groups {
            g.boot();
        }
    }

    /// The tier's metrics, merged over groups in canonical group order
    /// (integer stats are merge-order-insensitive; sample vectors
    /// concatenate in group order).
    pub fn metrics(&self) -> SimMetrics {
        let mut m = self.groups[0].metrics.clone();
        for g in &self.groups[1..] {
            m.merge(&g.metrics);
        }
        m
    }

    /// Events processed across all groups.
    pub fn processed(&self) -> u64 {
        self.groups.iter().map(|g| g.core.q.processed()).sum()
    }

    /// Operations issued across all groups (O(K)).
    pub fn total_issued(&self) -> u64 {
        self.groups.iter().map(|g| g.clients.total_issued()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Bindings;
    use crate::workload::spec::Operation;

    fn null_gen<'a>() -> Box<dyn OpGenerator + 'a> {
        Box::new(|_rng: &mut Rng, _site: usize, _n: usize| Operation {
            txn: 0,
            args: Bindings::new(),
        })
    }

    /// Toy event enum exercising the tier without a simulator.
    #[derive(Debug)]
    enum TEv {
        Issue(usize),
        Reply(usize, VTime),
    }

    impl IssueReply for TEv {
        fn classify(self) -> ClientEv<TEv> {
            match self {
                TEv::Issue(c) => ClientEv::Issue { client: c },
                TEv::Reply(c, at) => ClientEv::Reply { client: c, issued: at, flag: false },
            }
        }
        fn issue(client: usize) -> Self {
            TEv::Issue(client)
        }
    }

    /// Routing sink that drops every issued operation.
    struct NullCtx;
    impl IssueRouter<TEv> for NullCtx {
        fn route_issue(&self, _tier: &mut ClientTier<'_, TEv>, _client: usize) {}
    }

    #[test]
    fn round_robin_sites() {
        let p = ClientPool::new(ClientsConfig { n: 7, sites: 3, ..Default::default() });
        assert_eq!(p.site(0), 0);
        assert_eq!(p.site(1), 1);
        assert_eq!(p.site(2), 2);
        assert_eq!(p.site(3), 0);
        assert_eq!(p.site(6), 0);
    }

    #[test]
    fn zero_think_time_is_zero() {
        let mut p = ClientPool::new(ClientsConfig { n: 2, think_ms: 0.0, ..Default::default() });
        assert_eq!(p.think(0), VTime::ZERO);
    }

    #[test]
    fn issue_accounting_is_a_running_total() {
        let mut p = ClientPool::new(ClientsConfig { n: 3, ..Default::default() });
        assert_eq!(p.total_issued(), 0);
        for _ in 0..5 {
            p.note_issue();
        }
        assert_eq!(p.total_issued(), 5);
    }

    #[test]
    fn think_time_mean_roughly_matches() {
        let mut p =
            ClientPool::new(ClientsConfig { n: 1, think_ms: 10.0, ..Default::default() });
        let total: f64 = (0..20_000).map(|_| p.think(0).as_millis_f64()).sum();
        let mean = total / 20_000.0;
        assert!((mean - 10.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn client_rngs_are_independent_and_deterministic() {
        let mut a = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        let mut b = ClientPool::new(ClientsConfig { n: 2, seed: 1, ..Default::default() });
        assert_eq!(a.rng(0).next_u64(), b.rng(0).next_u64());
        assert_ne!(a.rng(0).next_u64(), a.rng(1).next_u64());
    }

    /// The K-invariance cornerstone: a client's RNG stream is a pure
    /// function of its global id, so group pools hand every member the
    /// exact same stream the single-group pool does.
    #[test]
    fn group_pools_partition_clients_with_identical_streams() {
        let cfg = ClientsConfig { n: 10, seed: 42, ..Default::default() };
        for k in [2usize, 3, 10] {
            let mut covered = 0;
            for g in 0..k {
                let mut part = ClientPool::for_group(cfg.clone(), g, k);
                covered += part.members();
                let mut whole = ClientPool::new(cfg.clone());
                for c in (g..10).step_by(k) {
                    assert_eq!(part.site(c), whole.site(c));
                    assert_eq!(
                        part.rng(c).next_u64(),
                        whole.rng(c).next_u64(),
                        "k={k} client={c}"
                    );
                }
            }
            assert_eq!(covered, 10, "groups must partition the population (k={k})");
        }
    }

    #[test]
    fn resolved_groups_caps_at_client_count() {
        let cfg = ClientsConfig { n: 3, groups: 8, ..Default::default() };
        assert_eq!(cfg.resolved_groups(), 3);
        let auto = ClientsConfig { n: 1_000, groups: 0, ..Default::default() };
        assert!(auto.resolved_groups() >= 1);
        assert_eq!(ClientsConfig::default().resolved_groups(), 1);
    }

    /// Satellite bugfix: the boot stagger is RNG-derived per client —
    /// spread over the think window with far more than the 97 distinct
    /// instants of the old `(c % 97) * 13` pattern — and identical
    /// whether a client boots in a single-group or a sharded tier.
    #[test]
    fn boot_stagger_is_rng_derived_and_partition_stable() {
        let cfg =
            ClientsConfig { n: 500, think_ms: 10.0, seed: 9, ..Default::default() };
        let w = (VTime::from_secs(1), VTime::from_secs(2));
        let mut single: ClientTier<'_, TEv> =
            ClientTier::new(cfg.clone(), 1, null_gen(), w.0, w.1);
        single.boot();
        let mut by_client: Vec<u64> = vec![0; 500];
        for &(at, c) in &single.boot {
            assert!(VTime::from_micros(at) < VTime::from_millis(10), "within think window");
            by_client[c as usize] = at;
        }
        let mut distinct: Vec<u64> = by_client.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() > 400, "only {} distinct boot instants", distinct.len());
        // Sharded groups draw the same per-client times.
        let mut tiers = ClientGroups::<TEv>::new(
            ClientsConfig { groups: 3, ..cfg },
            1,
            w.0,
            w.1,
            |_| null_gen(),
        );
        tiers.boot();
        for t in &tiers.groups {
            for &(at, c) in &t.boot {
                assert_eq!(at, by_client[c as usize], "client {c}");
            }
        }
    }

    /// Boot entries enter the event queue lazily, window by window.
    #[test]
    fn lazy_boot_releases_entries_through_the_cut() {
        let cfg =
            ClientsConfig { n: 200, think_ms: 10.0, seed: 4, ..Default::default() };
        let mut tier: ClientTier<'_, TEv> =
            ClientTier::new(cfg, 1, null_gen(), VTime::from_secs(1), VTime::from_secs(2));
        tier.boot();
        assert_eq!(tier.core.q.len(), 0, "boot stages, it does not schedule");
        let first = tier.peek().expect("staged work is visible to peek");
        let cut = VTime::from_millis(2);
        tier.drain(cut, &NullCtx);
        let released = tier.core.q.processed();
        assert!(released > 0 && released < 200, "released={released}");
        assert_eq!(released as usize, 200 - (tier.boot.len() - tier.boot_next));
        let next = tier.peek().expect("remaining boot entries still pending");
        assert!(next > cut && next >= first);
        // Draining to the end releases everyone and drops the stage list.
        tier.drain(VTime::from_millis(10), &NullCtx);
        assert_eq!(tier.core.q.processed(), 200);
        assert!(tier.boot.is_empty());
        assert_eq!(tier.clients.total_issued(), 200);
    }

    /// Open loop: issues are time-driven (scheduled at issue, not at
    /// reply), and replies only record metrics.
    #[test]
    fn open_loop_decouples_arrivals_from_replies() {
        let cfg = ClientsConfig {
            n: 1,
            arrival_rate: Some(100.0),
            ..Default::default()
        };
        let mut tier: ClientTier<'_, TEv> =
            ClientTier::new(cfg, 1, null_gen(), VTime::ZERO, VTime::from_secs(1));
        tier.handle(TEv::Issue(0), &NullCtx);
        assert_eq!(tier.core.q.len(), 1, "the next arrival is already scheduled");
        assert_eq!(tier.clients.total_issued(), 1);
        tier.handle(TEv::Reply(0, VTime::ZERO), &NullCtx);
        assert_eq!(tier.core.q.len(), 1, "a reply schedules nothing in open loop");
        assert_eq!(tier.metrics.completed, 1);
        // Closed loop for contrast: the reply drives the next issue.
        let mut closed: ClientTier<'_, TEv> = ClientTier::new(
            ClientsConfig { n: 1, ..Default::default() },
            1,
            null_gen(),
            VTime::ZERO,
            VTime::from_secs(1),
        );
        closed.handle(TEv::Reply(0, VTime::ZERO), &NullCtx);
        assert_eq!(closed.core.q.len(), 1, "closed loop reissues on reply");
        closed.handle(TEv::Issue(0), &NullCtx);
        assert_eq!(closed.core.q.len(), 1, "issue schedules nothing further");
    }

    #[test]
    fn group_metrics_merge_over_all_groups() {
        let cfg = ClientsConfig { n: 6, ..Default::default() };
        let mut tiers = ClientGroups::<TEv>::new(
            ClientsConfig { groups: 3, ..cfg },
            1,
            VTime::ZERO,
            VTime::from_secs(1),
            |_| null_gen(),
        );
        for (g, t) in tiers.groups.iter_mut().enumerate() {
            for local in 0..t.clients.members() {
                let c = g + local * 3;
                t.handle(TEv::Issue(c), &NullCtx);
                t.handle(TEv::Reply(c, VTime::ZERO), &NullCtx);
            }
        }
        assert_eq!(tiers.k(), 3);
        assert_eq!(tiers.total_issued(), 6);
        let m = tiers.metrics();
        assert_eq!(m.completed, 6);
        assert_eq!(m.latency_hist.count(), 6);
    }
}
