//! The discrete-event queue: a binary heap of (time, seq, event) with a
//! monotone sequence number for deterministic FIFO tie-breaking.

use crate::util::VTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Generic event queue over an event payload type `E`.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    now: VTime,
    seq: u64,
    popped: u64,
}

#[derive(Debug)]
struct Entry<E> {
    at: VTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: VTime::ZERO, seq: 0, popped: 0 }
    }

    /// Current virtual time.
    pub fn now(&self) -> VTime {
        self.now
    }

    /// Events processed so far.
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Schedule `ev` to fire `delay` after now.
    pub fn schedule(&mut self, delay: VTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Schedule `ev` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: VTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.heap.push(Reverse(Entry { at, seq: self.seq, ev }));
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(VTime, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        self.popped += 1;
        Some((e.at, e.ev))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<VTime> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Convenience trait for simulations: run until a time horizon.
pub trait Schedulable {
    type Event;
    /// Handle one event; may schedule more.
    fn handle(&mut self, at: VTime, ev: Self::Event, q: &mut EventQueue<Self::Event>);
}

/// Drive a [`Schedulable`] until `horizon` (events after the horizon stay
/// unprocessed). Returns the number of events handled.
pub fn run_until<S: Schedulable>(
    sys: &mut S,
    q: &mut EventQueue<S::Event>,
    horizon: VTime,
) -> u64 {
    let mut n = 0;
    while let Some(t) = q.peek_time() {
        if t > horizon {
            break;
        }
        let (at, ev) = q.pop().unwrap();
        sys.handle(at, ev, q);
        n += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(5), "b");
        q.schedule(VTime::from_millis(1), "a");
        q.schedule(VTime::from_millis(5), "c"); // same time as b, later seq
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(10), 1u32);
        q.schedule(VTime::from_millis(2), 2u32);
        q.pop();
        assert_eq!(q.now(), VTime::from_millis(2));
        // Relative scheduling is from the advanced clock.
        q.schedule(VTime::from_millis(1), 3u32);
        assert_eq!(q.pop().unwrap().1, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.now(), VTime::from_millis(10));
    }

    struct Counter {
        fired: Vec<u64>,
    }

    impl Schedulable for Counter {
        type Event = u64;
        fn handle(&mut self, _at: VTime, ev: u64, q: &mut EventQueue<u64>) {
            self.fired.push(ev);
            if ev < 3 {
                q.schedule(VTime::from_millis(10), ev + 1);
            }
        }
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut sys = Counter { fired: vec![] };
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(0), 0u64);
        let n = run_until(&mut sys, &mut q, VTime::from_millis(25));
        // Events at 0, 10, 20 fire; 30 is past the horizon.
        assert_eq!(n, 3);
        assert_eq!(sys.fired, vec![0, 1, 2]);
        assert_eq!(q.len(), 1);
    }
}
