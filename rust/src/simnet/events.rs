//! The discrete-event queue: a binary heap of (time, seq) keys over a
//! pooled slot table of event payloads, with a monotone sequence number
//! for deterministic FIFO tie-breaking.
//!
//! Pooling (ROADMAP item): the heap itself stores only small `Copy` keys;
//! payloads live in an index-addressed slot table whose entries are
//! recycled through a free list. A simulation that schedules and pops
//! millions of events therefore reaches a steady state where neither the
//! heap vector nor the slot table reallocates — the event loop stops
//! paying allocator time per event.

use crate::util::VTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Generic event queue over an event payload type `E`.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<HeapKey>>,
    /// Pooled payload slots; `None` = free (listed in `free`).
    slots: Vec<Option<E>>,
    free: Vec<u32>,
    now: VTime,
    seq: u64,
    popped: u64,
}

/// Heap entry: ordering key plus the payload's slot index. `Copy`, so
/// heap sift operations never touch payloads.
#[derive(Debug, Clone, Copy)]
struct HeapKey {
    at: VTime,
    seq: u64,
    slot: u32,
}

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue at virtual time zero.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            now: VTime::ZERO,
            seq: 0,
            popped: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> VTime {
        self.now
    }

    /// Events processed so far.
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Schedule `ev` to fire `delay` after now.
    pub fn schedule(&mut self, delay: VTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Schedule `ev` at absolute time `at` (must not be in the past).
    pub fn schedule_at(&mut self, at: VTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize] = Some(ev);
                s
            }
            None => {
                let s = self.slots.len() as u32;
                self.slots.push(Some(ev));
                s
            }
        };
        self.heap.push(Reverse(HeapKey { at, seq: self.seq, slot }));
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(VTime, E)> {
        let Reverse(k) = self.heap.pop()?;
        self.now = k.at;
        self.popped += 1;
        let ev = self.slots[k.slot as usize].take().expect("slot occupied");
        self.free.push(k.slot);
        Some((k.at, ev))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<VTime> {
        self.heap.peek().map(|Reverse(k)| k.at)
    }

    /// Pop the next event only if it fires strictly before `cut`
    /// (exclusive bound).
    pub fn pop_before(&mut self, cut: VTime) -> Option<(VTime, E)> {
        match self.peek_time() {
            Some(t) if t < cut => self.pop(),
            _ => None,
        }
    }

    /// Pop the next event only if it fires at or before `cut` (inclusive
    /// bound) — the drain primitive of the window-parallel engine: a
    /// group processes its own events up to the window bound and no
    /// further. The inclusive form lets the engine express windows that
    /// reach the very top of representable virtual time without
    /// overflowing (an exclusive bound above [`VTime`]'s maximum does
    /// not exist).
    pub fn pop_through(&mut self, cut: VTime) -> Option<(VTime, E)> {
        match self.peek_time() {
            Some(t) if t <= cut => self.pop(),
            _ => None,
        }
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Number of payload slots ever allocated (diagnostics: a steady-state
    /// simulation should see this plateau at its peak in-flight event
    /// count, proving slots are recycled rather than re-allocated).
    pub fn pooled_slots(&self) -> usize {
        self.slots.len()
    }
}

/// Convenience trait for simulations: run until a time horizon.
pub trait Schedulable {
    /// The event payload type.
    type Event;
    /// Handle one event; may schedule more.
    fn handle(&mut self, at: VTime, ev: Self::Event, q: &mut EventQueue<Self::Event>);
}

/// Drive a [`Schedulable`] until `horizon` (events after the horizon stay
/// unprocessed). Returns the number of events handled.
pub fn run_until<S: Schedulable>(
    sys: &mut S,
    q: &mut EventQueue<S::Event>,
    horizon: VTime,
) -> u64 {
    let mut n = 0;
    while let Some(t) = q.peek_time() {
        if t > horizon {
            break;
        }
        let (at, ev) = q.pop().unwrap();
        sys.handle(at, ev, q);
        n += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(5), "b");
        q.schedule(VTime::from_millis(1), "a");
        q.schedule(VTime::from_millis(5), "c"); // same time as b, later seq
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_before_respects_the_cut() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(1), "a");
        q.schedule(VTime::from_millis(5), "b");
        assert_eq!(q.pop_before(VTime::from_millis(5)).unwrap().1, "a");
        // Exclusive bound: an event *at* the cut stays queued.
        assert!(q.pop_before(VTime::from_millis(5)).is_none());
        assert_eq!(q.pop_before(VTime::from_millis(6)).unwrap().1, "b");
        assert!(q.pop_before(VTime::from_secs(1)).is_none());
    }

    #[test]
    fn pop_through_is_inclusive_and_overflow_free() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(1), "a");
        q.schedule(VTime::from_millis(5), "b");
        assert_eq!(q.pop_through(VTime::from_millis(4)).unwrap().1, "a");
        // Inclusive bound: an event *at* the cut pops.
        assert_eq!(q.pop_through(VTime::from_millis(5)).unwrap().1, "b");
        assert!(q.pop_through(VTime::from_secs(1)).is_none());
        // The maximum representable time is a valid inclusive cut: it
        // admits every event, including one at the maximum itself.
        q.schedule_at(VTime::from_micros(u64::MAX), "z");
        assert_eq!(q.pop_through(VTime::from_micros(u64::MAX)).unwrap().1, "z");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(10), 1u32);
        q.schedule(VTime::from_millis(2), 2u32);
        q.pop();
        assert_eq!(q.now(), VTime::from_millis(2));
        // Relative scheduling is from the advanced clock.
        q.schedule(VTime::from_millis(1), 3u32);
        assert_eq!(q.pop().unwrap().1, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.now(), VTime::from_millis(10));
    }

    struct Counter {
        fired: Vec<u64>,
    }

    impl Schedulable for Counter {
        type Event = u64;
        fn handle(&mut self, _at: VTime, ev: u64, q: &mut EventQueue<u64>) {
            self.fired.push(ev);
            if ev < 3 {
                q.schedule(VTime::from_millis(10), ev + 1);
            }
        }
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut sys = Counter { fired: vec![] };
        let mut q = EventQueue::new();
        q.schedule(VTime::from_millis(0), 0u64);
        let n = run_until(&mut sys, &mut q, VTime::from_millis(25));
        // Events at 0, 10, 20 fire; 30 is past the horizon.
        assert_eq!(n, 3);
        assert_eq!(sys.fired, vec![0, 1, 2]);
        assert_eq!(q.len(), 1);
    }

    /// ROADMAP pooling item: behaviour (pop order, `processed()` counts)
    /// must be unchanged by the slot pool, and slots must be recycled.
    #[test]
    fn pooling_preserves_order_and_counts_and_recycles_slots() {
        let mut q = EventQueue::new();
        // Interleave schedule/pop for many rounds with a bounded number
        // of in-flight events; replicate the expected order with a
        // reference model ((time, insertion#) sort).
        let mut reference: Vec<(u64, u64)> = Vec::new();
        let mut ins = 0u64;
        let mut got: Vec<u64> = Vec::new();
        for round in 0..1000u64 {
            // Two pushes, one pop per round: ≤ ~1001 in flight, 2000 total.
            for k in 0..2 {
                let at = (round * 7 + k * 13) % 50 + round; // non-monotone-ish but >= now
                let at = at.max(q.now().as_micros());
                q.schedule_at(VTime::from_micros(at), ins);
                reference.push((at, ins));
                ins += 1;
            }
            got.push(q.pop().unwrap().1);
        }
        while let Some((_, v)) = q.pop() {
            got.push(v);
        }
        reference.sort(); // (time, insertion#) = (time, seq) tie-break
        let expect: Vec<u64> = reference.into_iter().map(|(_, v)| v).collect();
        assert_eq!(got, expect);
        assert_eq!(q.processed(), 2000);
        // Slot pool plateaus at the peak in-flight count, far below the
        // total number of scheduled events.
        assert!(q.pooled_slots() <= 1002, "slots={}", q.pooled_slots());
        assert!(q.is_empty());
    }
}
