//! Measurement collection with a warm-up cutoff.
//!
//! Latency is aggregated twice: exact [`Summary`] samples (the harness
//! sorts them for percentile tables) and a mergeable integer-microsecond
//! [`LatencyStat`] — a count, a sum, min/max and a log-bucketed
//! histogram. The integer stats merge *exactly*: element-wise `u64`
//! addition is commutative and associative, so the per-group metrics of
//! a sharded client tier combine into bit-identical totals no matter how
//! many groups there are or in which order they merge. At million-client
//! scale the sample vectors are the only per-operation state, so
//! [`SimMetrics::bucketed`] turns them off and leaves the flat-memory
//! histograms as the sole aggregation (~6 KB per class, independent of
//! the operation count).

use crate::util::stats::Summary;
use crate::util::VTime;

/// Sub-bucket resolution of the latency histogram: `2^SUB_BITS` linear
/// sub-buckets per power-of-two range, bounding the relative error of a
/// bucket's lower bound to `1 / 2^SUB_BITS` (~3%).
const SUB_BITS: u32 = 5;
const SUBS: u64 = 1 << SUB_BITS;
/// Total bucket count: the linear range `[0, 32)` µs plus 22 octaves of
/// 32 sub-buckets — covering latencies up to ~134 s before clamping into
/// the last bucket (far past any simulated horizon).
const BUCKETS: usize = 23 * SUBS as usize;

/// Bucket index of a latency in integer microseconds (HDR-style
/// log-linear: exact below 32 µs, ~3% resolution above).
fn bucket_of(us: u64) -> usize {
    if us < SUBS {
        return us as usize;
    }
    let top = 63 - us.leading_zeros(); // floor(log2), >= SUB_BITS
    let oct = (top - SUB_BITS + 1) as usize;
    let sub = ((us >> (top - SUB_BITS)) - SUBS) as usize;
    (oct * SUBS as usize + sub).min(BUCKETS - 1)
}

/// Lower bound (µs) of bucket `i` — the left inverse of [`bucket_of`].
fn bucket_lo(i: usize) -> u64 {
    if i < SUBS as usize {
        return i as u64;
    }
    let oct = (i / SUBS as usize) as u32;
    let sub = (i % SUBS as usize) as u64;
    (SUBS + sub) << (oct - 1)
}

/// Mergeable latency aggregation over integer microseconds: count, sum,
/// min/max and a log-bucketed histogram. Every field merges by exact
/// integer arithmetic, so merging per-group stats is order-insensitive
/// and bit-identical to recording into a single instance — the property
/// the sharded client tier's determinism rests on (pinned by the merge
/// tests below and by `tests/parallel_determinism.rs`).
#[derive(Debug, Clone, Default)]
pub struct LatencyStat {
    count: u64,
    sum_us: u64,
    min_us: u64,
    max_us: u64,
    /// Lazily allocated on first record; empty means "no samples".
    buckets: Vec<u64>,
}

impl LatencyStat {
    /// An empty aggregation (allocates no buckets until the first
    /// sample).
    pub fn new() -> Self {
        LatencyStat::default()
    }

    /// Record one latency sample in microseconds.
    pub fn record(&mut self, us: u64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
            self.min_us = u64::MAX;
        }
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
        self.buckets[bucket_of(us)] += 1;
    }

    /// Fold another aggregation into this one. Exact: recording a sample
    /// set into one instance and merging per-group instances over any
    /// partition of that set produce identical fields.
    pub fn merge(&mut self, other: &LatencyStat) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
            self.min_us = u64::MAX;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += *o;
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Sum of all samples in microseconds (exact).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Mean latency in milliseconds (exact integer sum, one final
    /// division — identical bits for any merge order).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        (self.sum_us as f64 / self.count as f64) / 1_000.0
    }

    /// Smallest recorded sample in milliseconds.
    pub fn min_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.min_us as f64 / 1_000.0
    }

    /// Largest recorded sample in milliseconds.
    pub fn max_ms(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max_us as f64 / 1_000.0
    }

    /// Nearest-rank quantile estimate in milliseconds: the lower bound of
    /// the bucket holding the ranked sample (≤3% below the exact value).
    /// `p` in `[0, 100]`.
    pub fn quantile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen > rank {
                return bucket_lo(i) as f64 / 1_000.0;
            }
        }
        self.max_ms()
    }

    /// Median estimate in milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.quantile_ms(50.0)
    }

    /// 99th-percentile estimate in milliseconds.
    pub fn p99_ms(&self) -> f64 {
        self.quantile_ms(99.0)
    }

    /// The raw histogram buckets (empty before the first sample) —
    /// signature material for the determinism suite.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// Operation latency/throughput metrics over a simulation run. Samples
/// completed before `warmup` are discarded (cold caches, empty token
/// pipelines); throughput is computed over the post-warm-up window.
#[derive(Debug, Clone)]
pub struct SimMetrics {
    warmup: VTime,
    horizon: VTime,
    /// All completed operations.
    pub latency: Summary,
    /// Local/commutative operations only (the RQ3 figures need local vs
    /// global separately).
    pub local_latency: Summary,
    /// Global operations only.
    pub global_latency: Summary,
    /// Mergeable integer-µs aggregation over all completed operations.
    pub latency_hist: LatencyStat,
    /// Mergeable aggregation over local/commutative operations.
    pub local_hist: LatencyStat,
    /// Mergeable aggregation over global operations.
    pub global_hist: LatencyStat,
    /// Operations completed after warm-up.
    pub completed: u64,
    /// Operations that aborted (all retries exhausted).
    pub aborted: u64,
    /// When set, per-sample `Summary` vectors are not populated — only
    /// the flat-memory bucketed stats (million-client runs).
    bucketed_only: bool,
}

impl SimMetrics {
    /// Metrics over `[warmup, horizon]` virtual time.
    pub fn new(warmup: VTime, horizon: VTime) -> Self {
        assert!(horizon > warmup);
        SimMetrics {
            warmup,
            horizon,
            latency: Summary::new(),
            local_latency: Summary::new(),
            global_latency: Summary::new(),
            latency_hist: LatencyStat::new(),
            local_hist: LatencyStat::new(),
            global_hist: LatencyStat::new(),
            completed: 0,
            aborted: 0,
            bucketed_only: false,
        }
    }

    /// Metrics that keep only the bucketed aggregation: memory stays flat
    /// (a few KB) no matter how many operations complete, at the price of
    /// ~3% percentile resolution. The scaling mode for million-client
    /// runs.
    pub fn bucketed(warmup: VTime, horizon: VTime) -> Self {
        SimMetrics { bucketed_only: true, ..Self::new(warmup, horizon) }
    }

    /// Whether per-sample collection is disabled (see
    /// [`bucketed`](Self::bucketed)).
    pub fn is_bucketed_only(&self) -> bool {
        self.bucketed_only
    }

    /// Record a completed operation. `global` selects the per-class bucket.
    ///
    /// Samples outside the measurement window are ignored: warm-up on
    /// the left, and anything completing *past the horizon* on the
    /// right — [`throughput`](Self::throughput) divides by the fixed
    /// `horizon − warmup` window, so a simulation that drove events
    /// beyond the horizon would otherwise silently inflate ops/sec.
    pub fn complete(&mut self, issued_at: VTime, done_at: VTime, global: bool) {
        if done_at < self.warmup || done_at > self.horizon {
            return;
        }
        let us = (done_at - issued_at).as_micros();
        self.latency_hist.record(us);
        if global {
            self.global_hist.record(us);
        } else {
            self.local_hist.record(us);
        }
        if !self.bucketed_only {
            let ms = us as f64 / 1_000.0;
            self.latency.add(ms);
            if global {
                self.global_latency.add(ms);
            } else {
                self.local_latency.add(ms);
            }
        }
        self.completed += 1;
    }

    /// Record an aborted operation.
    pub fn abort(&mut self) {
        self.aborted += 1;
    }

    /// Fold another group's metrics (same measurement window) into this
    /// one. Counters and bucketed stats merge exactly (order-insensitive
    /// integer adds); `Summary` samples concatenate, so callers merging
    /// several groups should do so in a canonical group order.
    pub fn merge(&mut self, other: &SimMetrics) {
        assert_eq!(
            (self.warmup, self.horizon),
            (other.warmup, other.horizon),
            "merging metrics over different measurement windows"
        );
        self.completed += other.completed;
        self.aborted += other.aborted;
        self.latency_hist.merge(&other.latency_hist);
        self.local_hist.merge(&other.local_hist);
        self.global_hist.merge(&other.global_hist);
        self.latency.merge(&other.latency);
        self.local_latency.merge(&other.local_latency);
        self.global_latency.merge(&other.global_latency);
        self.bucketed_only |= other.bucketed_only;
    }

    /// Throughput over the measurement window (ops/sec).
    pub fn throughput(&self) -> f64 {
        let window = (self.horizon - self.warmup).as_secs_f64();
        if window <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / window
    }

    /// Mean latency over all completed operations (ms), computed from
    /// the exact integer sum — bit-identical however many group metrics
    /// were merged in.
    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_hist.mean_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_discards_early_samples() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        m.complete(VTime::ZERO, VTime::from_millis(500), false); // pre-warmup
        m.complete(VTime::from_secs(1), VTime::from_millis(1500), false);
        assert_eq!(m.completed, 1);
        assert_eq!(m.latency.count(), 1);
        assert!((m.mean_latency_ms() - 500.0).abs() < 1e-9);
    }

    /// Satellite bugfix regression: the measurement window is inclusive
    /// at both edges and closed on the right. A sample at exactly
    /// `warmup` and one at exactly `horizon` count; a post-horizon
    /// sample is ignored, so it can no longer inflate `throughput()`
    /// (which divides by the fixed `horizon − warmup` window).
    #[test]
    fn window_boundaries_and_post_horizon_samples() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        m.complete(VTime::ZERO, VTime::from_secs(1), false); // done_at == warmup
        m.complete(VTime::from_secs(2), VTime::from_secs(3), true); // done_at == horizon
        assert_eq!(m.completed, 2);
        assert_eq!(m.local_latency.count(), 1);
        assert_eq!(m.global_latency.count(), 1);
        let tput = m.throughput();
        // A sample completing past the horizon must not count anywhere.
        m.complete(VTime::from_secs(2), VTime::from_secs(3) + VTime::from_micros(1), false);
        assert_eq!(m.completed, 2);
        assert_eq!(m.latency.count(), 2);
        assert!((m.throughput() - tput).abs() < 1e-12);
    }

    #[test]
    fn throughput_over_window() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        for i in 0..100 {
            let t = VTime::from_millis(1000 + i * 10);
            m.complete(t, t + VTime::from_millis(5), i % 2 == 0);
        }
        assert!((m.throughput() - 50.0).abs() < 1e-9);
        assert_eq!(m.local_latency.count(), 50);
        assert_eq!(m.global_latency.count(), 50);
    }

    #[test]
    fn bucket_addressing_round_trips() {
        // bucket_lo is the left inverse of bucket_of over the covered
        // range, and buckets tile the axis without gaps or overlaps.
        for us in (0u64..4096).chain([10_000, 123_456, 5_000_000, 30_000_000]) {
            let b = bucket_of(us);
            assert!(bucket_lo(b) <= us, "us={us} b={b}");
            if b + 1 < BUCKETS {
                assert!(us < bucket_lo(b + 1), "us={us} b={b}");
            }
        }
        for i in 1..BUCKETS {
            assert!(bucket_lo(i) > bucket_lo(i - 1), "i={i}");
            assert_eq!(bucket_of(bucket_lo(i)), i, "i={i}");
        }
        // Out-of-range latencies clamp into the last bucket.
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn latency_stat_basics_and_quantiles() {
        let mut s = LatencyStat::new();
        assert!(s.is_empty());
        assert!(s.mean_ms().is_nan());
        assert!(s.quantile_ms(50.0).is_nan());
        for us in [1_000u64, 2_000, 3_000, 4_000, 1_000_000] {
            s.record(us);
        }
        assert_eq!(s.count(), 5);
        assert_eq!(s.sum_us(), 1_010_000);
        assert!((s.mean_ms() - 202.0).abs() < 1e-9);
        assert!((s.min_ms() - 1.0).abs() < 1e-9);
        assert!((s.max_ms() - 1_000.0).abs() < 1e-9);
        // Nearest rank 2 of 5 at p50 is the 3000 µs sample; its bucket's
        // lower bound is within the histogram's ~3% resolution.
        let p50 = s.p50_ms();
        assert!(p50 > 2.8 && p50 <= 3.0, "p50={p50}");
        let p99 = s.quantile_ms(99.0);
        assert!(p99 > 950.0 && p99 <= 1_000.0, "p99={p99}");
        assert!(s.quantile_ms(0.0) <= s.quantile_ms(100.0));
    }

    /// The tentpole property: merging per-group stats over *any*
    /// partition of a sample set is bit-identical to recording the set
    /// into one instance — every field, including the histogram.
    #[test]
    fn merge_is_exact_over_any_partition() {
        let samples: Vec<u64> =
            (0..500u64).map(|i| (i * 7919) % 2_000_000).collect();
        let mut whole = LatencyStat::new();
        for &s in &samples {
            whole.record(s);
        }
        for k in [1usize, 2, 3, 7] {
            let mut parts: Vec<LatencyStat> = (0..k).map(|_| LatencyStat::new()).collect();
            for (i, &s) in samples.iter().enumerate() {
                parts[i % k].record(s);
            }
            // Merge in reverse order too: order must not matter.
            let mut merged = LatencyStat::new();
            for p in parts.iter().rev() {
                merged.merge(p);
            }
            assert_eq!(merged.count(), whole.count(), "k={k}");
            assert_eq!(merged.sum_us(), whole.sum_us(), "k={k}");
            assert_eq!(merged.buckets(), whole.buckets(), "k={k}");
            assert_eq!(merged.mean_ms().to_bits(), whole.mean_ms().to_bits(), "k={k}");
            assert_eq!(merged.p50_ms().to_bits(), whole.p50_ms().to_bits(), "k={k}");
            assert_eq!(merged.p99_ms().to_bits(), whole.p99_ms().to_bits(), "k={k}");
        }
    }

    /// Merging per-group `SimMetrics` equals the single-group run: the
    /// satellite unit test for the client-tier sharding.
    #[test]
    fn sim_metrics_merge_matches_single_instance() {
        let window = (VTime::from_secs(1), VTime::from_secs(10));
        let mut whole = SimMetrics::new(window.0, window.1);
        let mut parts: Vec<SimMetrics> =
            (0..3).map(|_| SimMetrics::new(window.0, window.1)).collect();
        for i in 0..300u64 {
            let issued = VTime::from_millis(1_000 + i * 20);
            let done = issued + VTime::from_micros(500 + (i * 997) % 100_000);
            let global = i % 3 == 0;
            whole.complete(issued, done, global);
            parts[(i % 3) as usize].complete(issued, done, global);
            if i % 10 == 0 {
                whole.abort();
                parts[(i % 3) as usize].abort();
            }
        }
        let mut merged = SimMetrics::new(window.0, window.1);
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.completed, whole.completed);
        assert_eq!(merged.aborted, whole.aborted);
        assert_eq!(merged.latency_hist.buckets(), whole.latency_hist.buckets());
        assert_eq!(merged.local_hist.sum_us(), whole.local_hist.sum_us());
        assert_eq!(merged.global_hist.sum_us(), whole.global_hist.sum_us());
        assert_eq!(
            merged.mean_latency_ms().to_bits(),
            whole.mean_latency_ms().to_bits(),
            "integer-derived mean must be bit-identical across merge shapes"
        );
        assert_eq!(merged.latency.count(), whole.latency.count());
        // Sorted percentiles are canonical: same multiset, same bits.
        let (mut a, mut b) = (merged.latency.clone(), whole.latency.clone());
        assert_eq!(a.p99().to_bits(), b.p99().to_bits());
    }

    #[test]
    fn bucketed_mode_skips_summaries_and_stays_flat() {
        let mut m = SimMetrics::bucketed(VTime::from_secs(1), VTime::from_secs(3));
        assert!(m.is_bucketed_only());
        for i in 0..10_000u64 {
            let t = VTime::from_millis(1_000 + i % 1_000);
            m.complete(t, t + VTime::from_micros(1 + i % 50_000), i % 2 == 0);
        }
        assert_eq!(m.completed, 10_000);
        assert_eq!(m.latency.count(), 0, "no per-sample state in bucketed mode");
        assert_eq!(m.latency_hist.count(), 10_000);
        assert!(m.mean_latency_ms() > 0.0);
        assert!(m.latency_hist.p99_ms() >= m.latency_hist.p50_ms());
        // Merging a bucketed group into a sampled one stays bucketed.
        let mut all = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        all.merge(&m);
        assert!(all.is_bucketed_only());
        assert_eq!(all.completed, 10_000);
    }
}
