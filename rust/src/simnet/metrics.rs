//! Measurement collection with a warm-up cutoff.

use crate::util::stats::Summary;
use crate::util::VTime;

/// Operation latency/throughput metrics over a simulation run. Samples
/// completed before `warmup` are discarded (cold caches, empty token
/// pipelines); throughput is computed over the post-warm-up window.
#[derive(Debug, Clone)]
pub struct SimMetrics {
    warmup: VTime,
    horizon: VTime,
    /// All completed operations.
    pub latency: Summary,
    /// Local/commutative operations only (the RQ3 figures need local vs
    /// global separately).
    pub local_latency: Summary,
    /// Global operations only.
    pub global_latency: Summary,
    /// Operations completed after warm-up.
    pub completed: u64,
    /// Operations that aborted (all retries exhausted).
    pub aborted: u64,
}

impl SimMetrics {
    /// Metrics over `[warmup, horizon]` virtual time.
    pub fn new(warmup: VTime, horizon: VTime) -> Self {
        assert!(horizon > warmup);
        SimMetrics {
            warmup,
            horizon,
            latency: Summary::new(),
            local_latency: Summary::new(),
            global_latency: Summary::new(),
            completed: 0,
            aborted: 0,
        }
    }

    /// Record a completed operation. `global` selects the per-class bucket.
    ///
    /// Samples outside the measurement window are ignored: warm-up on
    /// the left, and anything completing *past the horizon* on the
    /// right — [`throughput`](Self::throughput) divides by the fixed
    /// `horizon − warmup` window, so a simulation that drove events
    /// beyond the horizon would otherwise silently inflate ops/sec.
    pub fn complete(&mut self, issued_at: VTime, done_at: VTime, global: bool) {
        if done_at < self.warmup || done_at > self.horizon {
            return;
        }
        let ms = (done_at - issued_at).as_millis_f64();
        self.latency.add(ms);
        if global {
            self.global_latency.add(ms);
        } else {
            self.local_latency.add(ms);
        }
        self.completed += 1;
    }

    /// Record an aborted operation.
    pub fn abort(&mut self) {
        self.aborted += 1;
    }

    /// Throughput over the measurement window (ops/sec).
    pub fn throughput(&self) -> f64 {
        let window = (self.horizon - self.warmup).as_secs_f64();
        if window <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / window
    }

    /// Mean latency over all completed operations (ms).
    pub fn mean_latency_ms(&self) -> f64 {
        self.latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_discards_early_samples() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        m.complete(VTime::ZERO, VTime::from_millis(500), false); // pre-warmup
        m.complete(VTime::from_secs(1), VTime::from_millis(1500), false);
        assert_eq!(m.completed, 1);
        assert_eq!(m.latency.count(), 1);
        assert!((m.mean_latency_ms() - 500.0).abs() < 1e-9);
    }

    /// Satellite bugfix regression: the measurement window is inclusive
    /// at both edges and closed on the right. A sample at exactly
    /// `warmup` and one at exactly `horizon` count; a post-horizon
    /// sample is ignored, so it can no longer inflate `throughput()`
    /// (which divides by the fixed `horizon − warmup` window).
    #[test]
    fn window_boundaries_and_post_horizon_samples() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        m.complete(VTime::ZERO, VTime::from_secs(1), false); // done_at == warmup
        m.complete(VTime::from_secs(2), VTime::from_secs(3), true); // done_at == horizon
        assert_eq!(m.completed, 2);
        assert_eq!(m.local_latency.count(), 1);
        assert_eq!(m.global_latency.count(), 1);
        let tput = m.throughput();
        // A sample completing past the horizon must not count anywhere.
        m.complete(VTime::from_secs(2), VTime::from_secs(3) + VTime::from_micros(1), false);
        assert_eq!(m.completed, 2);
        assert_eq!(m.latency.count(), 2);
        assert!((m.throughput() - tput).abs() < 1e-12);
    }

    #[test]
    fn throughput_over_window() {
        let mut m = SimMetrics::new(VTime::from_secs(1), VTime::from_secs(3));
        for i in 0..100 {
            let t = VTime::from_millis(1000 + i * 10);
            m.complete(t, t + VTime::from_millis(5), i % 2 == 0);
        }
        assert!((m.throughput() - 50.0).abs() < 1e-9);
        assert_eq!(m.local_latency.count(), 50);
        assert_eq!(m.global_latency.count(), 50);
    }
}
