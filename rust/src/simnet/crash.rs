//! Crash/recovery modeling shared by the simulators.
//!
//! The model is **freeze-then-replay**, matching what the WAL recovery
//! path (`db::wal`) does on the real engine: a crashed server stops
//! processing at the crash instant, buffers every event that arrives
//! during the outage (network peers keep sending — they cannot observe
//! the crash), and at recovery time — after a fixed restart cost plus a
//! per-log-record replay charge — processes the backlog in arrival
//! order. Buffering instead of dropping keeps the closed client loop
//! live (every request is eventually answered; the outage shows up as a
//! latency spike and a throughput dip, not a wedged simulation) and
//! keeps the event stream deterministic at any thread count: the crash
//! is group-local, introduces no new cross-group sends, and recovery
//! ordering depends only on virtual time.
//!
//! `ConveyorSim` uses it to kill a server mid-rotation (the token
//! freezes with it — the whole belt stalls until replay finishes);
//! `ClusterSim` to kill a participant mid-2PC (remote coordinators
//! time out and abort, the storm the conveyor never has).

use crate::util::VTime;

/// When and where a simulated server crash happens, and what recovery
/// costs.
#[derive(Debug, Clone, PartialEq)]
pub struct CrashConfig {
    /// Index of the server (window group) to kill.
    pub server: usize,
    /// Virtual time of the kill. Must land before the horizon to have
    /// any effect.
    pub at: VTime,
    /// Fixed restart cost in ms before replay begins (process start,
    /// log open, snapshot load).
    pub restart_ms: f64,
    /// Replay cost in ms charged per durable log record at the crashed
    /// server — the WAL recovery path, scaled by how much history the
    /// server had committed (see `db::wal::recover_log`).
    pub replay_per_record_ms: f64,
}

impl Default for CrashConfig {
    fn default() -> Self {
        CrashConfig {
            server: 0,
            at: VTime::from_secs(10),
            restart_ms: 500.0,
            replay_per_record_ms: 0.02,
        }
    }
}

impl CrashConfig {
    /// Total downtime for a server whose durable log held `log_len`
    /// records at the crash instant.
    pub fn downtime(&self, log_len: u64) -> VTime {
        VTime::from_millis_f64(self.restart_ms + log_len as f64 * self.replay_per_record_ms)
    }
}

/// What a simulated crash cost, reported by the sims.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashOutcome {
    /// The crashed server.
    pub server: usize,
    /// When it went down.
    pub crashed_at: VTime,
    /// When it finished restart + replay and resumed processing.
    pub recovered_at: VTime,
    /// Durable log records replayed during recovery.
    pub replayed_records: u64,
    /// Events that arrived during the outage and were processed (in
    /// arrival order) at recovery time.
    pub held_events: u64,
}

impl CrashOutcome {
    /// Downtime in milliseconds.
    pub fn downtime_ms(&self) -> f64 {
        self.recovered_at.saturating_sub(self.crashed_at).as_millis_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn downtime_scales_with_log_length() {
        let c = CrashConfig { restart_ms: 100.0, replay_per_record_ms: 0.5, ..Default::default() };
        assert_eq!(c.downtime(0), VTime::from_millis_f64(100.0));
        assert_eq!(c.downtime(1000), VTime::from_millis_f64(600.0));
        let o = CrashOutcome {
            server: 1,
            crashed_at: VTime::from_secs(4),
            recovered_at: VTime::from_secs(4) + c.downtime(1000),
            replayed_records: 1000,
            held_events: 7,
        };
        assert!((o.downtime_ms() - 600.0).abs() < 1e-9);
    }
}
