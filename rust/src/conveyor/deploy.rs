//! Real-threads Eliá deployment: Algorithm 2 running over OS threads,
//! one embedded DBMS instance per server, with genuine concurrency.
//!
//! This is the runtime the examples and the serializability tests use —
//! everything the simulator models (token rotation, pending queues,
//! commit-order tracing) happens here for real:
//!
//! * client threads call [`Deployment::submit`]; local and commutative
//!   operations execute immediately on the target server's DBMS
//!   (Algorithm 2 lines 2-4);
//! * global operations park in the server's pending queue (line 6) until
//!   the token thread takes a snapshot and wakes them (the paper's §5
//!   "parallelizing the execution of global operations": handling threads
//!   execute, the token thread waits on a countdown);
//! * state updates are appended in DBMS commit order via the engine's
//!   `commit_with` hook (§5 "tracing the sequential order");
//! * a dedicated token thread rotates the token, applying remote updates
//!   at each stop (lines 10-15), with optional injected per-hop latency
//!   to emulate WAN deployments.

//!
//! The engine-facing half of a server — execute-with-retries, the
//! pending queue, the confluent outbox and the per-stop token protocol —
//! lives in [`ServerCore`], shared verbatim between this in-process
//! runtime (one token thread walks all cores) and the networked runtime
//! (`crate::net`: one process/thread per core, the token arrives as a
//! framed message).

use crate::analysis::drift::{assignment_to_wire, AdaptiveConfig, EpochController};
use crate::db::{Db, StateUpdate, TxnError};
use crate::workload::analyzed::{AnalyzedApp, Route, RoutingEpoch};
use crate::workload::spec::{Operation, PreparedStmts, Reply, TxnCtx, TxnTemplate};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use super::token::Token;

/// Configuration of a real-threads deployment.
#[derive(Debug, Clone)]
pub struct DeployConfig {
    pub n_servers: usize,
    /// Injected token hop latency (0 for tests; set to one-way site
    /// latency to emulate WAN rings).
    pub hop_delay: Duration,
    /// Idle pause when a rotation found no work anywhere (keeps the
    /// token thread from spinning).
    pub idle_pause: Duration,
    /// Max retries for lock-aborted operations before giving up.
    pub max_retries: u32,
    /// Live routing epochs (`analysis::drift`): submits count
    /// per-template traffic, the token thread re-runs the partitioner
    /// every `window_rotations` rotations and installs a better
    /// [`RoutingEpoch`]; subsequent submits route under it. In-flight
    /// operations complete under their issue epoch (the route is
    /// resolved at submit). `None` (default) = static routing.
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for DeployConfig {
    fn default() -> Self {
        DeployConfig {
            n_servers: 3,
            hop_delay: Duration::ZERO,
            idle_pause: Duration::from_micros(200),
            max_retries: 1000,
            adaptive: None,
        }
    }
}

/// State of one parked global operation.
struct Parked {
    op: Operation,
    go: Mutex<bool>,
    cv: Condvar,
}

struct RoundShared {
    remaining: Mutex<usize>,
    cv: Condvar,
    /// Updates in DBMS commit order (the paper's U queue).
    updates: Mutex<Vec<StateUpdate>>,
}

/// One server's engine-side state: the embedded DBMS plus everything
/// Algorithm 2 keeps per server — the pending queue of parked globals,
/// the in-flight round, and the confluent outbox. [`Deployment`] owns
/// one per in-process server; the networked runtime (`crate::net`) owns
/// exactly one per `elia serve` server and drives the same methods from
/// its connection-handler and belt threads.
pub struct ServerCore {
    db: Db,
    pending: Mutex<Vec<Arc<Parked>>>,
    round: Mutex<Option<Arc<RoundShared>>>,
    /// Commit-ordered updates of confluent operations executed here
    /// since the token last stopped by; [`ServerCore::token_stop`] drains
    /// this at every stop and appends the deltas for replication.
    outbox: Mutex<Vec<StateUpdate>>,
    max_retries: u32,
    /// Lock-abort retries burned by this server's handling threads.
    pub retries: AtomicU64,
}

impl ServerCore {
    /// Wrap an engine instance (already seeded) for conveyor duty.
    pub fn new(db: Db, max_retries: u32) -> ServerCore {
        ServerCore {
            db,
            pending: Mutex::new(Vec::new()),
            round: Mutex::new(None),
            outbox: Mutex::new(Vec::new()),
            max_retries,
            retries: AtomicU64::new(0),
        }
    }

    /// The server's DBMS (tests: seed checks, hashes).
    pub fn db(&self) -> &Db {
        &self.db
    }

    /// Run one operation body to commit with wait-die retries. `sink`,
    /// when present, receives the commit's [`StateUpdate`] *before lock
    /// release* (the `commit_with` hook), so the sink order equals the
    /// DBMS serialization order.
    fn run(
        &self,
        tpl: &TxnTemplate,
        stmts: &PreparedStmts,
        args: &crate::db::Bindings,
        sink: Option<&dyn Fn(&StateUpdate)>,
    ) -> Result<Reply, TxnError> {
        let body = tpl.body.as_ref().expect("template needs a body for execution");
        let mut attempts = 0;
        loop {
            let mut handle = self.db.begin();
            let mut ctx = TxnCtx::new(&mut handle, stmts);
            match body(&mut ctx, args) {
                Ok(reply) => {
                    let committed = match sink {
                        Some(sink) => handle.commit_with(sink).map(|_| ()),
                        None => handle.commit().map(|_| ()),
                    };
                    match committed {
                        Ok(()) => return Ok(reply),
                        Err(e) if e.is_retryable() && attempts < self.max_retries => {
                            attempts += 1;
                            self.retries.fetch_add(1, Ordering::Relaxed);
                            std::thread::yield_now();
                        }
                        Err(e) => return Err(e),
                    }
                }
                Err(e) if e.is_retryable() && attempts < self.max_retries => {
                    handle.abort();
                    attempts += 1;
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::yield_now();
                }
                Err(e) => {
                    handle.abort();
                    return Err(e);
                }
            }
        }
    }

    /// Execute a local/commutative operation immediately (with wait-die
    /// retries), like Algorithm 2 lines 2-4.
    pub fn execute_local(
        &self,
        tpl: &TxnTemplate,
        stmts: &PreparedStmts,
        op: &Operation,
    ) -> Result<Reply, TxnError> {
        self.run(tpl, stmts, &op.args, None)
    }

    /// Execute an invariant-confluent operation immediately — no token
    /// wait — capturing its update in commit order into the server's
    /// outbox for replication on the next token stop. A declared
    /// invariant that would break aborts locally ([`TxnError::Invariant`]
    /// from the engine's bounded-apply check) instead of coordinating.
    pub fn execute_confluent(
        &self,
        tpl: &TxnTemplate,
        stmts: &PreparedStmts,
        op: &Operation,
    ) -> Result<Reply, TxnError> {
        self.run(
            tpl,
            stmts,
            &op.args,
            Some(&|u: &StateUpdate| {
                // Before lock release: outbox order equals the DBMS
                // serialization order, like the round queue.
                self.outbox.lock().unwrap().push(u.clone());
            }),
        )
    }

    /// Park a global operation until the token arrives, then execute it
    /// on this (handling) thread, appending the update in commit order
    /// to the active round's U queue.
    pub fn execute_global(
        &self,
        tpl: &TxnTemplate,
        stmts: &PreparedStmts,
        op: Operation,
    ) -> Result<Reply, TxnError> {
        let parked = Arc::new(Parked { op, go: Mutex::new(false), cv: Condvar::new() });
        self.pending.lock().unwrap().push(Arc::clone(&parked));

        // Wait for the token holder's wake-up (the initially-locked lock
        // of the paper's §5).
        {
            let mut go = parked.go.lock().unwrap();
            while !*go {
                go = parked.cv.wait(go).unwrap();
            }
        }

        let round = self
            .round
            .lock()
            .unwrap()
            .clone()
            .expect("round must be active when a parked op runs");
        let result = self.run(
            tpl,
            stmts,
            &parked.op.args,
            Some(&|u: &StateUpdate| {
                round.updates.lock().unwrap().push(u.clone());
            }),
        );

        // Signal the token holder (the semaphore of §5).
        {
            let mut remaining = round.remaining.lock().unwrap();
            *remaining -= 1;
            if *remaining == 0 {
                round.cv.notify_all();
            }
        }
        result
    }

    /// One token stop at this server (Algorithm 2 lines 10-22): apply
    /// remote updates in token order, stage confluent deltas, snapshot
    /// the pending queue, run the round (waking the parked handling
    /// threads and waiting on the countdown), and append the round's
    /// updates in commit order. Returns whether the stop found any work.
    pub fn token_stop(&self, p: usize, token: &mut Token) -> bool {
        let mut any_work = false;
        // Apply remote updates in token order (lines 11-15).
        let updates = token.on_receive(p);
        for u in &updates {
            self.db.apply_update(u).expect("apply_update");
        }
        any_work |= !updates.is_empty();

        // Collect deltas of confluent ops committed here since the last
        // stop (already executed — just replicate).
        let staged: Vec<StateUpdate> = {
            let mut outbox = self.outbox.lock().unwrap();
            std::mem::take(&mut *outbox)
        };
        any_work |= !staged.is_empty();
        for u in staged {
            token.append(p, u);
        }

        // Atomic snapshot of the pending queue (line 16).
        let snapshot: Vec<Arc<Parked>> = {
            let mut pending = self.pending.lock().unwrap();
            std::mem::take(&mut *pending)
        };
        if snapshot.is_empty() {
            return any_work;
        }

        let round = Arc::new(RoundShared {
            remaining: Mutex::new(snapshot.len()),
            cv: Condvar::new(),
            updates: Mutex::new(Vec::new()),
        });
        *self.round.lock().unwrap() = Some(Arc::clone(&round));

        // Wake all handling threads (they execute in parallel).
        for parked in &snapshot {
            let mut go = parked.go.lock().unwrap();
            *go = true;
            parked.cv.notify_all();
        }
        // Wait for the countdown (the paper's semaphore).
        {
            let mut remaining = round.remaining.lock().unwrap();
            while *remaining > 0 {
                remaining = round.cv.wait(remaining).unwrap();
            }
        }
        *self.round.lock().unwrap() = None;

        // Append updates to the token in commit order.
        let updates = std::mem::take(&mut *round.updates.lock().unwrap());
        for u in updates {
            token.append(p, u);
        }
        true
    }

    /// Flush staged confluent deltas into the token without running a
    /// round — the shutdown drain.
    pub fn drain_outbox(&self, p: usize, token: &mut Token) {
        let staged = std::mem::take(&mut *self.outbox.lock().unwrap());
        for u in staged {
            token.append(p, u);
        }
    }

    /// Apply this server's outstanding remote updates — the final drain
    /// rotation at shutdown (convergence checks read the DBs after this).
    pub fn apply_remote(&self, p: usize, token: &mut Token) {
        let updates = token.on_receive(p);
        for u in &updates {
            self.db.apply_update(u).expect("apply_update");
        }
    }
}

/// A running multi-server Eliá deployment.
pub struct Deployment {
    app: Arc<AnalyzedApp>,
    /// Per-template statements compiled once against the schema
    /// (prepare-once: plans, column indices and bind slots are resolved
    /// here, never on the request path).
    stmt_maps: Vec<crate::workload::spec::PreparedStmts>,
    cfg: DeployConfig,
    servers: Vec<Arc<ServerCore>>,
    stop: Arc<AtomicBool>,
    token_thread: Mutex<Option<std::thread::JoinHandle<Token>>>,
    pub ops_local: AtomicU64,
    pub ops_global: AtomicU64,
    /// Invariant-confluent operations: executed immediately like locals,
    /// replicated like globals (delta merged on the next token stop).
    pub ops_confluent: AtomicU64,
    /// The installed routing epoch (`Some` iff `cfg.adaptive`); submits
    /// read it, the token thread installs successors.
    epoch: RwLock<Option<Arc<RoutingEpoch>>>,
    /// Per-template operation counts since the last controller window
    /// (sized iff adaptive). Submit threads bump, the token thread
    /// drains onto the token's observation vector.
    obs: Vec<AtomicU64>,
    epoch_switches: AtomicU64,
}

impl Deployment {
    /// Start a deployment: builds per-server DBs (seeded by `seed_db`)
    /// and launches the token thread.
    pub fn start(
        app: Arc<AnalyzedApp>,
        cfg: DeployConfig,
        seed_db: impl Fn(&Db),
    ) -> Arc<Self> {
        let servers: Vec<Arc<ServerCore>> = (0..cfg.n_servers)
            .map(|_| {
                let db = Db::new(app.spec.schema.clone());
                seed_db(&db);
                Arc::new(ServerCore::new(db, cfg.max_retries))
            })
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let stmt_maps = app.spec.txns.iter().map(|t| t.prepared_map(&app.spec.schema)).collect();
        let epoch0 = cfg.adaptive.as_ref().map(|_| Arc::new(app.epoch0()));
        let n_templates = app.spec.txns.len();
        let dep = Arc::new(Deployment {
            epoch: RwLock::new(epoch0),
            obs: if cfg.adaptive.is_some() {
                (0..n_templates).map(|_| AtomicU64::new(0)).collect()
            } else {
                Vec::new()
            },
            epoch_switches: AtomicU64::new(0),
            app,
            stmt_maps,
            cfg: cfg.clone(),
            servers,
            stop: Arc::clone(&stop),
            token_thread: Mutex::new(None),
            ops_local: AtomicU64::new(0),
            ops_global: AtomicU64::new(0),
            ops_confluent: AtomicU64::new(0),
        });
        let dep2 = Arc::clone(&dep);
        let handle = std::thread::Builder::new()
            .name("conveyor-token".into())
            .spawn(move || dep2.token_loop())
            .expect("spawn token thread");
        *dep.token_thread.lock().unwrap() = Some(handle);
        dep
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    /// Direct access to a server's DBMS (tests: seed checks, hashes).
    pub fn db(&self, server: usize) -> &Db {
        self.servers[server].db()
    }

    /// Lock-abort retries burned across all servers' handling threads.
    pub fn retries(&self) -> u64 {
        self.servers.iter().map(|s| s.retries.load(Ordering::Relaxed)).sum()
    }

    /// The installed routing-epoch version (0 when static or before any
    /// switch).
    pub fn epoch_version(&self) -> u64 {
        self.epoch.read().unwrap().as_ref().map(|e| e.version).unwrap_or(0)
    }

    /// Routing epochs installed by the token thread's controller.
    pub fn epoch_switches(&self) -> u64 {
        self.epoch_switches.load(Ordering::Relaxed)
    }

    /// Submit one operation from a client thread and wait for its reply.
    /// This is Eliá's full request path: route, execute or park, reply.
    /// Under adaptive routing the route is resolved against the epoch
    /// installed *now* — the in-process deployment has no misroute
    /// window (there is no stale client-side router), so an epoch switch
    /// simply changes where the next submit lands.
    pub fn submit(&self, op: Operation) -> Result<Reply, TxnError> {
        let n = self.servers.len();
        let tpl = &self.app.spec.txns[op.txn];
        let stmts = &self.stmt_maps[op.txn];
        if !self.obs.is_empty() {
            self.obs[op.txn].fetch_add(1, Ordering::Relaxed);
        }
        let installed = if self.cfg.adaptive.is_some() {
            self.epoch.read().unwrap().clone()
        } else {
            None
        };
        let route = match &installed {
            Some(e) => e.route_op(&self.app, &op, n),
            None => self.app.route(&op, n),
        };
        match route {
            Route::Any => {
                self.ops_local.fetch_add(1, Ordering::Relaxed);
                // Commutative: any server; pick by cheap hash for spread.
                let s = (op.txn + op.args.len()) % n;
                self.servers[s].execute_local(tpl, stmts, &op)
            }
            Route::LocalAt(s) => {
                self.ops_local.fetch_add(1, Ordering::Relaxed);
                self.servers[s].execute_local(tpl, stmts, &op)
            }
            Route::GlobalAt(s) => {
                self.ops_global.fetch_add(1, Ordering::Relaxed);
                self.servers[s].execute_global(tpl, stmts, op)
            }
            Route::ConfluentAt(s) => {
                self.ops_confluent.fetch_add(1, Ordering::Relaxed);
                self.servers[s].execute_confluent(tpl, stmts, &op)
            }
        }
    }

    /// The token thread: rotate, apply, wake, collect (Algorithm 2 lines
    /// 10-22). Each stop is [`ServerCore::token_stop`]; the networked
    /// runtime runs the same stop per server with the token arriving as
    /// a framed message instead of a loop index.
    fn token_loop(&self) -> Token {
        let n = self.servers.len();
        let mut token = Token::new(n);
        let mut idle_rounds = 0;
        // The controller rides the token thread: re-partitioning
        // decisions are serialized by the same total order that
        // serializes global operations, so an epoch install needs no
        // extra coordination (the networked runtime does the same at
        // server 0's belt stop).
        let mut controller =
            self.cfg.adaptive.as_ref().map(|ac| EpochController::new(&self.app, ac.clone()));
        while !self.stop.load(Ordering::Relaxed) {
            let mut any_work = false;
            for (p, server) in self.servers.iter().enumerate() {
                if self.stop.load(Ordering::Relaxed) {
                    break;
                }
                if !self.cfg.hop_delay.is_zero() {
                    std::thread::sleep(self.cfg.hop_delay);
                }
                any_work |= server.token_stop(p, &mut token);
            }
            token.rotations += 1;
            if let (Some(acfg), Some(ctl)) = (&self.cfg.adaptive, controller.as_mut()) {
                token.ensure_obs(self.obs.len());
                for (t, c) in self.obs.iter().enumerate() {
                    token.obs[t] += c.swap(0, Ordering::Relaxed);
                }
                if token.rotations % acfg.window_rotations == 0 {
                    let installed = self.epoch.read().unwrap().clone();
                    if let Some(cur) = installed {
                        if let Some(next) = ctl.evaluate(&token.obs, &cur.assignment) {
                            let version = cur.version + 1;
                            token.epoch = version;
                            token.epoch_assignment = assignment_to_wire(&next);
                            let epoch = Arc::new(self.app.epoch_from(version, next));
                            *self.epoch.write().unwrap() = Some(epoch);
                            self.epoch_switches.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    for c in token.obs.iter_mut() {
                        *c = 0;
                    }
                }
            }
            if !any_work {
                idle_rounds += 1;
                if idle_rounds > 2 {
                    std::thread::sleep(self.cfg.idle_pause);
                }
            } else {
                idle_rounds = 0;
            }
        }
        // Drain: flush every outbox, then one final rotation so every
        // server applies outstanding updates (needed for convergence
        // checks at shutdown).
        for (p, server) in self.servers.iter().enumerate() {
            server.drain_outbox(p, &mut token);
        }
        for (p, server) in self.servers.iter().enumerate() {
            server.apply_remote(p, &mut token);
        }
        token
    }

    /// Stop the token thread, drain replication, and return the token
    /// (diagnostics). After this, per-server DBs are quiesced.
    pub fn shutdown(&self) -> Token {
        self.stop.store(true, Ordering::Relaxed);
        let handle = self.token_thread.lock().unwrap().take();
        match handle {
            Some(h) => h.join().expect("token thread panicked"),
            None => Token::new(self.servers.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::sqlir::parse_statement;
    use crate::workload::spec::{AppSpec, TxnTemplate};

    /// Cart app with a genuinely global `order` (derived STOCK write).
    fn app() -> Arc<AnalyzedApp> {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "add",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            )
            .with_body(|ctx, args| ctx.exec("u", args)),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived_item"),
                ],
                1.0,
            )
            .with_body(|ctx, args| {
                ctx.exec("r", args)?;
                let cid = args.get("cid").and_then(|v| v.as_int()).unwrap_or(0);
                let mut b = args.clone();
                b.insert("derived_item".to_string(), Value::Int(cid.rem_euclid(4)));
                ctx.exec("w", &b)
            }),
        ];
        let app = AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns });
        assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
        Arc::new(app)
    }

    fn seed(db: &Db) {
        use crate::db::BindSlots;
        let ins_cart = db.prepare_sql("INSERT INTO CARTS (CID, QTY) VALUES (?c, 0)").unwrap();
        let ins_stock =
            db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 10000)").unwrap();
        for c in 0..512i64 {
            db.exec_auto_prepared(&ins_cart, &BindSlots(vec![Value::Int(c)])).unwrap();
        }
        for i in 0..4i64 {
            db.exec_auto_prepared(&ins_stock, &BindSlots(vec![Value::Int(i)])).unwrap();
        }
    }

    fn cart_op(txn: usize, cid: i64) -> Operation {
        Operation {
            txn,
            args: [("cid".to_string(), Value::Int(cid))].into_iter().collect(),
        }
    }

    #[test]
    fn local_ops_execute_without_token() {
        let dep = Deployment::start(app(), DeployConfig::default(), seed);
        for cid in 0..32 {
            dep.submit(cart_op(0, cid)).unwrap();
        }
        assert_eq!(dep.ops_local.load(Ordering::Relaxed), 32);
        dep.shutdown();
    }

    #[test]
    fn global_ops_complete_and_replicate() {
        let dep = Deployment::start(app(), DeployConfig::default(), seed);
        // Issue orders from several threads.
        let mut handles = Vec::new();
        for t in 0..4 {
            let dep = Arc::clone(&dep);
            handles.push(std::thread::spawn(move || {
                for i in 0..25i64 {
                    dep.submit(cart_op(1, t * 100 + i)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(dep.ops_global.load(Ordering::Relaxed), 100);
        dep.shutdown();
        // After quiesce, total stock decrement must be exactly 100 at
        // EVERY server (global writes are replicated everywhere).
        let q = parse_statement("SELECT SUM(LEVEL) FROM STOCK").unwrap();
        for s in 0..dep.n_servers() {
            let total = dep
                .db(s)
                .exec_auto(&q, &Bindings::new())
                .unwrap()
                .scalar()
                .unwrap()
                .as_int()
                .unwrap();
            assert_eq!(total, 4 * 10000 - 100, "server {s}");
        }
    }

    #[test]
    fn mixed_load_under_concurrency() {
        let dep = Deployment::start(app(), DeployConfig::default(), seed);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let dep = Arc::clone(&dep);
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::Rng::new(t);
                for _ in 0..50 {
                    let cid = rng.range(0, 512) as i64;
                    let txn = if rng.chance(0.3) { 1 } else { 0 };
                    dep.submit(cart_op(txn, cid)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = dep.ops_local.load(Ordering::Relaxed) + dep.ops_global.load(Ordering::Relaxed);
        assert_eq!(total, 400);
        dep.shutdown();
    }

    /// Tentpole: confluent ops execute without parking, their deltas
    /// replicate through the token, and a delta that would break the
    /// declared invariant aborts locally instead of coordinating.
    #[test]
    fn confluent_ops_replicate_and_validate_locally() {
        let schema = Schema::new(vec![TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        )
        .with_nonnegative("LEVEL")]);
        let txns = vec![TxnTemplate::new(
            "restock",
            &["item", "q"],
            &[("w", "UPDATE STOCK SET LEVEL = LEVEL + ?q WHERE ITEM = ?derived")],
            1.0,
        )
        .with_nonneg_param("q")
        .with_body(|ctx, args| {
            let item = args.get("item").and_then(|v| v.as_int()).unwrap_or(0);
            let mut b = args.clone();
            b.insert("derived".to_string(), Value::Int(item.rem_euclid(4)));
            ctx.exec("w", &b)
        })];
        let app = Arc::new(AnalyzedApp::analyze_confluent(AppSpec {
            name: "restock".into(),
            schema,
            txns,
        }));
        assert_eq!(*app.class(0), crate::analysis::OpClass::Confluent);
        let seed_stock = |db: &Db| {
            use crate::db::BindSlots;
            let ins = db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 5)").unwrap();
            for i in 0..4i64 {
                db.exec_auto_prepared(&ins, &BindSlots(vec![Value::Int(i)])).unwrap();
            }
        };
        let dep = Deployment::start(Arc::clone(&app), DeployConfig::default(), seed_stock);
        let op = |item: i64, q: i64| Operation {
            txn: 0,
            args: [
                ("item".to_string(), Value::Int(item)),
                ("q".to_string(), Value::Int(q)),
            ]
            .into_iter()
            .collect(),
        };
        let mut handles = Vec::new();
        for t in 0..4i64 {
            let dep = Arc::clone(&dep);
            handles.push(std::thread::spawn(move || {
                for i in 0..25i64 {
                    dep.submit(op(t * 100 + i, 1)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(dep.ops_confluent.load(Ordering::Relaxed), 100);
        assert_eq!(dep.ops_global.load(Ordering::Relaxed), 0, "no op may park");
        // A lying client whose "non-negative" delta would drive LEVEL
        // below zero aborts locally — the engine's bounded-apply check —
        // with no coordination and no partial effects.
        let err = dep.submit(op(0, -1000)).unwrap_err();
        assert!(matches!(err, TxnError::Invariant { .. }), "{err:?}");
        dep.shutdown();
        // Every replica converges on the full restock total.
        let q = parse_statement("SELECT SUM(LEVEL) FROM STOCK").unwrap();
        for s in 0..dep.n_servers() {
            let total = dep
                .db(s)
                .exec_auto(&q, &Bindings::new())
                .unwrap()
                .scalar()
                .unwrap()
                .as_int()
                .unwrap();
            assert_eq!(total, 4 * 5 + 100, "server {s}");
        }
    }

    #[test]
    fn shutdown_drains_the_token() {
        let dep = Deployment::start(app(), DeployConfig::default(), seed);
        dep.submit(cart_op(1, 3)).unwrap();
        let token = dep.shutdown();
        assert!(token.is_empty(), "token drained at shutdown");
    }
}
