//! Discrete-event simulation of an Eliá deployment: N servers running the
//! Conveyor Belt protocol (Algorithm 2) over the paper's LAN/WAN
//! topologies, with closed-loop clients.
//!
//! Operations are *really executed* against per-server embedded DBMS
//! instances (so replication, token ordering and state convergence are
//! exercised, not just modeled) while time is virtual: each operation
//! charges a modeled service time on its server's 2-worker station, and
//! messages pay Table 2 latencies.

use crate::db::{Db, StateUpdate, TxnError};
use crate::simnet::clients::{ClientPool, ClientsConfig};
use crate::simnet::events::EventQueue;
use crate::simnet::latency::Topology;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::{AnalyzedApp, Route};
use crate::workload::generator::{OpGenerator, ServiceModel};
use crate::workload::spec::{Operation, TxnCtx};

use super::token::Token;

/// Tunables of the Conveyor Belt simulation.
#[derive(Debug, Clone)]
pub struct ConveyorConfig {
    pub workers: usize,
    pub service: ServiceModel,
    /// CPU time to apply one replicated state update (a fraction of a
    /// full execution: update-only replay, no reads).
    pub apply_per_update_ms: f64,
    /// Minimum token hold time when there is nothing to do.
    pub min_hold_ms: f64,
    /// Per-hop token processing overhead (serialization etc.).
    pub hop_overhead_ms: f64,
    /// Probability a client sends to the wrong server (exercises the MAP
    /// redirect path; 0 in the paper's common case).
    pub misroute_prob: f64,
    /// Execute operations against real per-server DBs.
    pub execute_real: bool,
    /// Client placement: latency matrix over *client sites* (the paper
    /// keeps clients at all five WAN sites even when Eliá deploys fewer
    /// servers; servers occupy the first `topology.n()` sites). `None` =
    /// clients co-located with servers.
    pub client_matrix: Option<crate::simnet::latency::LatencyMatrix>,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl Default for ConveyorConfig {
    fn default() -> Self {
        ConveyorConfig {
            // T2.medium runs a Tomcat thread pool over 2 vCPUs; the ~5 ms
            // operations are dominated by DBMS/IO waits, so the effective
            // service parallelism is the pool, not the core count.
            workers: 8,
            service: ServiceModel::default(),
            // Logical replay of one update record; measured ~2 us in the
            // real engine (hotpath bench) — 50 us here is conservative and
            // covers deserialization.
            apply_per_update_ms: 0.05,
            min_hold_ms: 0.1,
            hop_overhead_ms: 0.1,
            misroute_prob: 0.0,
            execute_real: false,
            client_matrix: None,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0x5EED,
        }
    }
}

#[derive(Debug, Clone)]
enum Ev {
    /// Client (after thinking) issues its next operation.
    Issue { client: usize },
    /// Request arrives at a server (possibly after a MAP redirect).
    Arrive { op: u64, redirected: bool },
    /// A station job completed.
    JobDone { server: usize, job: JobKind },
    /// The token arrives at a server.
    TokenArrive { server: usize },
    /// Reply reaches the client.
    Reply { op: u64 },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum JobKind {
    /// Execute operation (local/commutative, or global under token).
    Op(u64),
    /// Apply `n` replicated updates from the token.
    Apply { n: usize },
}

struct OpState {
    op: Operation,
    client: usize,
    issued: VTime,
    server: usize,
    global: bool,
}

struct ServerState {
    db: Option<Db>,
    station: Station<JobKind>,
    /// Global operations waiting for the token (Algorithm 2's Q).
    pending: Vec<u64>,
    /// Snapshot being executed under the current token hold (Q').
    outstanding: usize,
    /// True between TokenArrive and PassToken.
    holds_token: bool,
    /// Updates to apply were dispatched; globals wait for the apply job.
    applying: bool,
    aborts: u64,
}

/// The simulation driver.
pub struct ConveyorSim<'a> {
    app: &'a AnalyzedApp,
    /// Per-template statements compiled once against the schema
    /// (prepare-once; all per-server DBs share one schema).
    stmt_maps: Vec<crate::workload::spec::PreparedStmts>,
    topo: Topology,
    cfg: ConveyorConfig,
    gen: Box<dyn OpGenerator + 'a>,
    clients: ClientPool,
    servers: Vec<ServerState>,
    ops: Vec<OpState>,
    token: Token,
    token_at: usize,
    svc_rng: Rng,
    pub metrics: SimMetrics,
    q: EventQueue<Ev>,
}

impl<'a> ConveyorSim<'a> {
    pub fn new(
        app: &'a AnalyzedApp,
        topo: Topology,
        clients_cfg: ClientsConfig,
        cfg: ConveyorConfig,
        gen: Box<dyn OpGenerator + 'a>,
        seed_db: impl Fn(&Db),
    ) -> Self {
        let n = topo.n();
        let client_sites = cfg.client_matrix.as_ref().map(|m| m.n()).unwrap_or(n);
        let clients = ClientPool::new(ClientsConfig { sites: client_sites, ..clients_cfg });
        let servers = (0..n)
            .map(|_| {
                let db = if cfg.execute_real {
                    let db = Db::new(app.spec.schema.clone());
                    seed_db(&db);
                    Some(db)
                } else {
                    None
                };
                ServerState {
                    db,
                    station: Station::new(cfg.workers),
                    pending: Vec::new(),
                    outstanding: 0,
                    holds_token: false,
                    applying: false,
                    aborts: 0,
                }
            })
            .collect();
        let metrics = SimMetrics::new(cfg.warmup, cfg.horizon);
        let svc_rng = Rng::new(cfg.seed ^ 0xF00D);
        ConveyorSim {
            stmt_maps: app.spec.txns.iter().map(|t| t.prepared_map(&app.spec.schema)).collect(),
            app,
            topo,
            cfg,
            gen,
            clients,
            servers,
            ops: Vec::new(),
            token: Token::new(n),
            token_at: 0,
            svc_rng,
            metrics,
            q: EventQueue::new(),
        }
    }

    /// Run the simulation to the configured horizon and return final
    /// metrics. Consumes the driver.
    pub fn run(mut self) -> ConveyorReport {
        // Boot: token starts at server 0; all clients issue.
        self.q.schedule(VTime::ZERO, Ev::TokenArrive { server: 0 });
        for c in 0..self.clients.n() {
            // Stagger initial issues a little to avoid a thundering herd
            // artifact at t=0.
            let jitter = VTime::from_micros((c as u64 % 97) * 13);
            self.q.schedule(jitter, Ev::Issue { client: c });
        }
        while let Some(t) = self.q.peek_time() {
            if t > self.cfg.horizon {
                break;
            }
            let (_, ev) = self.q.pop().unwrap();
            self.handle(ev);
        }
        self.report()
    }

    fn report(&mut self) -> ConveyorReport {
        let n = self.topo.n();
        let now = self.cfg.horizon;
        ConveyorReport {
            metrics: self.metrics.clone(),
            rotations: self.token.rotations,
            utilization: (0..n).map(|s| self.servers[s].station.utilization(now)).collect(),
            aborts: self.servers.iter().map(|s| s.aborts).sum(),
            db_hashes: self
                .servers
                .iter()
                .map(|s| s.db.as_ref().map(|d| d.content_hash()))
                .collect(),
            events: self.q.processed(),
        }
    }

    fn client_server_latency(&self, site: usize, server: usize) -> VTime {
        // The Table 2 diagonal carries the intra-site latency. With an
        // explicit client matrix, clients may sit at sites without a
        // server (paper §7.2: five client locations regardless of the
        // server count).
        match &self.cfg.client_matrix {
            Some(m) => m.one_way(site, server),
            None => self.topo.servers.one_way(site.min(self.topo.n() - 1), server),
        }
    }

    /// The deployed server with the lowest latency from a client site.
    fn nearest_server(&self, site: usize) -> usize {
        match &self.cfg.client_matrix {
            Some(m) => (0..self.topo.n())
                .min_by_key(|&s| m.one_way(site, s))
                .unwrap_or(0),
            None => site % self.topo.n(),
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Issue { client } => self.on_issue(client),
            Ev::Arrive { op, redirected } => self.on_arrive(op, redirected),
            Ev::JobDone { server, job } => self.on_job_done(server, job),
            Ev::TokenArrive { server } => self.on_token(server),
            Ev::Reply { op } => self.on_reply(op),
        }
    }

    fn on_issue(&mut self, client: usize) {
        let n = self.topo.n();
        let site = self.clients.site(client);
        // Key affinity targets the nearest server site (clients at
        // server-less sites adopt the closest deployed server).
        let affinity = self.nearest_server(site);
        let op = {
            let rng = self.clients.rng(client);
            // Borrow juggling: generator needs its own &mut.
            let mut r = rng.fork();
            self.gen.next_op(&mut r, affinity, n)
        };
        let route = self.app.route(&op, n);
        let (server, global) = match route {
            Route::Any => (affinity, false),
            Route::LocalAt(s) => (s, false),
            Route::GlobalAt(s) => (s, true),
        };
        let op_id = self.ops.len() as u64;
        self.ops.push(OpState { op, client, issued: self.q.now(), server, global });

        // Misrouting: send to a wrong server which answers MAP; the client
        // then contacts the right one — two extra hops.
        let mut delay = self.client_server_latency(site, server);
        if self.cfg.misroute_prob > 0.0 {
            let r = self.clients.rng(client).f64();
            if r < self.cfg.misroute_prob {
                let wrong = (server + 1) % n;
                delay = self.client_server_latency(site, wrong)
                    + self.client_server_latency(site, wrong)
                    + self.client_server_latency(site, server);
            }
        }
        self.q.schedule(delay, Ev::Arrive { op: op_id, redirected: false });
    }

    fn on_arrive(&mut self, op_id: u64, _redirected: bool) {
        let (server, global, txn) = {
            let o = &self.ops[op_id as usize];
            (o.server, o.global, o.op.txn)
        };
        if global {
            // Algorithm 2 line 6: hold until the token arrives. If this
            // server currently holds the token and has not yet passed it,
            // the op still waits for the *next* rotation (the snapshot Q'
            // was already taken).
            self.servers[server].pending.push(op_id);
            return;
        }
        let service = self.cfg.service.sample(&self.app.spec.txns[txn], &mut self.svc_rng);
        self.submit_job(server, JobKind::Op(op_id), service, false);
    }

    fn submit_job(&mut self, server: usize, job: JobKind, service: VTime, priority: bool) {
        let now = self.q.now();
        if let Some(started) = self.servers[server].station.submit(now, job, service, priority) {
            self.q.schedule(started.service, Ev::JobDone { server, job: started.payload });
        }
    }

    fn on_job_done(&mut self, server: usize, job: JobKind) {
        // Start whatever the station dequeues next.
        let now = self.q.now();
        if let Some(next) = self.servers[server].station.complete(now) {
            self.q.schedule(next.service, Ev::JobDone { server, job: next.payload });
        }

        match job {
            JobKind::Op(op_id) => {
                let global = self.ops[op_id as usize].global;
                let update = self.execute_real(server, op_id);
                if global {
                    // Append to the token in completion order (the DBMS
                    // commit order under strict 2PL).
                    if let Some(u) = update {
                        self.token.append(server, u);
                    } else {
                        self.token.append(server, StateUpdate::new());
                    }
                    let s = &mut self.servers[server];
                    s.outstanding -= 1;
                    if s.outstanding == 0 {
                        self.pass_token(server);
                    }
                }
                self.send_reply(op_id);
            }
            JobKind::Apply { .. } => {
                // Replicated updates applied; dispatch the snapshot.
                self.servers[server].applying = false;
                self.dispatch_globals(server);
            }
        }
    }

    /// Execute the operation body against the server's DB, returning its
    /// state update (None when real execution is disabled or aborted).
    fn execute_real(&mut self, server: usize, op_id: u64) -> Option<StateUpdate> {
        if !self.cfg.execute_real {
            return None;
        }
        let o = &self.ops[op_id as usize];
        let tpl = &self.app.spec.txns[o.op.txn];
        let Some(body) = tpl.body.as_ref() else { return None };
        let db = self.servers[server].db.as_ref().expect("real exec needs db");
        let stmts = &self.stmt_maps[o.op.txn];
        // Single-threaded simulation: lock conflicts cannot occur, but
        // semantic errors (duplicate key etc.) count as aborts.
        let mut handle = db.begin();
        let mut ctx = TxnCtx::new(&mut handle, stmts);
        match body(&mut ctx, &o.op.args) {
            Ok(_reply) => match handle.commit() {
                Ok(update) => Some(update),
                Err(_) => {
                    self.servers[server].aborts += 1;
                    None
                }
            },
            Err(TxnError::Lock(_)) | Err(_) => {
                handle.abort();
                self.servers[server].aborts += 1;
                None
            }
        }
    }

    fn send_reply(&mut self, op_id: u64) {
        let o = &self.ops[op_id as usize];
        let site = self.clients.site(o.client);
        let delay = self.client_server_latency(site, o.server);
        self.q.schedule(delay, Ev::Reply { op: op_id });
    }

    fn on_reply(&mut self, op_id: u64) {
        let (client, issued, global) = {
            let o = &self.ops[op_id as usize];
            (o.client, o.issued, o.global)
        };
        self.metrics.complete(issued, self.q.now(), global);
        let think = self.clients.think(client);
        self.q.schedule(think, Ev::Issue { client });
    }

    fn on_token(&mut self, server: usize) {
        self.token_at = server;
        if server == 0 {
            self.token.rotations += 1;
        }
        let updates = self.token.on_receive(server);
        let s = &mut self.servers[server];
        s.holds_token = true;

        // Apply replicated updates (Algorithm 2 lines 11-15) as one CPU
        // job; the pending snapshot executes after it.
        let n_updates = updates.len();
        if self.cfg.execute_real {
            if let Some(db) = self.servers[server].db.as_ref() {
                for u in &updates {
                    db.apply_update(u).expect("apply_update");
                }
            }
        }
        if n_updates > 0 {
            self.servers[server].applying = true;
            let service = VTime::from_millis_f64(self.cfg.apply_per_update_ms * n_updates as f64);
            self.submit_job(server, JobKind::Apply { n: n_updates }, service, true);
        } else {
            self.dispatch_globals(server);
        }
    }

    /// Take the atomic snapshot Q' and execute it (Algorithm 2 lines
    /// 16-21); pass the token when the snapshot drains.
    fn dispatch_globals(&mut self, server: usize) {
        let snapshot: Vec<u64> = std::mem::take(&mut self.servers[server].pending);
        if snapshot.is_empty() {
            // Nothing to do: hold briefly, then pass.
            let hold = VTime::from_millis_f64(self.cfg.min_hold_ms);
            let next = (server + 1) % self.topo.n();
            let delay = hold
                + self.topo.servers.one_way(server, next)
                + VTime::from_millis_f64(self.cfg.hop_overhead_ms);
            self.q.schedule(delay, Ev::TokenArrive { server: next });
            self.servers[server].holds_token = false;
            return;
        }
        self.servers[server].outstanding = snapshot.len();
        for op_id in snapshot {
            let txn = self.ops[op_id as usize].op.txn;
            let service = self.cfg.service.sample(&self.app.spec.txns[txn], &mut self.svc_rng);
            // Global ops jump the queue: the paper's token thread wakes
            // the handling threads which run concurrently with new local
            // arrivals; priority keeps token hold times short.
            self.submit_job(server, JobKind::Op(op_id), service, true);
        }
    }

    fn pass_token(&mut self, server: usize) {
        debug_assert!(self.servers[server].holds_token);
        self.servers[server].holds_token = false;
        let next = (server + 1) % self.topo.n();
        let delay = self.topo.servers.one_way(server, next)
            + VTime::from_millis_f64(self.cfg.hop_overhead_ms);
        self.q.schedule(delay, Ev::TokenArrive { server: next });
    }
}

/// Output of one simulation run.
#[derive(Debug, Clone)]
pub struct ConveyorReport {
    pub metrics: SimMetrics,
    pub rotations: u64,
    pub utilization: Vec<f64>,
    pub aborts: u64,
    /// Per-server DB content hashes (real-execution runs); replicated
    /// tables must converge once quiesced.
    pub db_hashes: Vec<Option<u64>>,
    pub events: u64,
}

impl ConveyorReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.metrics.latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::workload::spec::{AppSpec, TxnTemplate};

    /// A small cart app: local add, global order (writes shared STOCK).
    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "add",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            )
            .with_body(|ctx, args| ctx.exec("u", args)),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    // The touched item is derived from the cart content at
                    // run time — an opaque write, so `order` is Global
                    // exactly like the paper's Figure 1.
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived_item"),
                ],
                1.0,
            )
            .with_body(|ctx, args| {
                ctx.exec("r", args)?;
                let cid = args.get("cid").and_then(|v| v.as_int()).unwrap_or(0);
                let mut b = args.clone();
                b.insert("derived_item".to_string(), Value::Int(cid.rem_euclid(8)));
                ctx.exec("w", &b)
            }),
        ];
        let app = AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns });
        assert_eq!(*app.class(0), crate::analysis::OpClass::Local);
        assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
        app
    }

    struct MixGen {
        global_ratio: f64,
    }

    impl OpGenerator for MixGen {
        fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
            if rng.chance(self.global_ratio) {
                // order a random cart; the derived item write makes it global.
                let cid = (rng.range(0, 1000) * n + site) as i64;
                let args: Bindings =
                    [("cid".to_string(), Value::Int(cid))].into_iter().collect();
                Operation { txn: 1, args }
            } else {
                // add: site-affine cart id.
                let cid = (rng.range(0, 1000) * n + site) as i64;
                let args: Bindings =
                    [("cid".to_string(), Value::Int(cid))].into_iter().collect();
                Operation { txn: 0, args }
            }
        }
    }

    fn seed(db: &Db) {
        use crate::db::BindSlots;
        let ins_cart = db.prepare_sql("INSERT INTO CARTS (CID, QTY) VALUES (?c, 0)").unwrap();
        let ins_stock = db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 1000)").unwrap();
        for c in 0..5000i64 {
            db.exec_auto_prepared(&ins_cart, &BindSlots(vec![Value::Int(c)])).unwrap();
        }
        for i in 0..8i64 {
            db.exec_auto_prepared(&ins_stock, &BindSlots(vec![Value::Int(i)])).unwrap();
        }
    }

    fn run(n_servers: usize, clients: usize, global_ratio: f64, real: bool) -> ConveyorReport {
        let app = app();
        let cfg = ConveyorConfig {
            execute_real: real,
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        let sim = ConveyorSim::new(
            &app,
            Topology::lan(n_servers),
            ClientsConfig { n: clients, think_ms: 10.0, seed: 7, ..Default::default() },
            cfg,
            Box::new(MixGen { global_ratio }),
            seed,
        );
        sim.run()
    }

    #[test]
    fn local_only_workload_flows() {
        let r = run(3, 30, 0.0, false);
        assert!(r.metrics.completed > 500, "completed={}", r.metrics.completed);
        // Latency ≈ client RTT (20ms) + service (5ms) + queueing.
        let mean = r.mean_latency_ms();
        assert!(mean > 20.0 && mean < 80.0, "mean={mean}");
        assert_eq!(r.metrics.global_latency.count(), 0);
    }

    #[test]
    fn global_ops_wait_for_token_and_cost_more() {
        let mut r = run(3, 30, 0.3, false);
        assert!(r.metrics.global_latency.count() > 50);
        let lg = r.metrics.global_latency.mean();
        let ll = r.metrics.local_latency.mean();
        assert!(
            lg > ll * 1.5,
            "global latency ({lg}) should exceed local ({ll}) significantly"
        );
        assert!(r.rotations > 10, "token must rotate: {}", r.rotations);
        // Sanity on percentiles API.
        assert!(r.metrics.latency.p99() >= r.metrics.latency.p50());
    }

    #[test]
    fn real_execution_replicates_global_writes() {
        let r = run(3, 20, 0.4, true);
        assert!(r.metrics.completed > 200);
        assert_eq!(r.aborts, 0, "no aborts expected");
        // STOCK must have been written: decrements happened across
        // servers. Per-server hashes differ because CARTS are partial
        // (local, not replicated) — convergence of the replicated STOCK
        // table is asserted in the integration test which quiesces first.
        assert!(r.db_hashes.iter().all(|h| h.is_some()));
    }

    #[test]
    fn more_servers_increase_local_capacity() {
        // Pure-local workload: 9 servers should sustain clearly more than 1.
        let r1 = run(1, 120, 0.0, false);
        let r9 = run(9, 120, 0.0, false);
        assert!(
            r9.throughput() > r1.throughput() * 2.0,
            "t1={} t9={}",
            r1.throughput(),
            r9.throughput()
        );
    }

    #[test]
    fn misrouting_adds_latency() {
        let app = app();
        let mk = |mis: f64| {
            let cfg = ConveyorConfig {
                misroute_prob: mis,
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(8),
                service: ServiceModel::fixed(5.0),
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 10, think_ms: 10.0, seed: 3, ..Default::default() },
                cfg,
                Box::new(MixGen { global_ratio: 0.0 }),
                |_db| {},
            )
            .run()
        };
        let clean = mk(0.0).mean_latency_ms();
        let dirty = mk(0.5).mean_latency_ms();
        assert!(dirty > clean + 5.0, "clean={clean} dirty={dirty}");
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(3, 25, 0.2, false);
        let b = run(3, 25, 0.2, false);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
        assert!((a.mean_latency_ms() - b.mean_latency_ms()).abs() < 1e-9);
    }
}
