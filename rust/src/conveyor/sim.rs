//! Discrete-event simulation of an Eliá deployment: N servers running the
//! Conveyor Belt protocol (Algorithm 2) over the paper's LAN/WAN
//! topologies, with closed-loop clients.
//!
//! Operations are *really executed* against per-server embedded DBMS
//! instances (so replication, token ordering and state convergence are
//! exercised, not just modeled) while time is virtual: each operation
//! charges a modeled service time on its server's station, and messages
//! pay Table 2 latencies.
//!
//! # Parallel window engine
//!
//! The simulation is organized as `n + K` *groups*, each owning its own
//! [`EventQueue`](crate::simnet::EventQueue), clock and state (a
//! [`GroupCore`]): one group per server (DB, station,
//! token-wait queue, service-time RNG stream) plus K *client groups*
//! (slices of the client pool with per-client RNG streams, workload
//! generator, mergeable metrics — see
//! [`ClientGroups`](crate::simnet::clients::ClientGroups)). Groups
//! interact only by messages that pay a network latency —
//! client→server requests, server→client replies, and the token hop —
//! so any event emitted for another group lands at least `L` (the
//! minimum such latency, the *lookahead*) into the future.
//!
//! The driver therefore advances in conservative windows `[T, T + L)`
//! where `T` is the earliest pending event across all groups: inside a
//! window every group can process its own events independently — there is
//! provably no cross-group delivery inside the window — so per-server
//! work (real DB execution, update replay, station scheduling) fans out
//! across a scoped thread pool. The window loop itself is the generic
//! [`crate::simnet::parallel::run_windows`] engine (shared with
//! `ClusterSim` and `BaselineSim`): emitted cross-group events are
//! collected in per-group buffers and merged back in canonical order —
//! `(virtual time, source group id, per-source emission number)` — so
//! queue insertion order, and with it the entire simulation, is
//! **bit-identical for every thread count** (see `src/simnet/README.md`
//! for the full argument and `tests/parallel_determinism.rs` for the
//! enforcement).
//!
//! The token itself travels *inside* the (private) `Ev::TokenArrive` event, just
//! like the real protocol: exactly one group ever owns it, so global-op
//! appends need no shared state.

use crate::analysis::drift::{assignment_from_wire, assignment_to_wire, AdaptiveConfig, DriftCollector, EpochController};
use crate::db::{Db, StateUpdate, TxnError};
use crate::simnet::clients::{
    ClientEv, ClientGroups, ClientTier, ClientsConfig, IssueReply, IssueRouter,
};
use crate::simnet::crash::{CrashConfig, CrashOutcome};
use crate::simnet::latency::Topology;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::parallel::{self, client_group_target, GroupCore, WindowGroup};
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::{AnalyzedApp, Route, RoutingEpoch};
use crate::workload::generator::{OpGenerator, ServiceModel};
use crate::workload::spec::{PreparedStmts, TxnCtx};

use std::sync::Arc;

use super::token::Token;

/// Tunables of the Conveyor Belt simulation.
#[derive(Debug, Clone)]
pub struct ConveyorConfig {
    pub workers: usize,
    pub service: ServiceModel,
    /// CPU time to apply one replicated state update (a fraction of a
    /// full execution: update-only replay, no reads).
    pub apply_per_update_ms: f64,
    /// Minimum token hold time when there is nothing to do.
    pub min_hold_ms: f64,
    /// Per-hop token processing overhead (serialization etc.).
    pub hop_overhead_ms: f64,
    /// Probability a client sends to the wrong server (exercises the MAP
    /// redirect path; 0 in the paper's common case).
    pub misroute_prob: f64,
    /// Execute operations against real per-server DBs.
    pub execute_real: bool,
    /// Client placement: latency matrix over *client sites* (the paper
    /// keeps clients at all five WAN sites even when Eliá deploys fewer
    /// servers; servers occupy the first `topology.n()` sites). `None` =
    /// clients co-located with servers.
    pub client_matrix: Option<crate::simnet::latency::LatencyMatrix>,
    /// Worker threads for the window-parallel engine: `1` = process every
    /// group on the driving thread (default), `0` = all available cores,
    /// `N` = at most N threads. Results are bit-identical for every
    /// value — the thread count is a pure performance knob.
    pub parallel: usize,
    /// Record the token's total order of global state updates and return
    /// it in [`ConveyorReport::global_log`] (testing hook for
    /// serializability checks; off by default — it retains every update
    /// for the whole run).
    pub record_global_log: bool,
    /// Kill one server mid-run (freeze-then-replay, see
    /// [`crate::simnet::crash`]). The token freezes with the crashed
    /// server, so the whole belt stalls for the downtime — the failure
    /// mode the paper's §6 fault discussion predicts. `None` (default)
    /// = no crash; the clean event stream is byte-identical to builds
    /// without this field.
    pub crash: Option<CrashConfig>,
    /// Live routing epochs (`analysis::drift`): servers collect
    /// per-template operation counts in a token-borne sliding window, the
    /// controller at server 0 re-runs the partitioner every
    /// `window_rotations`, and a better assignment installs as a new
    /// [`RoutingEpoch`] *via the token* — a total-order barrier with no
    /// extra coordination. Clients keep routing under the immutable epoch
    /// 0 (so the client tier stays bit-identical across K and thread
    /// counts); servers re-route arrivals under the installed epoch and
    /// forward at most one server-to-server hop. `None` (default) =
    /// static routing, event stream byte-identical to builds without this
    /// field. `Some(AdaptiveConfig::frozen())` = epoch machinery on but
    /// pinned to epoch 0 forever — the "static" arm of drift experiments.
    pub adaptive: Option<AdaptiveConfig>,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl Default for ConveyorConfig {
    fn default() -> Self {
        ConveyorConfig {
            // T2.medium runs a Tomcat thread pool over 2 vCPUs; the ~5 ms
            // operations are dominated by DBMS/IO waits, so the effective
            // service parallelism is the pool, not the core count.
            workers: 8,
            service: ServiceModel::default(),
            // Logical replay of one update record; measured ~2 us in the
            // real engine (hotpath bench) — 50 us here is conservative and
            // covers deserialization.
            apply_per_update_ms: 0.05,
            min_hold_ms: 0.1,
            hop_overhead_ms: 0.1,
            misroute_prob: 0.0,
            execute_real: false,
            client_matrix: None,
            parallel: 1,
            record_global_log: false,
            crash: None,
            adaptive: None,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0x5EED,
        }
    }
}

/// An operation in flight, carried inside events (the engine has no
/// global operation table — groups exchange self-contained messages).
#[derive(Debug, Clone)]
struct OpEnvelope {
    txn: usize,
    args: crate::db::Bindings,
    client: usize,
    client_site: usize,
    issued: VTime,
    global: bool,
    /// Invariant-confluent: executes immediately like a local op, but its
    /// state update rides the token as a merged delta (see
    /// [`crate::analysis::confluence`]).
    confluent: bool,
    /// Server-to-server forwards already paid (adaptive routing: a server
    /// whose installed epoch homes the op elsewhere forwards it once;
    /// the receiver executes unconditionally, which is sound because only
    /// token-ordered globals ever change home across epochs).
    hops: u8,
}

#[derive(Debug)]
enum Ev {
    /// Client (after thinking) issues its next operation. [client tier]
    Issue { client: usize },
    /// Reply reaches the client. [client tier]
    Reply { client: usize, issued: VTime, global: bool },
    /// Request arrives at its server, misroute redirects already paid.
    /// [server]
    Arrive { op: OpEnvelope },
    /// A station job completed. [server]
    JobDone { job: JobKind },
    /// The token arrives — the token state travels with the event, so
    /// exactly one group owns it at any virtual time. [server]
    TokenArrive { token: Token },
    /// This server crashes now (scheduled at boot from
    /// [`ConveyorConfig::crash`]). [server]
    Crash,
    /// Restart + WAL replay finished; drain the held backlog. [server]
    Recover,
}

#[derive(Debug)]
enum JobKind {
    /// Execute operation (local/commutative, or global under token).
    Op(OpEnvelope),
    /// Apply the replicated updates of one token receipt (the update
    /// count only shapes the job's service time, set at submission).
    Apply,
}

/// Immutable context shared by every group during a window.
struct Shared<'s> {
    app: &'s AnalyzedApp,
    stmt_maps: &'s [PreparedStmts],
    topo: &'s Topology,
    cfg: &'s ConveyorConfig,
    /// Client-group count K (servers address reply targets with it).
    client_groups: usize,
    /// The immutable boot epoch clients route under when adaptivity is
    /// on (`None` = static routing via [`AnalyzedApp::route`]).
    epoch0: Option<Arc<RoutingEpoch>>,
}

impl Shared<'_> {
    fn client_server_latency(&self, site: usize, server: usize) -> VTime {
        // The Table 2 diagonal carries the intra-site latency. With an
        // explicit client matrix, clients may sit at sites without a
        // server (paper §7.2: five client locations regardless of the
        // server count).
        match &self.cfg.client_matrix {
            Some(m) => m.one_way(site, server),
            None => self.topo.servers.one_way(site.min(self.topo.n() - 1), server),
        }
    }

    /// The deployed server with the lowest latency from a client site.
    fn nearest_server(&self, site: usize) -> usize {
        match &self.cfg.client_matrix {
            Some(m) => (0..self.topo.n())
                .min_by_key(|&s| m.one_way(site, s))
                .unwrap_or(0),
            None => site % self.topo.n(),
        }
    }
}

/// One server group: everything a server mutates while handling its own
/// events. No field is observable by another group during a window.
struct ServerState {
    id: usize,
    db: Option<Db>,
    station: Station<JobKind>,
    /// Global operations waiting for the token (Algorithm 2's Q).
    pending: Vec<OpEnvelope>,
    /// Operations of the snapshot Q' still executing under the hold.
    outstanding: usize,
    /// The token, while this server holds it (`Some` between
    /// TokenArrive and the pass).
    token: Option<Token>,
    /// Completed ring rotations observed here (counted at server 0).
    rotations: u64,
    aborts: u64,
    /// Per-server service-time stream: derived from the seed by server
    /// id (`Rng::stream`), so neither thread count nor event
    /// interleaving across servers can perturb any server's randomness.
    rng: Rng,
    core: GroupCore<Ev>,
    /// Token-order log of global updates (when `record_global_log`).
    log: Vec<(u64, StateUpdate)>,
    /// Updates of confluent ops committed since the token last left:
    /// flushed onto the token at the next `TokenArrive` (while holding
    /// the token, confluent commits append directly instead).
    outbox: Vec<StateUpdate>,
    /// Crashed and not yet recovered: every event freezes in `held`.
    down: bool,
    /// Events that arrived during the outage, in arrival order.
    held: Vec<Ev>,
    /// Durable redo records this server has logged (one per committed
    /// operation plus one per replicated update applied) — sizes the
    /// WAL replay charge at recovery, mirroring `db::wal::recover_log`.
    log_len: u64,
    crash: Option<CrashOutcome>,
    /// The installed routing epoch (`Some` iff adaptivity is on).
    /// Arrivals are re-routed and re-classified under this, not the
    /// client's issue-time epoch 0.
    epoch: Option<Arc<RoutingEpoch>>,
    /// Per-template operation counts since this server last held the
    /// token (flushed into [`Token::obs`] at receipt).
    collector: DriftCollector,
    /// The re-partitioning controller; `Some` only at server 0 when
    /// adaptivity is on.
    controller: Option<EpochController>,
    /// Epoch installations this server initiated (server 0 only).
    epoch_switches: u64,
    /// Arrivals forwarded to their installed-epoch home.
    redirects: u64,
    /// Per-virtual-second (belted, unbelted) execution counts — merged
    /// across servers into [`ConveyorReport::drift_curve`].
    curve: Vec<(u64, u64)>,
}

impl<'s> WindowGroup<Shared<'s>> for ServerState {
    type Ev = Ev;

    fn core(&self) -> &GroupCore<Ev> {
        &self.core
    }

    fn core_mut(&mut self) -> &mut GroupCore<Ev> {
        &mut self.core
    }

    fn handle(&mut self, ev: Ev, ctx: &Shared<'s>) {
        if self.down {
            // Frozen: peers cannot observe the crash, so their messages
            // (and our own in-flight timers) pile up until recovery.
            if matches!(ev, Ev::Recover) {
                self.on_recover(ctx);
            } else {
                self.held.push(ev);
            }
            return;
        }
        match ev {
            Ev::Arrive { op } => self.on_arrive(op, ctx),
            Ev::JobDone { job } => self.on_job_done(job, ctx),
            Ev::TokenArrive { token } => self.on_token(token, ctx),
            Ev::Crash => self.on_crash(ctx),
            Ev::Recover => unreachable!("recovery while up"),
            Ev::Issue { .. } | Ev::Reply { .. } => {
                unreachable!("client-tier event delivered to a server")
            }
        }
    }
}

impl ServerState {
    fn on_arrive(&mut self, mut op: OpEnvelope, ctx: &Shared<'_>) {
        if let Some(epoch) = self.epoch.as_ref() {
            // Re-route under the *installed* epoch (the client issued
            // under epoch 0). At most one forward hop: a second
            // disagreement (epoch moved again mid-flight) executes here —
            // sound, because the only ops whose home can move across
            // epochs are token-ordered globals, and a pinned Local's home
            // is a pure function of its own routing parameter.
            let route = epoch.route(ctx.app, op.txn, &op.args, ctx.topo.n());
            let (target, global, confluent) = match route {
                Route::Any => (self.id, false, false),
                Route::LocalAt(s) => (s, false, false),
                Route::GlobalAt(s) => (s, true, false),
                Route::ConfluentAt(s) => (s, false, true),
            };
            op.global = global;
            op.confluent = confluent;
            if target != self.id && op.hops == 0 {
                op.hops = 1;
                self.redirects += 1;
                let delay = ctx.topo.servers.one_way(self.id, target);
                self.core.send(target, self.core.now() + delay, Ev::Arrive { op });
                return;
            }
            // Observe at the executing server: the sliding-window counts
            // the controller re-partitions from, and the per-second
            // belted/unbelted curve the drift experiments plot.
            self.collector.note(op.txn);
            let sec = (self.core.now().as_micros() / 1_000_000) as usize;
            if self.curve.len() <= sec {
                self.curve.resize(sec + 1, (0, 0));
            }
            if op.global {
                self.curve[sec].0 += 1;
            } else {
                self.curve[sec].1 += 1;
            }
        }
        if op.global {
            // Algorithm 2 line 6: hold until the token arrives. If this
            // server currently holds the token and has not yet passed it,
            // the op still waits for the *next* rotation (the snapshot Q'
            // was already taken).
            self.pending.push(op);
            return;
        }
        let service = ctx.cfg.service.sample(&ctx.app.spec.txns[op.txn], &mut self.rng);
        self.submit_job(JobKind::Op(op), service, false);
    }

    fn submit_job(&mut self, job: JobKind, service: VTime, priority: bool) {
        let now = self.core.now();
        if let Some(started) = self.station.submit(now, job, service, priority) {
            self.core.q.schedule(started.service, Ev::JobDone { job: started.payload });
        }
    }

    fn on_job_done(&mut self, job: JobKind, ctx: &Shared<'_>) {
        // Start whatever the station dequeues next.
        let now = self.core.now();
        if let Some(next) = self.station.complete(now) {
            self.core.q.schedule(next.service, Ev::JobDone { job: next.payload });
        }

        match job {
            JobKind::Op(op) => {
                let update = self.execute_real(&op, ctx);
                // One redo record per committed operation (modeled runs
                // count every completion; the WAL skips empty updates,
                // a second-order effect the replay charge absorbs).
                self.log_len += 1;
                if op.global {
                    // Append to the token in completion order (the DBMS
                    // commit order under strict 2PL).
                    let token =
                        self.token.as_mut().expect("global op completed without the token");
                    let u = update.unwrap_or_default();
                    if ctx.cfg.record_global_log {
                        self.log.push((token.appended + 1, u.clone()));
                    }
                    token.append(self.id, u);
                    self.outstanding -= 1;
                    if self.outstanding == 0 {
                        self.pass_token(ctx, VTime::ZERO);
                    }
                } else if op.confluent {
                    // Confluent commit: replied immediately (no token
                    // wait); the delta replicates on the next pass.
                    self.stage_confluent(update.unwrap_or_default(), ctx);
                }
                self.send_reply(&op, ctx);
            }
            JobKind::Apply => {
                // Replicated updates applied; dispatch the snapshot.
                self.dispatch_globals(ctx);
            }
        }
    }

    /// Execute the operation body against this server's DB, returning its
    /// state update (None when real execution is disabled or aborted).
    fn execute_real(&mut self, op: &OpEnvelope, ctx: &Shared<'_>) -> Option<StateUpdate> {
        if !ctx.cfg.execute_real {
            return None;
        }
        let tpl = &ctx.app.spec.txns[op.txn];
        let body = tpl.body.as_ref()?;
        let db = self.db.as_ref().expect("real exec needs db");
        let stmts = &ctx.stmt_maps[op.txn];
        // Each server's events are handled sequentially, so lock
        // conflicts cannot occur within a server; semantic errors
        // (duplicate key etc.) count as aborts.
        let mut handle = db.begin();
        let mut tctx = TxnCtx::new(&mut handle, stmts);
        match body(&mut tctx, &op.args) {
            Ok(_reply) => match handle.commit() {
                Ok(update) => Some(update),
                Err(_) => {
                    self.aborts += 1;
                    None
                }
            },
            Err(TxnError::Lock(_)) | Err(_) => {
                handle.abort();
                self.aborts += 1;
                None
            }
        }
    }

    /// Queue a confluent op's update for replication: append straight to
    /// the token if it is here, otherwise hold it in the outbox until the
    /// next `TokenArrive` flushes it.
    fn stage_confluent(&mut self, u: StateUpdate, ctx: &Shared<'_>) {
        match self.token.as_mut() {
            Some(token) => {
                if ctx.cfg.record_global_log {
                    self.log.push((token.appended + 1, u.clone()));
                }
                token.append(self.id, u);
            }
            None => self.outbox.push(u),
        }
    }

    fn send_reply(&mut self, op: &OpEnvelope, ctx: &Shared<'_>) {
        let delay = ctx.client_server_latency(op.client_site, self.id);
        let ev = Ev::Reply { client: op.client, issued: op.issued, global: op.global };
        let target = client_group_target(op.client, ctx.client_groups);
        self.core.send(target, self.core.now() + delay, ev);
    }

    fn on_token(&mut self, mut token: Token, ctx: &Shared<'_>) {
        if self.id == 0 {
            self.rotations += 1;
        }
        if let Some(acfg) = &ctx.cfg.adaptive {
            // Flush this server's window counts onto the token, then
            // install any newer epoch it carries — every server switches
            // at its own receipt, so the install is totally ordered with
            // all global updates without extra coordination.
            token.ensure_obs(ctx.app.spec.txns.len());
            self.collector.flush_into(&mut token.obs);
            let installed_v = self.epoch.as_ref().map(|e| e.version).unwrap_or(0);
            if token.epoch > installed_v {
                let assign = assignment_from_wire(&token.epoch_assignment);
                self.epoch = Some(Arc::new(ctx.app.epoch_from(token.epoch, assign)));
            }
            if let Some(controller) = &self.controller {
                if self.rotations % acfg.window_rotations == 0 {
                    let (cur_version, better) = {
                        let installed =
                            self.epoch.as_ref().expect("adaptive server without an epoch");
                        (installed.version, controller.evaluate(&token.obs, &installed.assignment))
                    };
                    if let Some(next) = better {
                        let version = cur_version + 1;
                        token.epoch = version;
                        token.epoch_assignment = assignment_to_wire(&next);
                        self.epoch = Some(Arc::new(ctx.app.epoch_from(version, next)));
                        self.epoch_switches += 1;
                    }
                    // The window is consumed either way.
                    for c in token.obs.iter_mut() {
                        *c = 0;
                    }
                }
            }
        }
        let updates = token.on_receive(self.id);
        self.token = Some(token);

        // Flush deltas of confluent ops committed since the last pass.
        let outbox = std::mem::take(&mut self.outbox);
        for u in outbox {
            self.stage_confluent(u, ctx);
        }

        // Apply replicated updates (Algorithm 2 lines 11-15) as one CPU
        // job; the pending snapshot executes after it.
        if ctx.cfg.execute_real {
            if let Some(db) = self.db.as_ref() {
                for u in &updates {
                    db.apply_update(u).expect("apply_update");
                }
            }
        }
        let n_updates = updates.len();
        // Replicated updates hit the local WAL too (`try_apply_update`
        // appends after a successful apply).
        self.log_len += n_updates as u64;
        if n_updates > 0 {
            let service =
                VTime::from_millis_f64(ctx.cfg.apply_per_update_ms * n_updates as f64);
            self.submit_job(JobKind::Apply, service, true);
        } else {
            self.dispatch_globals(ctx);
        }
    }

    /// Take the atomic snapshot Q' and execute it (Algorithm 2 lines
    /// 16-21); pass the token when the snapshot drains.
    fn dispatch_globals(&mut self, ctx: &Shared<'_>) {
        let snapshot: Vec<OpEnvelope> = std::mem::take(&mut self.pending);
        if snapshot.is_empty() {
            // Nothing to do: hold briefly, then pass.
            self.pass_token(ctx, VTime::from_millis_f64(ctx.cfg.min_hold_ms));
            return;
        }
        self.outstanding = snapshot.len();
        for op in snapshot {
            let service = ctx.cfg.service.sample(&ctx.app.spec.txns[op.txn], &mut self.rng);
            // Global ops jump the queue: the paper's token thread wakes
            // the handling threads which run concurrently with new local
            // arrivals; priority keeps token hold times short.
            self.submit_job(JobKind::Op(op), service, true);
        }
    }

    fn on_crash(&mut self, ctx: &Shared<'_>) {
        let cc = ctx.cfg.crash.as_ref().expect("crash event without crash config");
        let now = self.core.now();
        let downtime = cc.downtime(self.log_len);
        self.down = true;
        self.crash = Some(CrashOutcome {
            server: self.id,
            crashed_at: now,
            recovered_at: now + downtime,
            replayed_records: self.log_len,
            held_events: 0,
        });
        self.core.q.schedule(downtime, Ev::Recover);
    }

    fn on_recover(&mut self, ctx: &Shared<'_>) {
        self.down = false;
        let held = std::mem::take(&mut self.held);
        if let Some(o) = self.crash.as_mut() {
            o.held_events = held.len() as u64;
            o.recovered_at = self.core.now();
        }
        // Drain the backlog in arrival order: job timers fire, buffered
        // requests execute, and — if the token froze here — the belt
        // starts moving again.
        for ev in held {
            self.handle(ev, ctx);
        }
    }

    fn pass_token(&mut self, ctx: &Shared<'_>, hold: VTime) {
        let token = self.token.take().expect("passing the token without holding it");
        let next = (self.id + 1) % ctx.topo.n();
        let delay = hold
            + ctx.topo.servers.one_way(self.id, next)
            + VTime::from_millis_f64(ctx.cfg.hop_overhead_ms);
        self.core.send(next, self.core.now() + delay, Ev::TokenArrive { token });
    }
}

impl IssueReply for Ev {
    fn classify(self) -> ClientEv<Ev> {
        match self {
            Ev::Issue { client } => ClientEv::Issue { client },
            Ev::Reply { client, issued, global } => {
                ClientEv::Reply { client, issued, flag: global }
            }
            other => ClientEv::Other(other),
        }
    }

    fn issue(client: usize) -> Ev {
        Ev::Issue { client }
    }
}

/// The conveyor half of the shared client tier: MAP-based routing (local
/// vs global server choice, key affinity, misroute redirects).
impl IssueRouter<Ev> for Shared<'_> {
    fn route_issue(&self, tier: &mut ClientTier<'_, Ev>, client: usize) {
        let n = self.topo.n();
        let site = tier.clients.site(client);
        // Key affinity targets the nearest server site (clients at
        // server-less sites adopt the closest deployed server).
        let affinity = self.nearest_server(site);
        let now = tier.core.now();
        let op = {
            let rng = tier.clients.rng(client);
            // Borrow juggling: generator needs its own &mut.
            let mut r = rng.fork();
            tier.gen.next_op_at(&mut r, affinity, n, now)
        };
        // Clients always route under the immutable epoch 0: the client
        // tier stays a pure function of (rng stream, time), so sharding
        // it into K groups stays invisible to results even while servers
        // re-route under later epochs.
        let route = match &self.epoch0 {
            Some(e0) => e0.route_op(self.app, &op, n),
            None => self.app.route(&op, n),
        };
        let (server, global, confluent) = match route {
            Route::Any => (affinity, false, false),
            Route::LocalAt(s) => (s, false, false),
            Route::GlobalAt(s) => (s, true, false),
            Route::ConfluentAt(s) => (s, false, true),
        };

        // Misrouting: send to a wrong server which answers MAP; the client
        // then contacts the right one — two extra hops.
        let mut delay = self.client_server_latency(site, server);
        if self.cfg.misroute_prob > 0.0 {
            let r = tier.clients.rng(client).f64();
            if r < self.cfg.misroute_prob {
                let wrong = (server + 1) % n;
                delay = self.client_server_latency(site, wrong)
                    + self.client_server_latency(site, wrong)
                    + self.client_server_latency(site, server);
            }
        }
        let env = OpEnvelope {
            txn: op.txn,
            args: op.args,
            client,
            client_site: site,
            issued: now,
            global,
            confluent,
            hops: 0,
        };
        // Tagged with the client's global id: the engine merges client
        // groups at one source rank, ordered by this tag, so delivery
        // order is independent of the client-group count.
        tier.core.send_tagged(server, now + delay, client as u32, Ev::Arrive { op: env });
    }
}

/// The simulation driver.
pub struct ConveyorSim<'a> {
    app: &'a AnalyzedApp,
    /// Per-template statements compiled once against the schema
    /// (prepare-once; all per-server DBs share one schema).
    stmt_maps: Vec<PreparedStmts>,
    topo: Topology,
    cfg: ConveyorConfig,
    clients: ClientGroups<'a, Ev>,
    servers: Vec<ServerState>,
    /// Epoch 0 (the offline analysis pinned), shared by the client tier
    /// and the servers' initial install. `Some` iff adaptivity is on.
    epoch0: Option<Arc<RoutingEpoch>>,
}

impl<'a> ConveyorSim<'a> {
    /// Build the simulation. `gen` supplies one generator instance per
    /// client group (`ClientsConfig::groups` of them; stateless callers
    /// just ignore the group index).
    pub fn new(
        app: &'a AnalyzedApp,
        topo: Topology,
        clients_cfg: ClientsConfig,
        cfg: ConveyorConfig,
        gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
        seed_db: impl Fn(&Db),
    ) -> Self {
        let n = topo.n();
        let client_sites = cfg.client_matrix.as_ref().map(|m| m.n()).unwrap_or(n);
        let epoch0 = cfg.adaptive.as_ref().map(|_| Arc::new(app.epoch0()));
        let n_templates = app.spec.txns.len();
        let servers = (0..n)
            .map(|id| {
                let db = if cfg.execute_real {
                    let db = Db::new(app.spec.schema.clone());
                    seed_db(&db);
                    Some(db)
                } else {
                    None
                };
                ServerState {
                    id,
                    db,
                    station: Station::new(cfg.workers),
                    pending: Vec::new(),
                    outstanding: 0,
                    token: None,
                    rotations: 0,
                    aborts: 0,
                    rng: Rng::stream(cfg.seed ^ 0xF00D, id as u64),
                    core: GroupCore::new(),
                    log: Vec::new(),
                    outbox: Vec::new(),
                    down: false,
                    held: Vec::new(),
                    log_len: 0,
                    crash: None,
                    epoch: epoch0.clone(),
                    collector: DriftCollector::new(n_templates),
                    controller: cfg
                        .adaptive
                        .as_ref()
                        .filter(|_| id == 0)
                        .map(|ac| EpochController::new(app, ac.clone())),
                    epoch_switches: 0,
                    redirects: 0,
                    curve: Vec::new(),
                }
            })
            .collect();
        let clients =
            ClientGroups::new(clients_cfg, client_sites, cfg.warmup, cfg.horizon, gen);
        ConveyorSim {
            stmt_maps: app.spec.txns.iter().map(|t| t.prepared_map(&app.spec.schema)).collect(),
            app,
            topo,
            cfg,
            clients,
            servers,
            epoch0,
        }
    }

    /// The conservative lookahead `L`: the minimum latency any
    /// cross-group event pays. Every client↔server leg and every token
    /// hop is at least this far in the future, so events inside a window
    /// `[T, T + L)` cannot be affected by other groups' work in the same
    /// window.
    fn lookahead(&self) -> VTime {
        let n = self.topo.n();
        let mut l = VTime::from_micros(u64::MAX);
        // Client <-> server legs (Issue→Arrive, op completion→Reply).
        match &self.cfg.client_matrix {
            Some(m) => {
                for site in 0..m.n() {
                    for s in 0..n {
                        l = l.min(m.one_way(site, s));
                    }
                }
            }
            None => {
                l = l.min(self.topo.servers.min_one_way());
            }
        }
        // Token ring hops; every pass also pays the hop overhead.
        let hop = VTime::from_millis_f64(self.cfg.hop_overhead_ms);
        for a in 0..n {
            let b = (a + 1) % n;
            l = l.min(self.topo.servers.one_way(a, b) + hop);
        }
        // Adaptive routing forwards arrivals between *arbitrary* server
        // pairs (no hop overhead), so the lookahead must cover them all.
        if self.cfg.adaptive.is_some() {
            for a in 0..n {
                for b in 0..n {
                    if a != b {
                        l = l.min(self.topo.servers.one_way(a, b));
                    }
                }
            }
        }
        l
    }

    /// Run the simulation to the configured horizon and return final
    /// metrics. Consumes the driver.
    pub fn run(self) -> ConveyorReport {
        self.run_keep_dbs().0
    }

    /// Like [`run`](Self::run), but additionally hands back the
    /// per-server DB instances (real-execution runs; `None` entries
    /// otherwise) so tests can inspect final state beyond the digest.
    pub fn run_keep_dbs(mut self) -> (ConveyorReport, Vec<Option<Db>>) {
        // Boot: token starts at server 0; all client groups stage their
        // first issues.
        let n = self.topo.n();
        let token = Token::new(n);
        self.servers[0].core.q.schedule_at(VTime::ZERO, Ev::TokenArrive { token });
        if let Some(cc) = &self.cfg.crash {
            assert!(cc.server < n, "crash.server {} out of range (n={n})", cc.server);
            self.servers[cc.server].core.q.schedule_at(cc.at, Ev::Crash);
        }
        self.clients.boot();

        let lookahead = self.lookahead();
        let threads = parallel::resolve_threads(self.cfg.parallel);
        let horizon = self.cfg.horizon;

        let ConveyorSim { app, stmt_maps, topo, cfg, mut clients, mut servers, epoch0 } = self;
        let windows = {
            let ctx = Shared {
                app,
                stmt_maps: &stmt_maps,
                topo: &topo,
                cfg: &cfg,
                client_groups: clients.k(),
                epoch0,
            };
            parallel::run_windows(
                threads,
                lookahead,
                horizon,
                &ctx,
                &mut servers,
                &mut clients.groups,
            )
        };

        let now = cfg.horizon;
        let mut log: Vec<(u64, StateUpdate)> = Vec::new();
        for s in servers.iter_mut() {
            log.append(&mut s.log);
        }
        log.sort_by_key(|(seq, _)| *seq);
        let report = ConveyorReport {
            metrics: clients.metrics(),
            rotations: servers.iter().map(|s| s.rotations).sum(),
            utilization: servers.iter().map(|s| s.station.utilization(now)).collect(),
            aborts: servers.iter().map(|s| s.aborts).sum(),
            db_hashes: servers.iter().map(|s| s.db.as_ref().map(|d| d.content_hash())).collect(),
            events: clients.processed()
                + servers.iter().map(|s| s.core.q.processed()).sum::<u64>(),
            windows,
            global_log_seqs: log.iter().map(|(seq, _)| *seq).collect(),
            global_log: log.into_iter().map(|(_, u)| u).collect(),
            crash: servers.iter().find_map(|s| s.crash),
            epoch_switches: servers.iter().map(|s| s.epoch_switches).sum(),
            final_epoch: servers
                .iter()
                .map(|s| s.epoch.as_ref().map(|e| e.version).unwrap_or(0))
                .max()
                .unwrap_or(0),
            redirects: servers.iter().map(|s| s.redirects).sum(),
            drift_curve: {
                let mut curve: Vec<(u64, u64)> = Vec::new();
                for s in servers.iter() {
                    if curve.len() < s.curve.len() {
                        curve.resize(s.curve.len(), (0, 0));
                    }
                    for (sec, &(belted, local)) in s.curve.iter().enumerate() {
                        curve[sec].0 += belted;
                        curve[sec].1 += local;
                    }
                }
                curve
            },
        };
        let dbs = servers.into_iter().map(|s| s.db).collect();
        (report, dbs)
    }
}

/// Output of one simulation run.
#[derive(Debug, Clone)]
pub struct ConveyorReport {
    pub metrics: SimMetrics,
    pub rotations: u64,
    pub utilization: Vec<f64>,
    pub aborts: u64,
    /// Per-server DB content hashes (real-execution runs); replicated
    /// tables must converge once quiesced.
    pub db_hashes: Vec<Option<u64>>,
    pub events: u64,
    /// Conservative windows the engine executed (the worker-pool bench
    /// divides wall clock by this to get windows/second).
    pub windows: u64,
    /// The token's total order of global state updates (only populated
    /// with [`ConveyorConfig::record_global_log`]): the serial history
    /// every server's replicated state must be explainable by.
    pub global_log: Vec<StateUpdate>,
    /// Token sequence numbers of [`ConveyorReport::global_log`], in log
    /// order. Must be contiguous from 1 — a gap means a lost update, a
    /// duplicate means one applied twice (the epoch-switch soundness
    /// oracle).
    pub global_log_seqs: Vec<u64>,
    /// What the configured crash cost (`None` when no crash was
    /// configured or it landed past the horizon).
    pub crash: Option<CrashOutcome>,
    /// Routing-epoch installations the controller initiated (0 when
    /// adaptivity is off or frozen).
    pub epoch_switches: u64,
    /// Highest epoch version installed anywhere by the horizon.
    pub final_epoch: u64,
    /// Arrivals a server forwarded to their installed-epoch home.
    pub redirects: u64,
    /// Per-virtual-second `(belted, unbelted)` executed-op counts summed
    /// across servers (populated only under [`ConveyorConfig::adaptive`]) —
    /// the static-vs-adaptive drift figure plots the belted fraction of
    /// this curve.
    pub drift_curve: Vec<(u64, u64)>,
}

impl ConveyorReport {
    /// Belted fraction over seconds `[from, to)` of the drift curve.
    pub fn belted_fraction(&self, from: usize, to: usize) -> f64 {
        let mut belted = 0u64;
        let mut total = 0u64;
        for &(b, l) in self.drift_curve.iter().take(to).skip(from) {
            belted += b;
            total += b + l;
        }
        if total == 0 {
            return 0.0;
        }
        belted as f64 / total as f64
    }
}

impl ConveyorReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        // Integer-sum mean: bit-identical across client-group counts
        // and available in bucketed (million-client) mode, where the
        // per-sample Summary is intentionally empty.
        self.metrics.mean_latency_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

    /// A small cart app: local add, global order (writes shared STOCK).
    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "add",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            )
            .with_body(|ctx, args| ctx.exec("u", args)),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    // The touched item is derived from the cart content at
                    // run time — an opaque write, so `order` is Global
                    // exactly like the paper's Figure 1.
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived_item"),
                ],
                1.0,
            )
            .with_body(|ctx, args| {
                ctx.exec("r", args)?;
                let cid = args.get("cid").and_then(|v| v.as_int()).unwrap_or(0);
                let mut b = args.clone();
                b.insert("derived_item".to_string(), Value::Int(cid.rem_euclid(8)));
                ctx.exec("w", &b)
            }),
        ];
        let app = AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns });
        assert_eq!(*app.class(0), crate::analysis::OpClass::Local);
        assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
        app
    }

    struct MixGen {
        global_ratio: f64,
    }

    impl OpGenerator for MixGen {
        fn next_op(&mut self, rng: &mut Rng, site: usize, n: usize) -> Operation {
            if rng.chance(self.global_ratio) {
                // order a random cart; the derived item write makes it global.
                let cid = (rng.range(0, 1000) * n + site) as i64;
                let args: Bindings =
                    [("cid".to_string(), Value::Int(cid))].into_iter().collect();
                Operation { txn: 1, args }
            } else {
                // add: site-affine cart id.
                let cid = (rng.range(0, 1000) * n + site) as i64;
                let args: Bindings =
                    [("cid".to_string(), Value::Int(cid))].into_iter().collect();
                Operation { txn: 0, args }
            }
        }
    }

    fn seed(db: &Db) {
        use crate::db::BindSlots;
        let ins_cart = db.prepare_sql("INSERT INTO CARTS (CID, QTY) VALUES (?c, 0)").unwrap();
        let ins_stock = db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 1000)").unwrap();
        for c in 0..5000i64 {
            db.exec_auto_prepared(&ins_cart, &BindSlots(vec![Value::Int(c)])).unwrap();
        }
        for i in 0..8i64 {
            db.exec_auto_prepared(&ins_stock, &BindSlots(vec![Value::Int(i)])).unwrap();
        }
    }

    fn run_par(
        n_servers: usize,
        clients: usize,
        global_ratio: f64,
        real: bool,
        threads: usize,
    ) -> ConveyorReport {
        let app = app();
        let cfg = ConveyorConfig {
            execute_real: real,
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            parallel: threads,
            ..Default::default()
        };
        let sim = ConveyorSim::new(
            &app,
            Topology::lan(n_servers),
            ClientsConfig { n: clients, think_ms: 10.0, seed: 7, ..Default::default() },
            cfg,
            move |_| Box::new(MixGen { global_ratio }),
            seed,
        );
        sim.run()
    }

    fn run(n_servers: usize, clients: usize, global_ratio: f64, real: bool) -> ConveyorReport {
        run_par(n_servers, clients, global_ratio, real, 1)
    }

    #[test]
    fn local_only_workload_flows() {
        let r = run(3, 30, 0.0, false);
        assert!(r.metrics.completed > 500, "completed={}", r.metrics.completed);
        // Latency ≈ client RTT (20ms) + service (5ms) + queueing.
        let mean = r.mean_latency_ms();
        assert!(mean > 20.0 && mean < 80.0, "mean={mean}");
        assert_eq!(r.metrics.global_latency.count(), 0);
    }

    #[test]
    fn global_ops_wait_for_token_and_cost_more() {
        let mut r = run(3, 30, 0.3, false);
        assert!(r.metrics.global_latency.count() > 50);
        let lg = r.metrics.global_latency.mean();
        let ll = r.metrics.local_latency.mean();
        assert!(
            lg > ll * 1.5,
            "global latency ({lg}) should exceed local ({ll}) significantly"
        );
        assert!(r.rotations > 10, "token must rotate: {}", r.rotations);
        // Sanity on percentiles API.
        assert!(r.metrics.latency.p99() >= r.metrics.latency.p50());
    }

    #[test]
    fn real_execution_replicates_global_writes() {
        let r = run(3, 20, 0.4, true);
        assert!(r.metrics.completed > 200);
        assert_eq!(r.aborts, 0, "no aborts expected");
        // STOCK must have been written: decrements happened across
        // servers. Per-server hashes differ because CARTS are partial
        // (local, not replicated) — convergence of the replicated STOCK
        // table is asserted in the integration test which quiesces first.
        assert!(r.db_hashes.iter().all(|h| h.is_some()));
    }

    #[test]
    fn more_servers_increase_local_capacity() {
        // Pure-local workload: 9 servers should sustain clearly more than 1.
        let r1 = run(1, 120, 0.0, false);
        let r9 = run(9, 120, 0.0, false);
        assert!(
            r9.throughput() > r1.throughput() * 2.0,
            "t1={} t9={}",
            r1.throughput(),
            r9.throughput()
        );
    }

    #[test]
    fn misrouting_adds_latency() {
        let app = app();
        let mk = |mis: f64| {
            let cfg = ConveyorConfig {
                misroute_prob: mis,
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(8),
                service: ServiceModel::fixed(5.0),
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 10, think_ms: 10.0, seed: 3, ..Default::default() },
                cfg,
                |_| Box::new(MixGen { global_ratio: 0.0 }),
                |_db| {},
            )
            .run()
        };
        let clean = mk(0.0).mean_latency_ms();
        let dirty = mk(0.5).mean_latency_ms();
        assert!(dirty > clean + 5.0, "clean={clean} dirty={dirty}");
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(3, 25, 0.2, false);
        let b = run(3, 25, 0.2, false);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
        assert!((a.mean_latency_ms() - b.mean_latency_ms()).abs() < 1e-9);
    }

    /// The headline property of the window engine, checked cheaply here
    /// and exhaustively in `tests/parallel_determinism.rs`: any thread
    /// count produces bit-identical results.
    #[test]
    fn thread_count_does_not_change_results() {
        let base = run_par(4, 40, 0.3, false, 1);
        for threads in [2usize, 0] {
            let r = run_par(4, 40, 0.3, false, threads);
            assert_eq!(r.metrics.completed, base.metrics.completed, "threads={threads}");
            assert_eq!(r.events, base.events, "threads={threads}");
            assert_eq!(r.rotations, base.rotations, "threads={threads}");
            assert!(
                (r.mean_latency_ms() - base.mean_latency_ms()).abs() < 1e-12,
                "threads={threads}"
            );
        }
    }

    /// Tentpole: sharding the client tier into K groups is invisible to
    /// results — any group count, crossed with any thread count,
    /// matches the single-group sequential run bit for bit (integer
    /// latency stats included). Exhaustive matrix in
    /// `tests/parallel_determinism.rs`.
    #[test]
    fn client_group_count_does_not_change_results() {
        let run_k = |groups: usize, threads: usize| {
            let app = app();
            let cfg = ConveyorConfig {
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 24, think_ms: 10.0, seed: 7, groups, ..Default::default() },
                cfg,
                |_| Box::new(MixGen { global_ratio: 0.3 }),
                seed,
            )
            .run()
        };
        let base = run_k(1, 1);
        assert!(base.metrics.completed > 200);
        for (groups, threads) in [(2usize, 1usize), (2, 2), (24, 0), (0, 0)] {
            let r = run_k(groups, threads);
            assert_eq!(r.metrics.completed, base.metrics.completed, "k={groups} t={threads}");
            assert_eq!(r.events, base.events, "k={groups} t={threads}");
            assert_eq!(r.windows, base.windows, "k={groups} t={threads}");
            assert_eq!(r.rotations, base.rotations, "k={groups} t={threads}");
            assert_eq!(
                r.mean_latency_ms().to_bits(),
                base.mean_latency_ms().to_bits(),
                "k={groups} t={threads}"
            );
            assert_eq!(
                r.metrics.latency_hist.buckets(),
                base.metrics.latency_hist.buckets(),
                "k={groups} t={threads}"
            );
        }
    }

    /// Satellite guard: the documented defaults the benches assume. A
    /// silent retuning of these constants would skew every recorded
    /// figure, so drift fails loudly here.
    #[test]
    fn documented_defaults_match_bench_assumptions() {
        let c = ConveyorConfig::default();
        assert_eq!(c.workers, 8);
        assert!((c.apply_per_update_ms - 0.05).abs() < 1e-12);
        assert!((c.min_hold_ms - 0.1).abs() < 1e-12);
        assert!((c.hop_overhead_ms - 0.1).abs() < 1e-12);
        assert!((c.misroute_prob - 0.0).abs() < 1e-12);
        assert_eq!(c.parallel, 1, "sequential by default; benches opt in");
        assert!(!c.record_global_log);
        assert!(c.crash.is_none(), "durability modeling is opt-in");
        assert!(c.adaptive.is_none(), "adaptive routing epochs are opt-in");
        assert!(!c.execute_real);
        assert_eq!(c.warmup, VTime::from_secs(5));
        assert_eq!(c.horizon, VTime::from_secs(25));
        assert_eq!(c.seed, 0x5EED);
    }

    /// Tentpole: a server crash freezes the belt (the token stalls with
    /// the crashed server), recovery replays the modeled WAL, and held
    /// traffic drains — the run completes, just slower. Crash handling
    /// is group-local, so thread count still cannot change a bit.
    #[test]
    fn crash_stalls_the_belt_then_recovers_deterministically() {
        let app = app();
        let mk = |crash: Option<CrashConfig>, threads: usize| {
            let cfg = ConveyorConfig {
                execute_real: true,
                crash,
                warmup: VTime::from_secs(1),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 24, think_ms: 10.0, seed: 7, ..Default::default() },
                cfg,
                |_| Box::new(MixGen { global_ratio: 0.3 }),
                seed,
            )
            .run()
        };
        let clean = mk(None, 1);
        let cc = CrashConfig {
            server: 1,
            at: VTime::from_secs(4),
            restart_ms: 800.0,
            replay_per_record_ms: 0.05,
        };
        let crashed = mk(Some(cc.clone()), 1);
        let o = crashed.crash.expect("crash outcome");
        assert_eq!(o.server, 1);
        assert_eq!(o.crashed_at, VTime::from_secs(4));
        assert!(o.replayed_records > 0, "server 1 must have logged work by 4s");
        assert!(o.held_events > 0, "belt traffic must pile up during the outage");
        assert!(o.downtime_ms() >= 800.0, "downtime {} < restart cost", o.downtime_ms());
        assert_eq!(o.recovered_at, o.crashed_at + cc.downtime(o.replayed_records));
        // The stall is visible end to end: fewer rotations, higher
        // latency — but every held request is eventually answered.
        assert!(crashed.rotations < clean.rotations, "token did not stall");
        assert!(crashed.metrics.completed > 100);
        assert!(
            crashed.mean_latency_ms() > clean.mean_latency_ms(),
            "outage must show up as a latency spike: {} vs {}",
            crashed.mean_latency_ms(),
            clean.mean_latency_ms()
        );
        let par = mk(Some(cc), 2);
        assert_eq!(par.metrics.completed, crashed.metrics.completed);
        assert_eq!(par.events, crashed.events);
        assert_eq!(par.crash, crashed.crash);
        assert_eq!(par.mean_latency_ms().to_bits(), crashed.mean_latency_ms().to_bits());
    }

    /// Satellite regression (carried from the WAL PR): crashing the
    /// server where the token boots *and rotations are counted* (server
    /// 0). The token freezes with it — either held at crash time or
    /// parked in `held` when the next `TokenArrive` lands during the
    /// outage — so the whole belt stalls, the rotation counter resumes
    /// from its exact frozen value at recovery, and the run stays
    /// bit-identical at 2 threads.
    #[test]
    fn token_holder_crash_freezes_the_belt_and_resumes() {
        let app = app();
        let mk = |crash: Option<CrashConfig>, threads: usize| {
            let cfg = ConveyorConfig {
                execute_real: true,
                crash,
                warmup: VTime::from_secs(1),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 24, think_ms: 10.0, seed: 7, ..Default::default() },
                cfg,
                |_| Box::new(MixGen { global_ratio: 0.3 }),
                seed,
            )
            .run()
        };
        let clean = mk(None, 1);
        let cc = CrashConfig {
            server: 0,
            at: VTime::from_secs(4),
            restart_ms: 800.0,
            replay_per_record_ms: 0.05,
        };
        let crashed = mk(Some(cc.clone()), 1);
        let o = crashed.crash.expect("crash outcome");
        assert_eq!(o.server, 0);
        assert!(o.held_events > 0, "the token (or belt traffic) must freeze here");
        // The belt stalls for the downtime, then resumes: strictly fewer
        // rotations than the clean run, but far more than zero — the
        // counter picks up from its frozen value rather than resetting.
        assert!(
            crashed.rotations < clean.rotations,
            "belt did not stall: {} vs {}",
            crashed.rotations,
            clean.rotations
        );
        assert!(
            crashed.rotations > clean.rotations / 2,
            "belt never resumed: {} vs {}",
            crashed.rotations,
            clean.rotations
        );
        assert!(crashed.metrics.completed > 100, "held requests must drain");
        // Determinism: a rerun and a 2-thread run agree bit for bit —
        // including the exact rotation count after resumption.
        let again = mk(Some(cc.clone()), 1);
        assert_eq!(again.rotations, crashed.rotations);
        assert_eq!(again.events, crashed.events);
        let par = mk(Some(cc), 2);
        assert_eq!(par.rotations, crashed.rotations, "thread count changed rotations");
        assert_eq!(par.events, crashed.events);
        assert_eq!(par.crash, crashed.crash);
        assert_eq!(par.mean_latency_ms().to_bits(), crashed.mean_latency_ms().to_bits());
    }

    /// Tentpole: confluent ops execute without the token and their deltas
    /// replicate on the next pass — all replicas converge on the
    /// replicated table exactly as for token-ordered globals.
    #[test]
    fn confluent_ops_bypass_the_token_and_still_replicate() {
        // STOCK with a declared non-negative LEVEL and an increment-only
        // writer: the confluence pass promotes `restock` to Confluent.
        let schema = Schema::new(vec![TableSchema::new(
            "STOCK",
            &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
            &["ITEM"],
        )
        .with_nonnegative("LEVEL")]);
        let txns = vec![TxnTemplate::new(
            "restock",
            &["item"],
            &[("w", "UPDATE STOCK SET LEVEL = LEVEL + 1 WHERE ITEM = ?derived")],
            1.0,
        )
        .with_body(|ctx, args| {
            let item = args.get("item").and_then(|v| v.as_int()).unwrap_or(0);
            let mut b = args.clone();
            b.insert("derived".to_string(), Value::Int(item.rem_euclid(8)));
            ctx.exec("w", &b)
        })];
        let app = AnalyzedApp::analyze_confluent(crate::workload::spec::AppSpec {
            name: "restock".into(),
            schema,
            txns,
        });
        assert_eq!(*app.class(0), crate::analysis::OpClass::Confluent);

        struct RestockGen;
        impl OpGenerator for RestockGen {
            fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
                let args: Bindings =
                    [("item".to_string(), Value::Int(rng.range(0, 1000) as i64))]
                        .into_iter()
                        .collect();
                Operation { txn: 0, args }
            }
        }
        let seed_stock = |db: &Db| {
            use crate::db::BindSlots;
            let ins =
                db.prepare_sql("INSERT INTO STOCK (ITEM, LEVEL) VALUES (?i, 0)").unwrap();
            for i in 0..8i64 {
                db.exec_auto_prepared(&ins, &BindSlots(vec![Value::Int(i)])).unwrap();
            }
        };
        let cfg = ConveyorConfig {
            execute_real: true,
            record_global_log: true,
            warmup: VTime::from_secs(1),
            horizon: VTime::from_secs(8),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        let (r, dbs) = ConveyorSim::new(
            &app,
            Topology::lan(3),
            ClientsConfig { n: 12, think_ms: 10.0, seed: 7, ..Default::default() },
            cfg,
            |_| Box::new(RestockGen),
            seed_stock,
        )
        .run_keep_dbs();
        assert!(r.metrics.completed > 100);
        assert_eq!(r.aborts, 0);
        // No op ever waited for the token...
        assert_eq!(r.metrics.global_latency.count(), 0, "confluent ops must not wait");
        // ...yet their deltas rode it: the recorded token history is
        // non-empty and replays to the total restock count.
        assert!(!r.global_log.is_empty(), "confluent deltas must ride the token");
        use crate::db::Key;
        let total = |db: &Db| -> i64 {
            (0..8i64)
                .map(|item| {
                    db.peek("STOCK", &Key::single(Value::Int(item))).unwrap()[1]
                        .as_int()
                        .unwrap()
                })
                .sum()
        };
        let replica = Db::new(app.spec.schema.clone());
        seed_stock(&replica);
        for u in &r.global_log {
            replica.apply_update(u).unwrap();
        }
        assert_eq!(total(&replica), r.global_log.len() as i64);
        // Every server applied a prefix of everyone's deltas on top of
        // its own commits: strictly positive stock everywhere, bounded by
        // the full history.
        for (s, db) in dbs.iter().enumerate() {
            let t = total(db.as_ref().expect("real-execution db"));
            assert!(t > 0, "server {s} saw no restocks");
            assert!(t <= r.global_log.len() as i64, "server {s} over-applied");
        }
    }

    /// Tentpole: under the drift workload (flash crowd at 10 s flips the
    /// dominant update stream from `aupd` to `bupd`) the controller
    /// re-partitions from the token-borne observation window and installs
    /// a new epoch over the token. The frozen arm keeps paying the belt
    /// for the now-dominant stream; the adaptive arm sheds it — its
    /// steady-state belted fraction after the drift point is strictly
    /// lower. Redirects are exercised too: `move`'s pinned routing
    /// parameter flips from `a` to `b`, so epoch-0-routed arrivals get
    /// forwarded to their new home.
    #[test]
    fn adaptive_epochs_shed_belt_traffic_after_drift() {
        use crate::analysis::drift::DriftConfig;
        use crate::workload::micro::{drift_analyzed, DriftGen};
        let app = drift_analyzed();
        let run = |adaptive: AdaptiveConfig, threads: usize| {
            let cfg = ConveyorConfig {
                adaptive: Some(adaptive),
                warmup: VTime::from_secs(1),
                horizon: VTime::from_secs(20),
                service: ServiceModel::fixed(1.0),
                parallel: threads,
                ..Default::default()
            };
            ConveyorSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 24, think_ms: 10.0, seed: 7, ..Default::default() },
                cfg,
                |_| Box::new(DriftGen::new(DriftConfig::default())),
                |_db| {},
            )
            .run()
        };
        let frozen = run(AdaptiveConfig::frozen(), 1);
        assert!(frozen.metrics.completed > 1000);
        assert_eq!(frozen.epoch_switches, 0, "frozen arm must never switch");
        assert_eq!(frozen.final_epoch, 0);
        let adaptive = run(AdaptiveConfig { window_rotations: 32, ..Default::default() }, 1);
        assert!(adaptive.epoch_switches >= 1, "controller must re-partition after the drift");
        assert!(adaptive.final_epoch >= 1);
        assert!(adaptive.redirects > 0, "move's home flips; epoch-0 arrivals must forward");
        let f = frozen.belted_fraction(14, 20);
        let a = adaptive.belted_fraction(14, 20);
        assert!(
            a < f,
            "adaptive steady-state belted fraction ({a:.3}) must beat static ({f:.3})"
        );
        // Before the drift both arms route identically.
        let f0 = frozen.belted_fraction(2, 9);
        let a0 = adaptive.belted_fraction(2, 9);
        assert!((f0 - a0).abs() < 1e-12, "pre-drift arms diverged: {f0} vs {a0}");

        // Adaptivity preserves the engine's headline property: thread
        // count cannot change a bit — epoch switches, redirects and the
        // curve included.
        let par = run(AdaptiveConfig { window_rotations: 32, ..Default::default() }, 2);
        assert_eq!(par.metrics.completed, adaptive.metrics.completed);
        assert_eq!(par.events, adaptive.events);
        assert_eq!(par.epoch_switches, adaptive.epoch_switches);
        assert_eq!(par.final_epoch, adaptive.final_epoch);
        assert_eq!(par.redirects, adaptive.redirects);
        assert_eq!(par.drift_curve, adaptive.drift_curve);
        assert_eq!(par.mean_latency_ms().to_bits(), adaptive.mean_latency_ms().to_bits());
    }

    /// The recorded token log is the serial history: replaying it on a
    /// fresh DB must reproduce every server's replicated table.
    #[test]
    fn global_log_replays_to_converged_state() {
        let app = app();
        let cfg = ConveyorConfig {
            execute_real: true,
            record_global_log: true,
            warmup: VTime::from_secs(1),
            horizon: VTime::from_secs(6),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        let (r, dbs) = ConveyorSim::new(
            &app,
            Topology::lan(3),
            ClientsConfig { n: 12, think_ms: 10.0, seed: 7, ..Default::default() },
            cfg,
            |_| Box::new(MixGen { global_ratio: 0.5 }),
            seed,
        )
        .run_keep_dbs();
        assert!(!r.global_log.is_empty());
        assert!(r.metrics.completed > 100);
        // Serial replay of the token history on a fresh replica.
        let replica = Db::new(app.spec.schema.clone());
        seed(&replica);
        for u in &r.global_log {
            replica.apply_update(u).unwrap();
        }
        use crate::db::Key;
        let levels = |db: &Db| -> Vec<i64> {
            (0..8i64)
                .map(|item| {
                    db.peek("STOCK", &Key::single(Value::Int(item))).unwrap()[1]
                        .as_int()
                        .unwrap()
                })
                .collect()
        };
        // Every recorded global is one STOCK decrement, so the full
        // replay sells exactly log-many units — the log records real,
        // replayable effects.
        let full = levels(&replica);
        let sold: i64 = full.iter().map(|l| 1000 - l).sum();
        assert_eq!(sold, r.global_log.len() as i64);
        // The generator never quiesces (globals keep arriving up to the
        // horizon), so each server holds the effects of a *subset* of
        // the log: per item, its level sits between the full replay and
        // the seed value — and well below the seed overall, proving the
        // servers really applied replicated updates.
        for (s, db) in dbs.iter().enumerate() {
            let lv = levels(db.as_ref().expect("real-execution db"));
            let mut server_sold = 0;
            for (item, (&have, &all)) in lv.iter().zip(full.iter()).enumerate() {
                assert!(
                    (all..=1000).contains(&have),
                    "server {s} item {item}: level {have} outside [{all}, 1000]"
                );
                server_sold += 1000 - have;
            }
            assert!(server_sold > 0, "server {s} applied no global updates");
        }
    }
}
