//! The Conveyor Belt protocol (paper §4) — Eliá's coordination core.
//!
//! * [`token`] — the circulating token (Primary Order atomic broadcast),
//! * [`sim`] — the virtual-time simulation of an N-server deployment,
//! * [`deploy`] — the real-threads runtime (Algorithm 2 verbatim, real
//!   concurrency, used by examples and the serializability tests).

pub mod deploy;
pub mod sim;
pub mod token;

pub use deploy::{DeployConfig, Deployment, ServerCore};
pub use sim::{ConveyorConfig, ConveyorReport, ConveyorSim};
pub use token::{Token, TokenEntry};
