//! The token: the Conveyor Belt's replication vehicle.
//!
//! The token carries `⟨u, q⟩` entries — state updates of global
//! operations executed at server `q`. It circulates in a fixed ring
//! order; a server receiving the token removes its *own* entries (they
//! have completed a full rotation, so every other server has applied
//! them — Algorithm 2 lines 11-13) and applies everyone else's (each
//! entry is seen exactly once per server during its single rotation of
//! life). This implements Primary Order atomic broadcast (paper appendix,
//! Lemma 1).

use crate::db::StateUpdate;
use std::collections::VecDeque;

/// One token entry: the update `u` produced at origin server `q`, with a
/// global sequence number (the token total order).
#[derive(Debug, Clone, PartialEq)]
pub struct TokenEntry {
    pub origin: usize,
    pub seq: u64,
    pub update: StateUpdate,
}

/// The circulating token.
///
/// Exactly-once delivery is tracked with per-server *watermarks* (highest
/// applied sequence). An entry is pruned once every server's watermark
/// covers it — in the steady ring this coincides with Algorithm 2's
/// "remove own entries after one rotation", and it additionally makes
/// irregular receipt orders (the shutdown drain) safe.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Token {
    entries: VecDeque<TokenEntry>,
    /// Highest entry sequence each server has applied.
    applied_up_to: Vec<u64>,
    /// Total updates ever appended (diagnostics).
    pub appended: u64,
    /// Completed ring rotations (diagnostics).
    pub rotations: u64,
    /// Installed routing-epoch version (live re-partitioning,
    /// `analysis::drift`). The token is the installation vehicle: server 0
    /// bumps this with a fresh [`Token::epoch_assignment`], every server
    /// installs at receipt — a total-order barrier with no extra
    /// coordination protocol. `0` = epoch 0 / adaptivity off.
    pub epoch: u64,
    /// Wire form of the epoch's partitioning assignment (`-1` = `None`,
    /// see `analysis::drift::assignment_to_wire`). Empty when adaptivity
    /// is off.
    pub epoch_assignment: Vec<i64>,
    /// Sliding-window per-template operation counts (the drift
    /// collector's transport): each server flushes its local counts into
    /// this at receipt; the controller at server 0 reads and resets it
    /// every observation window. Empty when adaptivity is off.
    pub obs: Vec<u64>,
}

impl Token {
    /// A token for a ring of `n` servers.
    pub fn new(n: usize) -> Self {
        Token { applied_up_to: vec![0; n.max(1)], ..Token::default() }
    }

    /// Process token receipt at server `p`: return the updates `p` has
    /// not yet applied, in token (= total) order, and prune entries every
    /// server has now seen.
    pub fn on_receive(&mut self, p: usize) -> Vec<StateUpdate> {
        let mark = self.applied_up_to[p];
        let fresh: Vec<StateUpdate> = self
            .entries
            .iter()
            .filter(|e| e.seq > mark)
            .map(|e| e.update.clone())
            .collect();
        if let Some(max) = self.entries.iter().map(|e| e.seq).max() {
            self.applied_up_to[p] = max.max(mark);
        }
        let global_min = self.applied_up_to.iter().copied().min().unwrap_or(0);
        self.entries.retain(|e| e.seq > global_min);
        fresh
    }

    /// Append an update produced by a global operation at server `p`
    /// (Algorithm 2 line 19). Order of appends must match the DBMS
    /// serialization order — the engine's `commit_with` hook guarantees
    /// that in the real runtime; the simulator appends at completion time.
    pub fn append(&mut self, p: usize, update: StateUpdate) {
        self.appended += 1;
        let seq = self.appended;
        // The producing server's own state already reflects the update.
        self.applied_up_to[p] = self.applied_up_to[p].max(seq);
        self.entries.push_back(TokenEntry { origin: p, seq, update });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialized size for latency modeling.
    pub fn wire_size(&self) -> usize {
        16 + self.entries.iter().map(|e| 8 + e.update.wire_size()).sum::<usize>()
            + 8
            + 8 * self.epoch_assignment.len()
            + 8 * self.obs.len()
    }

    /// Ensure the observation vector covers `n` templates (idempotent).
    pub fn ensure_obs(&mut self, n: usize) {
        if self.obs.len() < n {
            self.obs.resize(n, 0);
        }
    }

    /// Iterate the in-flight entries, oldest (lowest `seq`) first — the
    /// wire encoder (`net::proto`) and the test oracles read these.
    pub fn entries(&self) -> impl Iterator<Item = &TokenEntry> {
        self.entries.iter()
    }

    /// Per-server applied watermarks (highest sequence each ring position
    /// has applied). Index = server, in ring order.
    pub fn watermarks(&self) -> &[u64] {
        &self.applied_up_to
    }

    /// Rebuild a token from its wire parts — the decode side of the net
    /// frame codec. Inverse of reading [`Token::entries`],
    /// [`Token::watermarks`], `appended`, `rotations`, and the epoch /
    /// observation fields off a token.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        entries: Vec<TokenEntry>,
        watermarks: Vec<u64>,
        appended: u64,
        rotations: u64,
        epoch: u64,
        epoch_assignment: Vec<i64>,
        obs: Vec<u64>,
    ) -> Token {
        Token {
            entries: entries.into(),
            applied_up_to: watermarks,
            appended,
            rotations,
            epoch,
            epoch_assignment,
            obs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::value::{Key, Value};
    use crate::db::WriteRecord;

    fn upd(tag: i64) -> StateUpdate {
        StateUpdate {
            records: vec![WriteRecord::Delete { table: 0, key: Key::single(Value::Int(tag)) }],
        }
    }

    fn tags(v: &[StateUpdate]) -> Vec<i64> {
        v.iter()
            .map(|u| match &u.records[0] {
                WriteRecord::Delete { key, .. } => key.0[0].as_int().unwrap(),
                _ => unreachable!(),
            })
            .collect()
    }

    #[test]
    fn each_server_applies_each_entry_exactly_once() {
        // Ring of 3; server 0 appends u0, u1. Walk the ring: 1 and 2 apply
        // both; back at 0 they are removed; a second rotation applies
        // nothing anywhere.
        let mut t = Token::new(3);
        t.append(0, upd(100));
        t.append(0, upd(101));
        assert_eq!(tags(&t.on_receive(1)), vec![100, 101]);
        assert_eq!(tags(&t.on_receive(2)), vec![100, 101]);
        assert!(t.on_receive(0).is_empty());
        assert!(t.is_empty());
        for p in [1, 2, 0] {
            assert!(t.on_receive(p).is_empty());
        }
    }

    #[test]
    fn interleaved_origins_preserve_total_order() {
        let mut t = Token::new(3);
        t.append(0, upd(1));
        // Token moves to 1, which applies (1) and appends its own.
        assert_eq!(tags(&t.on_receive(1)), vec![1]);
        t.append(1, upd(2));
        // Server 2 applies both in order.
        assert_eq!(tags(&t.on_receive(2)), vec![1, 2]);
        // Server 0 drops its own, applies (2).
        assert_eq!(tags(&t.on_receive(0)), vec![2]);
        // Server 1 drops its own; nothing left.
        assert!(t.on_receive(1).is_empty());
        assert_eq!(t.appended, 2);
    }

    #[test]
    fn wire_size_grows_with_entries() {
        let mut t = Token::new(3);
        let empty = t.wire_size();
        t.append(0, upd(1));
        assert!(t.wire_size() > empty);
    }

    #[test]
    fn epoch_fields_ride_and_roundtrip() {
        let mut t = Token::new(2);
        t.ensure_obs(3);
        t.ensure_obs(2); // idempotent, never shrinks
        t.obs[1] += 5;
        t.epoch = 2;
        t.epoch_assignment = vec![0, -1, 1];
        t.append(0, upd(1));
        let _ = t.on_receive(1);
        // Receipt applies/prunes entries but never touches epoch state.
        assert_eq!(t.epoch, 2);
        assert_eq!(t.obs, vec![0, 5, 0]);
        let t2 = Token::from_parts(
            t.entries().cloned().collect(),
            t.watermarks().to_vec(),
            t.appended,
            t.rotations,
            t.epoch,
            t.epoch_assignment.clone(),
            t.obs.clone(),
        );
        assert_eq!(t2, t);
        assert!(t.wire_size() > Token::new(2).wire_size());
    }
}
