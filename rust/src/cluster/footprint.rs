//! Shard-footprint analysis for the data-partitioning baseline.
//!
//! MySQL Cluster partitions each table horizontally by a partition column
//! (we use the same scheme the paper used: "we extracted the resulting
//! data partitioning scheme [from Operation Partitioning] and applied it
//! to MySQL Cluster" — in practice the leading primary-key column, e.g.
//! customer and cart ids in TPC-W).
//!
//! For every statement of a template we derive how it touches shards:
//! * an equality on the partition column with an input parameter —
//!   a single shard decided by the argument at run time;
//! * an equality with a constant — a fixed shard;
//! * anything else on a read — a scatter to all shards;
//! * anything else on a write — one data-dependent shard (derived key).

use crate::catalog::Schema;
use crate::db::{Bindings, Value};
use crate::sqlir::{CmpOp, Pred, Scalar, Stmt};
use crate::util::Rng;
use crate::workload::analyzed::route_hash;
use crate::workload::spec::TxnTemplate;

/// How one statement hits the shards.
#[derive(Debug, Clone, PartialEq)]
pub enum StmtAccess {
    /// Single shard selected by an input parameter's value.
    Param { param: String, write: bool },
    /// Single fixed shard.
    Const { value: Value, write: bool },
    /// All shards (scatter-gather reads, broadcast writes).
    Broadcast { write: bool },
    /// One run-time-dependent shard (derived key write/read).
    Derived { write: bool },
}

impl StmtAccess {
    pub fn is_write(&self) -> bool {
        match self {
            StmtAccess::Param { write, .. }
            | StmtAccess::Const { value: _, write }
            | StmtAccess::Broadcast { write }
            | StmtAccess::Derived { write } => *write,
        }
    }
}

/// The shard footprint of a transaction template.
#[derive(Debug, Clone, Default)]
pub struct Footprint {
    pub accesses: Vec<StmtAccess>,
    pub read_only: bool,
}

/// Find an equality `partition_col = scalar` in the top-level conjunction.
fn find_partition_eq<'s>(pred: &'s Pred, partition_col: &str) -> Option<&'s Scalar> {
    match pred {
        Pred::Cmp { col, op: CmpOp::Eq, rhs } if col.eq_ignore_ascii_case(partition_col) => {
            Some(rhs)
        }
        Pred::And(ps) => ps.iter().find_map(|p| find_partition_eq(p, partition_col)),
        _ => None,
    }
}

fn classify_scalar(s: &Scalar, tpl: &TxnTemplate, write: bool) -> StmtAccess {
    match s {
        Scalar::Param(p) if tpl.params.iter().any(|ip| ip == p) => {
            StmtAccess::Param { param: p.clone(), write }
        }
        Scalar::Lit(l) => StmtAccess::Const { value: Value::from_literal(l), write },
        // Derived placeholder or arithmetic: key exists but is run-time
        // dependent.
        _ => StmtAccess::Derived { write },
    }
}

/// Compute the footprint of `tpl`. The partition column of each table is
/// its leading primary-key column.
pub fn footprint(tpl: &TxnTemplate, schema: &Schema) -> Footprint {
    let mut fp = Footprint { accesses: Vec::new(), read_only: tpl.is_read_only() };
    for (_, stmt) in &tpl.stmts {
        let table = schema.table_by_name(stmt.table()).expect("known table");
        let pcol = table.primary_key.first().cloned().unwrap_or_default();
        let access = match stmt {
            Stmt::Select(s) => match find_partition_eq(&s.where_, &pcol) {
                Some(scalar) => classify_scalar(scalar, tpl, false),
                None => StmtAccess::Broadcast { write: false },
            },
            Stmt::Update(u) => match find_partition_eq(&u.where_, &pcol) {
                Some(scalar) => classify_scalar(scalar, tpl, true),
                None => StmtAccess::Derived { write: true },
            },
            Stmt::Delete(d) => match find_partition_eq(&d.where_, &pcol) {
                Some(scalar) => classify_scalar(scalar, tpl, true),
                None => StmtAccess::Derived { write: true },
            },
            Stmt::Insert(ins) => {
                let scalar = ins
                    .columns
                    .iter()
                    .zip(&ins.values)
                    .find(|(c, _)| c.eq_ignore_ascii_case(&pcol))
                    .map(|(_, v)| v);
                match scalar {
                    Some(s) => classify_scalar(s, tpl, true),
                    None => StmtAccess::Derived { write: true },
                }
            }
        };
        fp.accesses.push(access);
    }
    fp
}

/// The concrete shard/lock demand of one operation instance.
#[derive(Debug, Clone)]
pub struct ShardDemand {
    /// Distinct shards touched.
    pub shards: Vec<usize>,
    /// Lock keys (shard, key-hash) for write accesses.
    pub write_keys: Vec<(usize, u64)>,
    pub read_only: bool,
    /// True when any access scattered to all shards.
    pub scatter: bool,
}

impl ShardDemand {
    /// The write-key hashes owned by `shard`. Every reservation belongs
    /// to exactly one data shard, which is what lets the cluster
    /// simulator shard its virtual lock table by server group: the
    /// coordinator reserves `keys_on(coordinator)` locally and ships
    /// `keys_on(participant)` inside the prepare/commit messages.
    pub fn keys_on(&self, shard: usize) -> Vec<u64> {
        self.write_keys.iter().filter(|(s, _)| *s == shard).map(|&(_, k)| k).collect()
    }

    /// The shards other than `home` this operation touches, in demand
    /// order (the 2PC participant set when `home` coordinates).
    pub fn remotes(&self, home: usize) -> Vec<usize> {
        self.shards.iter().copied().filter(|&s| s != home).collect()
    }
}

impl Footprint {
    /// Instantiate the footprint for a concrete operation.
    pub fn demand(
        &self,
        args: &Bindings,
        n_shards: usize,
        rng: &mut Rng,
    ) -> ShardDemand {
        let mut shards = Vec::new();
        let mut write_keys = Vec::new();
        let mut scatter = false;
        let push = |s: usize, shards: &mut Vec<usize>| {
            if !shards.contains(&s) {
                shards.push(s);
            }
        };
        for a in &self.accesses {
            match a {
                StmtAccess::Param { param, write } => {
                    if let Some(v) = args.get(param) {
                        let h = route_hash(v);
                        let s = (h % n_shards as u64) as usize;
                        push(s, &mut shards);
                        if *write {
                            write_keys.push((s, h));
                        }
                    }
                }
                StmtAccess::Const { value, write } => {
                    let h = route_hash(value);
                    let s = (h % n_shards as u64) as usize;
                    push(s, &mut shards);
                    if *write {
                        write_keys.push((s, h));
                    }
                }
                StmtAccess::Broadcast { write } => {
                    scatter = true;
                    for s in 0..n_shards {
                        push(s, &mut shards);
                        if *write {
                            // Broadcast writes take a coarse per-shard lock.
                            write_keys.push((s, u64::MAX));
                        }
                    }
                }
                StmtAccess::Derived { write } => {
                    // Derived keys follow a Zipf-popular domain (e.g. the
                    // items a buyConfirm touches): hot rows are what make
                    // distributed 2PC transactions queue behind each
                    // other's multi-RTT lock holds — the paper's central
                    // contention argument. Eliá's token execution is
                    // immune (global ops serialize without row locks).
                    let id = rng.zipf(1000, 0.9) as u64;
                    let h = id.wrapping_mul(0x9E3779B97F4A7C15) ^ id;
                    let s = (h % n_shards as u64) as usize;
                    push(s, &mut shards);
                    if *write {
                        write_keys.push((s, h));
                    }
                }
            }
        }
        if shards.is_empty() {
            shards.push(rng.range(0, n_shards));
        }
        ShardDemand { shards, write_keys, read_only: self.read_only, scatter }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{TableSchema, ValueType};

    fn schema() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ])
    }

    fn binds(cid: i64) -> Bindings {
        [("cid".to_string(), Value::Int(cid))].into_iter().collect()
    }

    #[test]
    fn param_access_single_shard() {
        let tpl = TxnTemplate::new(
            "add",
            &["cid"],
            &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        assert_eq!(fp.accesses, vec![StmtAccess::Param { param: "cid".into(), write: true }]);
        let mut rng = Rng::new(1);
        let d = fp.demand(&binds(7), 4, &mut rng);
        assert_eq!(d.shards.len(), 1);
        assert_eq!(d.write_keys.len(), 1);
        assert!(!d.read_only);
    }

    #[test]
    fn scan_read_scatters() {
        let tpl = TxnTemplate::new(
            "browse",
            &[],
            &[("q", "SELECT LEVEL FROM STOCK WHERE LEVEL > 0")],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        assert_eq!(fp.accesses, vec![StmtAccess::Broadcast { write: false }]);
        let mut rng = Rng::new(1);
        let d = fp.demand(&Bindings::new(), 5, &mut rng);
        assert_eq!(d.shards.len(), 5);
        assert!(d.read_only && d.scatter);
        assert!(d.write_keys.is_empty());
    }

    #[test]
    fn derived_write_hits_one_random_shard() {
        let tpl = TxnTemplate::new(
            "order",
            &["cid"],
            &[
                ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived"),
            ],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        assert!(matches!(fp.accesses[1], StmtAccess::Derived { write: true }));
        let mut rng = Rng::new(3);
        // Union of cart shard + derived shard: 1 or 2 shards.
        let d = fp.demand(&binds(3), 8, &mut rng);
        assert!(!d.shards.is_empty() && d.shards.len() <= 2);
        assert_eq!(d.write_keys.len(), 1);
    }

    #[test]
    fn multi_shard_probability_grows_with_n() {
        // The core scaling phenomenon: with more shards, a two-key op is
        // more likely distributed.
        let tpl = TxnTemplate::new(
            "transfer",
            &["a", "b"],
            &[
                ("u1", "UPDATE CARTS SET QTY = 0 WHERE CID = ?a"),
                ("u2", "UPDATE CARTS SET QTY = 0 WHERE CID = ?b"),
            ],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        let mut rng = Rng::new(9);
        let frac = |n: usize, rng: &mut Rng| {
            let mut multi = 0;
            for i in 0..2000 {
                let args: Bindings = [
                    ("a".to_string(), Value::Int(i)),
                    ("b".to_string(), Value::Int(rng.range(0, 10_000) as i64)),
                ]
                .into_iter()
                .collect();
                if fp.demand(&args, n, rng).shards.len() > 1 {
                    multi += 1;
                }
            }
            multi as f64 / 2000.0
        };
        let f2 = frac(2, &mut rng);
        let f8 = frac(8, &mut rng);
        assert!(f8 > f2, "multi-shard fraction must grow: f2={f2} f8={f8}");
        assert!((f2 - 0.5).abs() < 0.1);
        assert!((f8 - 0.875).abs() < 0.05);
    }

    #[test]
    fn keys_partition_by_owning_shard() {
        // Two-key write: the per-shard views partition the write-key
        // set, and each reservation belongs to exactly one shard.
        let tpl = TxnTemplate::new(
            "transfer",
            &["a", "b"],
            &[
                ("u1", "UPDATE CARTS SET QTY = 0 WHERE CID = ?a"),
                ("u2", "UPDATE CARTS SET QTY = 0 WHERE CID = ?b"),
            ],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        let mut rng = Rng::new(4);
        let args: Bindings = [
            ("a".to_string(), Value::Int(1)),
            ("b".to_string(), Value::Int(2)),
        ]
        .into_iter()
        .collect();
        let d = fp.demand(&args, 3, &mut rng);
        assert_eq!(d.write_keys.len(), 2);
        let mut total = 0;
        for s in 0..3 {
            let keys = d.keys_on(s);
            total += keys.len();
            for k in &keys {
                assert!(d.write_keys.contains(&(s, *k)));
            }
        }
        assert_eq!(total, d.write_keys.len());
        assert_eq!(d.keys_on(99), Vec::<u64>::new());
        // Participant set = touched shards minus the coordinator.
        for home in 0..3 {
            let r = d.remotes(home);
            assert!(!r.contains(&home));
            assert_eq!(
                r.len(),
                d.shards.iter().filter(|&&s| s != home).count()
            );
        }
    }

    #[test]
    fn const_key_is_fixed_shard() {
        let tpl = TxnTemplate::new(
            "touch",
            &[],
            &[("u", "UPDATE STOCK SET LEVEL = 0 WHERE ITEM = 5")],
            1.0,
        );
        let fp = footprint(&tpl, &schema());
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let d1 = fp.demand(&Bindings::new(), 6, &mut r1);
        let d2 = fp.demand(&Bindings::new(), 6, &mut r2);
        assert_eq!(d1.shards, d2.shards, "const shard must not depend on rng");
    }
}
