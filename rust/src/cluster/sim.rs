//! Simulation of the data-partitioning baseline: a MySQL-Cluster-like
//! system with horizontal partitioning, distributed row locks and
//! two-phase commit, at read-committed isolation (paper §7.1).
//!
//! Model per operation:
//! * the client talks to the nearest server, which acts as coordinator;
//! * the shards touched come from the template's [`Footprint`];
//! * writes take virtual row locks on their partition keys that are held
//!   for the whole transaction, including the 2PC rounds — the paper's
//!   "necessary coordination with remote machines prevents the progress
//!   of concurrent conflicting transactions";
//! * multi-shard reads scatter-gather (one round), multi-shard writes run
//!   2PC (prepare round + commit round);
//! * every remote interaction costs CPU on both ends, so coordination
//!   eats aggregate capacity as the distributed fraction grows with N —
//!   the mechanism behind MySQL Cluster's peak at ~4 servers.

use crate::simnet::clients::{ClientPool, ClientsConfig};
use crate::simnet::events::EventQueue;
use crate::simnet::latency::Topology;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::{OpGenerator, ServiceModel};

use std::collections::HashMap;

use super::footprint::{footprint, Footprint, ShardDemand};

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub service: ServiceModel,
    /// Fraction of the full service time a remote shard spends on its
    /// share of a distributed transaction.
    pub remote_exec_frac: f64,
    /// CPU cost of handling one coordination message.
    pub msg_cpu_ms: f64,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            // Same thread-pool sizing as the Eliá servers (fair baseline).
            workers: 8,
            service: ServiceModel::default(),
            // A 2PC participant re-executes its share of the transaction
            // (prepare) and applies the decision; coordination messages
            // cost CPU on both ends.
            remote_exec_frac: 0.8,
            msg_cpu_ms: 0.8,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0xC1B5,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Job {
    Coord(u64),
    Remote { op: u64, shard: usize },
    /// Fire-and-forget commit application at a participant.
    CommitApply,
}

#[derive(Debug, Clone)]
enum Ev {
    Issue { client: usize },
    Arrive { op: u64 },
    LockStart { op: u64 },
    JobDone { server: usize, job: Job },
    /// Prepare/read request lands at a participant shard.
    PrepareArrive { op: u64, shard: usize },
    VoteArrive { op: u64 },
    /// Commit decision lands at a participant shard.
    CommitArrive { shard: usize },
    Complete { op: u64 },
    Reply { op: u64 },
}

struct OpState {
    client: usize,
    issued: VTime,
    coordinator: usize,
    demand: ShardDemand,
    votes_pending: usize,
    service: VTime,
    distributed: bool,
}

pub struct ClusterSim<'a> {
    app: &'a AnalyzedApp,
    topo: Topology,
    cfg: ClusterConfig,
    gen: Box<dyn OpGenerator + 'a>,
    clients: ClientPool,
    stations: Vec<Station<Job>>,
    footprints: Vec<Footprint>,
    ops: Vec<OpState>,
    /// Virtual row-lock table: key -> earliest next acquisition time.
    locks: HashMap<(usize, u64), VTime>,
    /// Per-server RNG streams (demand + service sampling at the
    /// coordinator), derived statelessly from the seed so server count
    /// and event interleaving cannot perturb another server's stream.
    rngs: Vec<Rng>,
    pub metrics: SimMetrics,
    q: EventQueue<Ev>,
    lock_waits: u64,
}

impl<'a> ClusterSim<'a> {
    pub fn new(
        app: &'a AnalyzedApp,
        topo: Topology,
        clients_cfg: ClientsConfig,
        cfg: ClusterConfig,
        gen: Box<dyn OpGenerator + 'a>,
    ) -> Self {
        let n = topo.n();
        let clients = ClientPool::new(ClientsConfig { sites: n, ..clients_cfg });
        let stations = (0..n).map(|_| Station::new(cfg.workers)).collect();
        let footprints =
            app.spec.txns.iter().map(|t| footprint(t, &app.spec.schema)).collect();
        let metrics = SimMetrics::new(cfg.warmup, cfg.horizon);
        let rngs = (0..n).map(|i| Rng::stream(cfg.seed, i as u64)).collect();
        ClusterSim {
            app,
            topo,
            cfg,
            gen,
            clients,
            stations,
            footprints,
            ops: Vec::new(),
            locks: HashMap::new(),
            rngs,
            metrics,
            q: EventQueue::new(),
            lock_waits: 0,
        }
    }

    pub fn run(mut self) -> ClusterReport {
        for c in 0..self.clients.n() {
            let jitter = VTime::from_micros((c as u64 % 97) * 13);
            self.q.schedule(jitter, Ev::Issue { client: c });
        }
        while let Some(t) = self.q.peek_time() {
            if t > self.cfg.horizon {
                break;
            }
            let (_, ev) = self.q.pop().unwrap();
            self.handle(ev);
        }
        let now = self.cfg.horizon;
        ClusterReport {
            metrics: self.metrics.clone(),
            utilization: self.stations.iter().map(|s| s.utilization(now)).collect(),
            lock_waits: self.lock_waits,
            events: self.q.processed(),
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Issue { client } => self.on_issue(client),
            Ev::Arrive { op } => self.on_arrive(op),
            Ev::LockStart { op } => self.on_lock_start(op),
            Ev::JobDone { server, job } => self.on_job_done(server, job),
            Ev::PrepareArrive { op, shard } => self.on_prepare(op, shard),
            Ev::VoteArrive { op } => self.on_vote(op),
            Ev::CommitArrive { shard } => {
                let apply = VTime::from_millis_f64(self.cfg.msg_cpu_ms);
                self.submit(shard, Job::CommitApply, apply, false);
            }
            Ev::Complete { op } => self.on_complete(op),
            Ev::Reply { op } => self.on_reply(op),
        }
    }

    fn submit(&mut self, server: usize, job: Job, service: VTime, priority: bool) {
        let now = self.q.now();
        if let Some(j) = self.stations[server].submit(now, job, service, priority) {
            self.q.schedule(j.service, Ev::JobDone { server, job: j.payload });
        }
    }

    fn on_issue(&mut self, client: usize) {
        let n = self.topo.n();
        let site = self.clients.site(client);
        let op = {
            let mut r = self.clients.rng(client).fork();
            self.gen.next_op(&mut r, site, n)
        };
        let coordinator = site % n;
        let demand = self.footprints[op.txn].demand(&op.args, n, &mut self.rngs[coordinator]);
        let service =
            self.cfg.service.sample(&self.app.spec.txns[op.txn], &mut self.rngs[coordinator]);
        let distributed = demand.shards.iter().any(|&s| s != coordinator);
        let op_id = self.ops.len() as u64;
        self.ops.push(OpState {
            client,
            issued: self.q.now(),
            coordinator,
            demand,
            votes_pending: 0,
            service,
            distributed,
        });
        let delay = self.topo.servers.one_way(site, coordinator);
        self.q.schedule(delay, Ev::Arrive { op: op_id });
    }

    /// Estimated lock hold: local execution plus the coordination rounds.
    fn estimate_hold(&self, op: &OpState) -> VTime {
        let mut hold = op.service;
        let remotes: Vec<usize> = op
            .demand
            .shards
            .iter()
            .copied()
            .filter(|&s| s != op.coordinator)
            .collect();
        if !remotes.is_empty() {
            let max_rtt = remotes
                .iter()
                .map(|&s| self.topo.servers.rtt(op.coordinator, s))
                .max()
                .unwrap();
            let rounds = if op.demand.read_only { 1 } else { 2 };
            hold += VTime::from_micros(max_rtt.as_micros() * rounds);
        }
        hold
    }

    fn on_arrive(&mut self, op_id: u64) {
        let now = self.q.now();
        // Read-committed: read-only transactions take no locks.
        let (start, hold) = {
            let op = &self.ops[op_id as usize];
            if op.demand.write_keys.is_empty() {
                (now, VTime::ZERO)
            } else {
                let hold = self.estimate_hold(op);
                let mut start = now;
                for key in &op.demand.write_keys {
                    if let Some(&avail) = self.locks.get(key) {
                        if avail > start {
                            start = avail;
                        }
                    }
                }
                (start, hold)
            }
        };
        if start > now {
            self.lock_waits += 1;
        }
        // Reserve the locks until the estimated release.
        let keys: Vec<(usize, u64)> = self.ops[op_id as usize].demand.write_keys.clone();
        for key in keys {
            self.locks.insert(key, start + hold);
        }
        self.q.schedule_at(start, Ev::LockStart { op: op_id });
    }

    fn on_lock_start(&mut self, op_id: u64) {
        let (coordinator, service, n_remotes) = {
            let op = &self.ops[op_id as usize];
            let n_remotes =
                op.demand.shards.iter().filter(|&&s| s != op.coordinator).count();
            (op.coordinator, op.service, n_remotes)
        };
        // Coordinator executes its share plus per-remote message handling.
        let coord_service =
            service + VTime::from_millis_f64(self.cfg.msg_cpu_ms * n_remotes as f64);
        self.submit(coordinator, Job::Coord(op_id), coord_service, false);
    }

    fn on_job_done(&mut self, server: usize, job: Job) {
        let now = self.q.now();
        if let Some(next) = self.stations[server].complete(now) {
            self.q.schedule(next.service, Ev::JobDone { server, job: next.payload });
        }
        match job {
            Job::Coord(op_id) => {
                let remotes: Vec<usize> = {
                    let op = &self.ops[op_id as usize];
                    op.demand
                        .shards
                        .iter()
                        .copied()
                        .filter(|&s| s != op.coordinator)
                        .collect()
                };
                if remotes.is_empty() {
                    self.q.schedule(VTime::ZERO, Ev::Complete { op: op_id });
                    return;
                }
                self.ops[op_id as usize].votes_pending = remotes.len();
                let coordinator = self.ops[op_id as usize].coordinator;
                for shard in remotes {
                    let d = self.topo.servers.one_way(coordinator, shard);
                    self.q.schedule(d, Ev::PrepareArrive { op: op_id, shard });
                }
            }
            Job::Remote { op: op_id, shard } => {
                // Remote share done: vote travels back.
                let coordinator = self.ops[op_id as usize].coordinator;
                let d = self.topo.servers.one_way(shard, coordinator);
                self.q.schedule(d, Ev::VoteArrive { op: op_id });
            }
            Job::CommitApply => {}
        }
    }

    /// Prepare/read request landed at a participant: charge its CPU share.
    fn on_prepare(&mut self, op_id: u64, shard: usize) {
        let service = self.ops[op_id as usize].service;
        let remote_service = VTime::from_millis_f64(
            service.as_millis_f64() * self.cfg.remote_exec_frac + self.cfg.msg_cpu_ms,
        );
        self.submit(shard, Job::Remote { op: op_id, shard }, remote_service, false);
    }

    fn on_vote(&mut self, op_id: u64) {
        let done = {
            let op = &mut self.ops[op_id as usize];
            op.votes_pending -= 1;
            op.votes_pending == 0
        };
        if !done {
            return;
        }
        let (read_only, coordinator, remotes): (bool, usize, Vec<usize>) = {
            let op = &self.ops[op_id as usize];
            (
                op.demand.read_only,
                op.coordinator,
                op.demand.shards.iter().copied().filter(|&s| s != op.coordinator).collect(),
            )
        };
        if read_only {
            // Scatter-gather read: done once all results are in.
            self.q.schedule(VTime::ZERO, Ev::Complete { op: op_id });
        } else {
            // 2PC commit round: decision to all participants + acks; the
            // commit application costs CPU at each participant.
            let mut max_rtt = VTime::ZERO;
            for &shard in &remotes {
                let one = self.topo.servers.one_way(coordinator, shard);
                if one + one > max_rtt {
                    max_rtt = one + one;
                }
                self.q.schedule(one, Ev::CommitArrive { shard });
            }
            self.q.schedule(max_rtt, Ev::Complete { op: op_id });
        }
    }

    fn on_complete(&mut self, op_id: u64) {
        let (client, coordinator) = {
            let op = &self.ops[op_id as usize];
            (op.client, op.coordinator)
        };
        let site = self.clients.site(client);
        let delay = self.topo.servers.one_way(coordinator, site);
        self.q.schedule(delay, Ev::Reply { op: op_id });
    }

    fn on_reply(&mut self, op_id: u64) {
        let (client, issued, distributed) = {
            let op = &self.ops[op_id as usize];
            (op.client, op.issued, op.distributed)
        };
        self.metrics.complete(issued, self.q.now(), distributed);
        let think = self.clients.think(client);
        self.q.schedule(think, Ev::Issue { client });
    }
}

#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub metrics: SimMetrics,
    pub utilization: Vec<f64>,
    pub lock_waits: u64,
    pub events: u64,
}

impl ClusterReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.metrics.latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "add",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            ),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived"),
                ],
                1.0,
            ),
            TxnTemplate::new(
                "view",
                &["cid"],
                &[("q", "SELECT QTY FROM CARTS WHERE CID = ?cid")],
                1.0,
            ),
        ];
        AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns })
    }

    struct Gen {
        write_ratio: f64,
    }

    impl OpGenerator for Gen {
        fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
            let cid = rng.range(0, 5000) as i64;
            let args: Bindings = [("cid".to_string(), Value::Int(cid))].into_iter().collect();
            if rng.chance(self.write_ratio) {
                if rng.chance(0.5) {
                    Operation { txn: 0, args }
                } else {
                    Operation { txn: 1, args }
                }
            } else {
                Operation { txn: 2, args }
            }
        }
    }

    fn run(n: usize, clients: usize, write_ratio: f64) -> ClusterReport {
        let app = app();
        let cfg = ClusterConfig {
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        ClusterSim::new(
            &app,
            Topology::lan(n),
            ClientsConfig { n: clients, think_ms: 10.0, seed: 11, ..Default::default() },
            cfg,
            Box::new(Gen { write_ratio }),
        )
        .run()
    }

    #[test]
    fn single_server_is_all_local() {
        let r = run(1, 20, 0.5);
        assert!(r.metrics.completed > 500);
        // No remote coordination on one server.
        assert_eq!(r.metrics.global_latency.count(), 0);
    }

    #[test]
    fn distributed_fraction_appears_with_shards() {
        let r = run(4, 20, 0.5);
        let dist = r.metrics.global_latency.count() as f64;
        let local = r.metrics.local_latency.count() as f64;
        // With 4 shards most point ops are remote (3/4 expected).
        assert!(dist / (dist + local) > 0.5, "dist={dist} local={local}");
        // Distributed ops must be slower (they pay RTTs).
        assert!(r.metrics.global_latency.mean() > r.metrics.local_latency.mean() + 5.0);
    }

    #[test]
    fn write_heavy_suffers_more_than_read_heavy() {
        let wr = run(6, 40, 0.8);
        let rd = run(6, 40, 0.1);
        // Read-heavy completes more with the same offered load (reads take
        // no locks and only one round).
        assert!(
            rd.metrics.latency.mean() < wr.metrics.latency.mean(),
            "read mean {} vs write mean {}",
            rd.metrics.latency.mean(),
            wr.metrics.latency.mean()
        );
    }

    #[test]
    fn hot_key_contention_serializes() {
        // All writes to one cart: lock queueing must show up.
        struct HotGen;
        impl OpGenerator for HotGen {
            fn next_op(&mut self, _rng: &mut Rng, _site: usize, _n: usize) -> Operation {
                let args: Bindings =
                    [("cid".to_string(), Value::Int(7))].into_iter().collect();
                Operation { txn: 0, args }
            }
        }
        let app = app();
        let cfg = ClusterConfig {
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        let r = ClusterSim::new(
            &app,
            Topology::lan(3),
            ClientsConfig { n: 30, think_ms: 0.0, seed: 5, ..Default::default() },
            cfg,
            Box::new(HotGen),
        )
        .run();
        assert!(r.lock_waits > 100, "lock_waits={}", r.lock_waits);
    }

    #[test]
    fn deterministic() {
        let a = run(4, 25, 0.3);
        let b = run(4, 25, 0.3);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
    }

    /// Satellite guard: the documented defaults the benches assume
    /// (`ClusterConfig::default()` inside `harness::experiments`). A
    /// silent retuning would skew every recorded Fig-3 baseline curve.
    #[test]
    fn documented_defaults_match_bench_assumptions() {
        let c = ClusterConfig::default();
        assert_eq!(c.workers, 8, "fair-baseline thread pool (same as Eliá servers)");
        assert!((c.remote_exec_frac - 0.8).abs() < 1e-12);
        assert!((c.msg_cpu_ms - 0.8).abs() < 1e-12);
        assert_eq!(c.warmup, VTime::from_secs(5));
        assert_eq!(c.horizon, VTime::from_secs(25));
        assert_eq!(c.seed, 0xC1B5);
    }
}
