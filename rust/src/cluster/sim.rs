//! Simulation of the data-partitioning baseline: a MySQL-Cluster-like
//! system with horizontal partitioning, distributed row locks and
//! two-phase commit, at read-committed isolation (paper §7.1).
//!
//! Model per operation:
//! * the client talks to the nearest server, which acts as coordinator;
//! * the shards touched come from the template's [`Footprint`];
//! * writes take virtual row locks on their partition keys that are held
//!   for the whole transaction, including the 2PC rounds — the paper's
//!   "necessary coordination with remote machines prevents the progress
//!   of concurrent conflicting transactions";
//! * multi-shard reads scatter-gather (one round), multi-shard writes run
//!   2PC (prepare round + commit round + acks);
//! * every remote interaction costs CPU on both ends — prepares and
//!   votes at the participants, one `msg_cpu_ms` per participant ack at
//!   the coordinator — so coordination eats aggregate capacity as the
//!   distributed fraction grows with N: the mechanism behind MySQL
//!   Cluster's peak at ~4 servers.
//!
//! # Sharded virtual lock table + window engine
//!
//! The virtual row-lock table is *sharded by data shard*: every
//! reservation `(table, key-hash)` from [`ShardDemand`] belongs to
//! exactly one partition, so each server group owns the reservations for
//! its own shard (the private `LockShard`). Acquisition is an explicit event
//! at the owning shard — the coordinator reserves its local keys when
//! the operation arrives, participants reserve theirs when the 2PC
//! prepare reaches them — and every reservation is *released* (and its
//! entry evicted) when the transaction completes. The old engine kept
//! one global `HashMap` that only ever inserted, leaking an entry per
//! distinct key forever; eviction-on-release falls out of the sharded
//! design and is pinned by `lock_table_is_bounded_on_sustained_hot_key_run`.
//!
//! With the lock table sharded, the simulation runs on the conservative
//! window engine ([`crate::simnet::parallel::run_windows`], same as
//! `ConveyorSim`): one group per server (station, lock shard, RNG
//! stream, coordinated-op table) plus K client groups, advancing in
//! lookahead windows with the canonical cross-group merge — results are
//! bit-identical at any thread count ([`ClusterConfig::parallel`]) and
//! any client-group count ([`ClientsConfig::groups`]).

use crate::simnet::clients::{
    ClientEv, ClientGroups, ClientTier, ClientsConfig, IssueReply, IssueRouter,
};
use crate::simnet::crash::{CrashConfig, CrashOutcome};
use crate::simnet::latency::Topology;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::parallel::{self, client_group_target, GroupCore, WindowGroup};
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::{OpGenerator, ServiceModel};

use std::collections::HashMap;

use super::footprint::{footprint, Footprint, ShardDemand};

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub service: ServiceModel,
    /// Fraction of the full service time a remote shard spends on its
    /// share of a distributed transaction.
    pub remote_exec_frac: f64,
    /// CPU cost of handling one coordination message.
    pub msg_cpu_ms: f64,
    /// Worker threads for the window-parallel engine: `1` sequential
    /// (default), `0` all cores, `N` at most N threads. Results are
    /// bit-identical for every value.
    pub parallel: usize,
    /// Kill one server mid-run (freeze-then-replay, see
    /// [`crate::simnet::crash`]). Unlike the conveyor — where the token
    /// stalls and everything waits — a crashed 2PC participant leaves
    /// coordinators hanging in their prepare rounds, holding row locks.
    pub crash: Option<CrashConfig>,
    /// Coordinator-side timeout on the 2PC prepare round, in ms. When a
    /// round is still missing votes this long after the prepare fan-out,
    /// the coordinator aborts: it releases its local keys, tells every
    /// participant to release theirs, and answers the client (aborted
    /// operations complete the closed loop but are counted in
    /// [`ClusterReport::aborts`]). `None` (default) = wait forever.
    pub txn_timeout_ms: Option<f64>,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            // Same thread-pool sizing as the Eliá servers (fair baseline).
            workers: 8,
            service: ServiceModel::default(),
            // A 2PC participant re-executes its share of the transaction
            // (prepare) and applies the decision; coordination messages
            // cost CPU on both ends.
            remote_exec_frac: 0.8,
            msg_cpu_ms: 0.8,
            parallel: 1,
            crash: None,
            txn_timeout_ms: None,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0xC1B5,
        }
    }
}

#[derive(Debug, Clone)]
enum Job {
    /// Coordinator's own execution share (plus per-remote message CPU).
    Coord(u64),
    /// A participant's prepare/read share of `op` coordinated elsewhere;
    /// `stamp` rides along so the vote can identify the op incarnation.
    Remote { coord: usize, op: u64, stamp: u64 },
    /// Commit application at a participant; releases `keys` on this
    /// shard when done, then acks the coordinator.
    CommitApply { coord: usize, op: u64, stamp: u64, keys: Vec<u64> },
    /// Coordinator-side handling of one participant ack (the commit
    /// round costs CPU on *both* ends, like the prepare round).
    Ack { op: u64, stamp: u64 },
}

#[derive(Debug, Clone)]
enum Ev {
    /// Client (after thinking) issues its next operation. [client tier]
    Issue { client: usize },
    /// Reply reaches the client. [client tier]
    Reply { client: usize, issued: VTime, distributed: bool },
    /// Request arrives at its coordinator. [server]
    Arrive { op: OpEnvelope },
    /// Coordinator-local lock reservations granted; execution starts.
    /// [server]
    LockStart { op: u64 },
    /// A station job completed. [server]
    JobDone { job: Job },
    /// Prepare/read request lands at a participant shard, carrying the
    /// write keys that shard owns. [server]
    PrepareArrive { coord: usize, op: u64, stamp: u64, service: VTime, keys: Vec<u64> },
    /// Participant lock reservations granted; its share executes.
    /// [server]
    RemoteStart { coord: usize, op: u64, stamp: u64, service: VTime },
    /// A participant's prepare vote reaches the coordinator; dropped if
    /// `stamp` no longer matches (the op timed out and its slot was
    /// recycled). [server]
    VoteArrive { op: u64, stamp: u64 },
    /// Commit decision lands at a participant shard. [server]
    CommitArrive { coord: usize, op: u64, stamp: u64, keys: Vec<u64> },
    /// A participant's commit ack reaches the coordinator. [server]
    AckArrive { op: u64, stamp: u64 },
    /// All rounds done: the transaction completes at the coordinator.
    /// [server]
    Complete { op: u64 },
    /// The prepare round is still missing votes `txn_timeout_ms` after
    /// fan-out: abort. Self-scheduled, stamped against recycling. [server]
    Deadline { op: u64, stamp: u64 },
    /// An aborting coordinator tells a participant to release the write
    /// keys it reserved for the aborted transaction. [server]
    AbortArrive { keys: Vec<u64> },
    /// This server crashes now (scheduled at boot from
    /// [`ClusterConfig::crash`]). [server]
    Crash,
    /// Restart + WAL replay finished; drain the held backlog. [server]
    Recover,
}

/// An operation travelling from the client tier to its coordinator; the
/// coordinator derives demand and service time with its own RNG stream.
#[derive(Debug, Clone)]
struct OpEnvelope {
    txn: usize,
    args: crate::db::Bindings,
    client: usize,
    client_site: usize,
    issued: VTime,
}

/// Coordinator-side state of one operation (owned by the coordinating
/// server group; other groups see only self-contained messages).
struct OpState {
    client: usize,
    client_site: usize,
    issued: VTime,
    demand: ShardDemand,
    /// The coordinator's own write keys (`demand.keys_on(coordinator)`),
    /// computed once at arrival: acquired before execution starts,
    /// released at `Complete`.
    local_keys: Vec<u64>,
    service: VTime,
    votes_pending: usize,
    acks_pending: usize,
    distributed: bool,
    /// Incarnation stamp of this op slot (slots are recycled; stale
    /// votes/acks for a previous occupant are dropped by mismatch).
    stamp: u64,
    /// Completed or aborted: no further message may act on this slot.
    done: bool,
}

/// One server's shard of the virtual row-lock table: only keys whose
/// data shard is this server ever appear here.
///
/// A reservation models a queued-then-held row lock by its *estimated*
/// hold window: acquiring keys returns the grant time (after every
/// earlier reservation's window) and extends each key's `avail`
/// horizon; releasing decrements the key's live-reservation count and
/// evicts the entry when it reaches zero. The table therefore holds
/// only keys with in-flight transactions — bounded by concurrency, not
/// by the number of distinct keys ever touched.
#[derive(Debug, Default)]
struct LockShard {
    slots: HashMap<u64, LockSlot>,
    /// High-water mark of live entries (leak regression diagnostics).
    peak: usize,
}

#[derive(Debug, Clone, Copy)]
struct LockSlot {
    /// When the last queued reservation's estimated hold ends.
    avail: VTime,
    /// Live reservations (granted or queued) on this key.
    queued: u32,
}

impl LockShard {
    /// Reserve `keys` for one transaction starting no earlier than
    /// `now`; returns the grant time (`> now` means it queued).
    fn acquire(&mut self, now: VTime, keys: &[u64], hold: VTime) -> VTime {
        let mut grant = now;
        for k in keys {
            if let Some(slot) = self.slots.get(k) {
                grant = grant.max(slot.avail);
            }
        }
        for &k in keys {
            let slot =
                self.slots.entry(k).or_insert(LockSlot { avail: VTime::ZERO, queued: 0 });
            slot.avail = slot.avail.max(grant + hold);
            slot.queued += 1;
        }
        self.peak = self.peak.max(self.slots.len());
        grant
    }

    /// Release the reservations taken by one matching `acquire`; entries
    /// with no live reservations are evicted (the leak fix).
    fn release(&mut self, keys: &[u64]) {
        for k in keys {
            if let Some(slot) = self.slots.get_mut(k) {
                slot.queued = slot.queued.saturating_sub(1);
                if slot.queued == 0 {
                    self.slots.remove(k);
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.slots.len()
    }
}

/// Immutable context shared by every group during a window.
struct Shared<'s> {
    app: &'s AnalyzedApp,
    topo: &'s Topology,
    cfg: &'s ClusterConfig,
    footprints: &'s [Footprint],
    /// Number of client groups (for routing replies to the right one).
    client_groups: usize,
}

/// One server group: coordinator + 2PC participant + lock shard.
struct ServerGroup {
    id: usize,
    station: Station<Job>,
    /// This shard's slice of the virtual row-lock table.
    locks: LockShard,
    /// Operations this server coordinates (ids are group-local). Slots
    /// of completed operations are recycled through `free_ops`, so the
    /// table is bounded by in-flight concurrency — the same guarantee
    /// the lock shards give — instead of growing with every operation
    /// ever coordinated.
    ops: Vec<OpState>,
    /// Recycled op slots (no message can reference an op after its
    /// `Complete` fires, so reuse is safe).
    free_ops: Vec<u64>,
    /// Per-server RNG stream (demand + service sampling), derived
    /// statelessly from the seed so server count and event interleaving
    /// cannot perturb another server's stream.
    rng: Rng,
    lock_waits: u64,
    core: GroupCore<Ev>,
    /// Monotonic op-incarnation counter (stamps).
    op_stamps: u64,
    /// Prepare rounds this coordinator timed out and aborted.
    aborts: u64,
    /// Crashed and not yet recovered: every event freezes in `held`.
    down: bool,
    /// Events that arrived during the outage, in arrival order.
    held: Vec<Ev>,
    /// Durable redo records logged here (one per committed write at the
    /// coordinator, one per commit applied as a participant) — sizes
    /// the WAL replay charge at recovery.
    log_len: u64,
    crash: Option<CrashOutcome>,
}

impl<'s> WindowGroup<Shared<'s>> for ServerGroup {
    type Ev = Ev;

    fn core(&self) -> &GroupCore<Ev> {
        &self.core
    }

    fn core_mut(&mut self) -> &mut GroupCore<Ev> {
        &mut self.core
    }

    fn handle(&mut self, ev: Ev, ctx: &Shared<'s>) {
        if self.down {
            // Frozen: peers cannot observe the crash, so prepares,
            // commits and our own timers pile up until recovery.
            if matches!(ev, Ev::Recover) {
                self.on_recover(ctx);
            } else {
                self.held.push(ev);
            }
            return;
        }
        match ev {
            Ev::Arrive { op } => self.on_arrive(op, ctx),
            Ev::LockStart { op } => self.on_lock_start(op, ctx),
            Ev::JobDone { job } => self.on_job_done(job, ctx),
            Ev::PrepareArrive { coord, op, stamp, service, keys } => {
                self.on_prepare(coord, op, stamp, service, keys, ctx)
            }
            Ev::RemoteStart { coord, op, stamp, service } => {
                self.submit(Job::Remote { coord, op, stamp }, service, false)
            }
            Ev::CommitArrive { coord, op, stamp, keys } => {
                let apply = VTime::from_millis_f64(ctx.cfg.msg_cpu_ms);
                self.submit(Job::CommitApply { coord, op, stamp, keys }, apply, false);
            }
            Ev::AckArrive { op, stamp } => {
                let ack_cpu = VTime::from_millis_f64(ctx.cfg.msg_cpu_ms);
                self.submit(Job::Ack { op, stamp }, ack_cpu, false);
            }
            Ev::VoteArrive { op, stamp } => self.on_vote(op, stamp, ctx),
            Ev::Complete { op } => self.on_complete(op, ctx),
            Ev::Deadline { op, stamp } => self.on_deadline(op, stamp, ctx),
            Ev::AbortArrive { keys } => self.locks.release(&keys),
            Ev::Crash => self.on_crash(ctx),
            Ev::Recover => unreachable!("recovery while up"),
            Ev::Issue { .. } | Ev::Reply { .. } => {
                unreachable!("client-tier event delivered to a server")
            }
        }
    }
}

impl ServerGroup {
    fn submit(&mut self, job: Job, service: VTime, priority: bool) {
        let now = self.core.now();
        if let Some(j) = self.station.submit(now, job, service, priority) {
            self.core.q.schedule(j.service, Ev::JobDone { job: j.payload });
        }
    }

    /// Estimated lock hold at the coordinator: local execution plus the
    /// coordination rounds. An estimate shapes only the queueing of
    /// later reservations — the reservation itself is explicitly
    /// released (and evicted) at completion.
    fn estimate_hold(&self, op: &OpState, ctx: &Shared<'_>) -> VTime {
        let mut hold = op.service;
        let mut max_rtt = VTime::ZERO;
        for &s in &op.demand.shards {
            if s != self.id {
                max_rtt = max_rtt.max(ctx.topo.servers.rtt(self.id, s));
            }
        }
        if max_rtt > VTime::ZERO {
            let rounds = if op.demand.read_only { 1 } else { 2 };
            hold += VTime::from_micros(max_rtt.as_micros() * rounds);
        }
        hold
    }

    fn on_arrive(&mut self, env: OpEnvelope, ctx: &Shared<'_>) {
        let n = ctx.topo.n();
        let demand = ctx.footprints[env.txn].demand(&env.args, n, &mut self.rng);
        let service = ctx.cfg.service.sample(&ctx.app.spec.txns[env.txn], &mut self.rng);
        let distributed = demand.shards.iter().any(|&s| s != self.id);
        let local_keys = demand.keys_on(self.id);
        self.op_stamps += 1;
        let op = OpState {
            client: env.client,
            client_site: env.client_site,
            issued: env.issued,
            demand,
            local_keys,
            service,
            votes_pending: 0,
            acks_pending: 0,
            distributed,
            stamp: self.op_stamps,
            done: false,
        };
        // Read-committed: read-only transactions take no row locks.
        // Write transactions reserve their *coordinator-local* keys here;
        // keys owned by other shards are reserved where they live, when
        // the prepare round reaches them.
        let now = self.core.now();
        let start = if op.local_keys.is_empty() {
            now
        } else {
            let hold = self.estimate_hold(&op, ctx);
            let grant = self.locks.acquire(now, &op.local_keys, hold);
            if grant > now {
                self.lock_waits += 1;
            }
            grant
        };
        let op_id = match self.free_ops.pop() {
            Some(id) => {
                self.ops[id as usize] = op;
                id
            }
            None => {
                self.ops.push(op);
                self.ops.len() as u64 - 1
            }
        };
        self.core.q.schedule_at(start, Ev::LockStart { op: op_id });
    }

    fn on_lock_start(&mut self, op_id: u64, ctx: &Shared<'_>) {
        let (service, n_remotes) = {
            let op = &self.ops[op_id as usize];
            let n_remotes = op.demand.shards.iter().filter(|&&s| s != self.id).count();
            (op.service, n_remotes)
        };
        // Coordinator executes its share plus per-remote message handling.
        let coord_service =
            service + VTime::from_millis_f64(ctx.cfg.msg_cpu_ms * n_remotes as f64);
        self.submit(Job::Coord(op_id), coord_service, false);
    }

    fn on_job_done(&mut self, job: Job, ctx: &Shared<'_>) {
        let now = self.core.now();
        if let Some(next) = self.station.complete(now) {
            self.core.q.schedule(next.service, Ev::JobDone { job: next.payload });
        }
        match job {
            Job::Coord(op_id) => self.on_coord_done(op_id, ctx),
            Job::Remote { coord, op, stamp } => {
                // Remote share done: the vote travels back.
                let d = ctx.topo.servers.one_way(self.id, coord);
                self.core.send(coord, now + d, Ev::VoteArrive { op, stamp });
            }
            Job::CommitApply { coord, op, stamp, keys } => {
                // Commit applied: this shard's reservations end (entries
                // evict), the redo record is logged, and the ack travels
                // back to the coordinator.
                self.locks.release(&keys);
                self.log_len += 1;
                let d = ctx.topo.servers.one_way(self.id, coord);
                self.core.send(coord, now + d, Ev::AckArrive { op, stamp });
            }
            Job::Ack { op: op_id, stamp } => {
                if !self.op_live(op_id, stamp) {
                    return;
                }
                let done = {
                    let op = &mut self.ops[op_id as usize];
                    op.acks_pending -= 1;
                    op.acks_pending == 0
                };
                if done {
                    self.core.q.schedule(VTime::ZERO, Ev::Complete { op: op_id });
                }
            }
        }
    }

    /// A message references a live incarnation of an op slot iff the
    /// stamp matches and the op has neither completed nor aborted.
    fn op_live(&self, op_id: u64, stamp: u64) -> bool {
        let op = &self.ops[op_id as usize];
        op.stamp == stamp && !op.done
    }

    fn on_coord_done(&mut self, op_id: u64, ctx: &Shared<'_>) {
        let remotes = self.ops[op_id as usize].demand.remotes(self.id);
        if remotes.is_empty() {
            self.core.q.schedule(VTime::ZERO, Ev::Complete { op: op_id });
            return;
        }
        self.ops[op_id as usize].votes_pending = remotes.len();
        let service = self.ops[op_id as usize].service;
        let stamp = self.ops[op_id as usize].stamp;
        let now = self.core.now();
        for shard in remotes {
            let keys = self.ops[op_id as usize].demand.keys_on(shard);
            let d = ctx.topo.servers.one_way(self.id, shard);
            let ev = Ev::PrepareArrive { coord: self.id, op: op_id, stamp, service, keys };
            self.core.send(shard, now + d, ev);
        }
        // Arm the prepare-round timeout (the round a crashed participant
        // leaves hanging). The commit round needs no deadline: every
        // voted participant eventually applies the decision — at worst
        // after its recovery — so acks always arrive.
        if let Some(t) = ctx.cfg.txn_timeout_ms {
            self.core.q.schedule(VTime::from_millis_f64(t), Ev::Deadline { op: op_id, stamp });
        }
    }

    /// Prepare/read request landed at a participant: reserve this
    /// shard's keys (writes only — `keys` is empty for reads), then
    /// charge its CPU share once the reservations are granted.
    fn on_prepare(
        &mut self,
        coord: usize,
        op: u64,
        stamp: u64,
        service: VTime,
        keys: Vec<u64>,
        ctx: &Shared<'_>,
    ) {
        let remote_service = VTime::from_millis_f64(
            service.as_millis_f64() * ctx.cfg.remote_exec_frac + ctx.cfg.msg_cpu_ms,
        );
        let now = self.core.now();
        let start = if keys.is_empty() {
            now
        } else {
            // Held through the vote leg and the commit round back.
            let hold = remote_service + ctx.topo.servers.rtt(self.id, coord);
            let grant = self.locks.acquire(now, &keys, hold);
            if grant > now {
                self.lock_waits += 1;
            }
            grant
        };
        self.core.q.schedule_at(
            start,
            Ev::RemoteStart { coord, op, stamp, service: remote_service },
        );
    }

    fn on_vote(&mut self, op_id: u64, stamp: u64, ctx: &Shared<'_>) {
        if !self.op_live(op_id, stamp) {
            // The coordinator timed out and aborted this incarnation
            // while the vote was in flight (or in our station queue).
            return;
        }
        let done = {
            let op = &mut self.ops[op_id as usize];
            op.votes_pending -= 1;
            op.votes_pending == 0
        };
        if !done {
            return;
        }
        if self.ops[op_id as usize].demand.read_only {
            // Scatter-gather read: done once all results are in.
            self.core.q.schedule(VTime::ZERO, Ev::Complete { op: op_id });
            return;
        }
        // 2PC commit round: decision to every participant; each applies
        // it (releasing its reservations) and acks back, and the
        // coordinator pays CPU per ack — symmetric with the prepare path.
        let remotes = self.ops[op_id as usize].demand.remotes(self.id);
        self.ops[op_id as usize].acks_pending = remotes.len();
        let stamp = self.ops[op_id as usize].stamp;
        let now = self.core.now();
        for shard in remotes {
            let keys = self.ops[op_id as usize].demand.keys_on(shard);
            let d = ctx.topo.servers.one_way(self.id, shard);
            let ev = Ev::CommitArrive { coord: self.id, op: op_id, stamp, keys };
            self.core.send(shard, now + d, ev);
        }
    }

    fn on_complete(&mut self, op_id: u64, ctx: &Shared<'_>) {
        // The transaction is over: the coordinator's own reservations
        // end (strict 2PL release; entries evict when idle).
        self.locks.release(&self.ops[op_id as usize].local_keys);
        if !self.ops[op_id as usize].demand.read_only {
            // One redo record for the coordinator's own write share.
            self.log_len += 1;
        }
        let (client, client_site, issued, distributed) = {
            let op = &mut self.ops[op_id as usize];
            op.done = true;
            (op.client, op.client_site, op.issued, op.distributed)
        };
        let d = ctx.topo.servers.one_way(self.id, client_site);
        let ev = Ev::Reply { client, issued, distributed };
        let target = client_group_target(client, ctx.client_groups);
        self.core.send(target, self.core.now() + d, ev);
        // Nothing live references this incarnation past its Complete
        // (votes and acks are all in): recycle the slot.
        self.free_ops.push(op_id);
    }

    /// The prepare-round timeout fired. If the round is still missing
    /// votes, abort: release this coordinator's keys, send releases to
    /// every participant, answer the client, recycle the slot. Stale
    /// deadlines (the op completed, aborted, or the slot was recycled)
    /// are dropped by the stamp/done check.
    fn on_deadline(&mut self, op_id: u64, stamp: u64, ctx: &Shared<'_>) {
        let waiting = self.op_live(op_id, stamp) && self.ops[op_id as usize].votes_pending > 0;
        if !waiting {
            return;
        }
        self.aborts += 1;
        let now = self.core.now();
        let remotes = self.ops[op_id as usize].demand.remotes(self.id);
        for shard in remotes {
            let keys = self.ops[op_id as usize].demand.keys_on(shard);
            let d = ctx.topo.servers.one_way(self.id, shard);
            // FIFO per pair: this lands after the prepare it cancels,
            // even at a participant that buffers both through an outage.
            self.core.send(shard, now + d, Ev::AbortArrive { keys });
        }
        let (client, client_site, issued, distributed, local_keys) = {
            let op = &mut self.ops[op_id as usize];
            op.done = true;
            op.votes_pending = 0;
            (op.client, op.client_site, op.issued, op.distributed, std::mem::take(&mut op.local_keys))
        };
        self.locks.release(&local_keys);
        // The abort still answers the client — the closed loop stays
        // closed; the failure is visible in `ClusterReport::aborts`.
        let d = ctx.topo.servers.one_way(self.id, client_site);
        let target = client_group_target(client, ctx.client_groups);
        self.core.send(target, now + d, Ev::Reply { client, issued, distributed });
        self.free_ops.push(op_id);
    }

    fn on_crash(&mut self, ctx: &Shared<'_>) {
        let cc = ctx.cfg.crash.as_ref().expect("crash event without crash config");
        let now = self.core.now();
        let downtime = cc.downtime(self.log_len);
        self.down = true;
        self.crash = Some(CrashOutcome {
            server: self.id,
            crashed_at: now,
            recovered_at: now + downtime,
            replayed_records: self.log_len,
            held_events: 0,
        });
        self.core.q.schedule(downtime, Ev::Recover);
    }

    fn on_recover(&mut self, ctx: &Shared<'_>) {
        self.down = false;
        let held = std::mem::take(&mut self.held);
        if let Some(o) = self.crash.as_mut() {
            o.held_events = held.len() as u64;
            o.recovered_at = self.core.now();
        }
        // Drain the backlog in arrival order: buffered prepares execute
        // (their coordinators may long since have timed out — the late
        // votes are dropped by stamp), commits apply, timers fire.
        for ev in held {
            self.handle(ev, ctx);
        }
    }
}

impl IssueReply for Ev {
    fn classify(self) -> ClientEv<Ev> {
        match self {
            Ev::Issue { client } => ClientEv::Issue { client },
            Ev::Reply { client, issued, distributed } => {
                ClientEv::Reply { client, issued, flag: distributed }
            }
            other => ClientEv::Other(other),
        }
    }

    fn issue(client: usize) -> Ev {
        Ev::Issue { client }
    }
}

/// The cluster half of the shared client tier: every operation goes to
/// the client site's co-located coordinator shard.
impl IssueRouter<Ev> for Shared<'_> {
    fn route_issue(&self, tier: &mut ClientTier<'_, Ev>, client: usize) {
        let n = self.topo.n();
        let site = tier.clients.site(client);
        let op = {
            let mut r = tier.clients.rng(client).fork();
            tier.gen.next_op(&mut r, site, n)
        };
        let coordinator = site % n;
        let now = tier.core.now();
        let env = OpEnvelope {
            txn: op.txn,
            args: op.args,
            client,
            client_site: site,
            issued: now,
        };
        let delay = self.topo.servers.one_way(site, coordinator);
        // Tag with the global client id: issues from every client group
        // merge in one canonical `(time, source, client)` order, so the
        // schedule is bit-identical at any group count.
        tier.core.send_tagged(coordinator, now + delay, client as u32, Ev::Arrive { op: env });
    }
}

pub struct ClusterSim<'a> {
    app: &'a AnalyzedApp,
    topo: Topology,
    cfg: ClusterConfig,
    footprints: Vec<Footprint>,
    clients: ClientGroups<'a, Ev>,
    servers: Vec<ServerGroup>,
}

impl<'a> ClusterSim<'a> {
    /// `gen` builds one generator per client group (the argument is the
    /// group index); rng-pure generators can ignore it.
    pub fn new(
        app: &'a AnalyzedApp,
        topo: Topology,
        clients_cfg: ClientsConfig,
        cfg: ClusterConfig,
        gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
    ) -> Self {
        let n = topo.n();
        let footprints =
            app.spec.txns.iter().map(|t| footprint(t, &app.spec.schema)).collect();
        let servers = (0..n)
            .map(|id| ServerGroup {
                id,
                station: Station::new(cfg.workers),
                locks: LockShard::default(),
                ops: Vec::new(),
                free_ops: Vec::new(),
                rng: Rng::stream(cfg.seed, id as u64),
                lock_waits: 0,
                core: GroupCore::new(),
                op_stamps: 0,
                aborts: 0,
                down: false,
                held: Vec::new(),
                log_len: 0,
                crash: None,
            })
            .collect();
        let clients = ClientGroups::new(clients_cfg, n, cfg.warmup, cfg.horizon, gen);
        ClusterSim { app, topo, cfg, footprints, clients, servers }
    }

    /// The conservative lookahead: every cross-group message — request,
    /// prepare, vote, commit, ack, reply — pays a one-way latency from
    /// the server matrix (clients are co-located with server sites), so
    /// the matrix minimum bounds all of them.
    fn lookahead(&self) -> VTime {
        self.topo.servers.min_one_way()
    }

    pub fn run(mut self) -> ClusterReport {
        if let Some(cc) = &self.cfg.crash {
            let n = self.topo.n();
            assert!(cc.server < n, "crash.server {} out of range (n={n})", cc.server);
            self.servers[cc.server].core.q.schedule_at(cc.at, Ev::Crash);
        }
        self.clients.boot();
        let lookahead = self.lookahead();
        let threads = parallel::resolve_threads(self.cfg.parallel);
        let horizon = self.cfg.horizon;

        let ClusterSim { app, topo, cfg, footprints, mut clients, mut servers } = self;
        let windows = {
            let ctx = Shared {
                app,
                topo: &topo,
                cfg: &cfg,
                footprints: &footprints,
                client_groups: clients.k(),
            };
            parallel::run_windows(
                threads,
                lookahead,
                horizon,
                &ctx,
                &mut servers,
                &mut clients.groups,
            )
        };

        let now = cfg.horizon;
        ClusterReport {
            metrics: clients.metrics(),
            utilization: servers.iter().map(|s| s.station.utilization(now)).collect(),
            lock_waits: servers.iter().map(|s| s.lock_waits).sum(),
            lock_entries: servers.iter().map(|s| s.locks.len()).sum(),
            lock_entries_peak: servers.iter().map(|s| s.locks.peak).sum(),
            events: clients.processed()
                + servers.iter().map(|s| s.core.q.processed()).sum::<u64>(),
            windows,
            aborts: servers.iter().map(|s| s.aborts).sum(),
            crash: servers.iter().find_map(|s| s.crash),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub metrics: SimMetrics,
    pub utilization: Vec<f64>,
    pub lock_waits: u64,
    /// Live lock-table entries at the horizon, summed over shards.
    pub lock_entries: usize,
    /// Sum of per-shard lock-table high-water marks: bounded by
    /// in-flight write concurrency, not by distinct keys ever touched
    /// (the leak regression metric).
    pub lock_entries_peak: usize,
    pub events: u64,
    /// Conservative windows the engine executed.
    pub windows: u64,
    /// Prepare rounds aborted by [`ClusterConfig::txn_timeout_ms`]
    /// (aborted operations answer their clients but are the 2PC failure
    /// mode a crash provokes — the abort storm).
    pub aborts: u64,
    /// What the configured crash cost (`None` when no crash was
    /// configured or it landed past the horizon).
    pub crash: Option<CrashOutcome>,
}

impl ClusterReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        // Integer-sum mean: exact at any client-group count and defined
        // in bucketed-only mode too.
        self.metrics.mean_latency_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new(
                "CARTS",
                &[("CID", ValueType::Int), ("QTY", ValueType::Int)],
                &["CID"],
            ),
            TableSchema::new(
                "STOCK",
                &[("ITEM", ValueType::Int), ("LEVEL", ValueType::Int)],
                &["ITEM"],
            ),
        ]);
        let txns = vec![
            TxnTemplate::new(
                "add",
                &["cid"],
                &[("u", "UPDATE CARTS SET QTY = QTY + 1 WHERE CID = ?cid")],
                1.0,
            ),
            TxnTemplate::new(
                "order",
                &["cid"],
                &[
                    ("r", "SELECT QTY FROM CARTS WHERE CID = ?cid"),
                    ("w", "UPDATE STOCK SET LEVEL = LEVEL - 1 WHERE ITEM = ?derived"),
                ],
                1.0,
            ),
            TxnTemplate::new(
                "view",
                &["cid"],
                &[("q", "SELECT QTY FROM CARTS WHERE CID = ?cid")],
                1.0,
            ),
        ];
        AnalyzedApp::analyze(AppSpec { name: "cart".into(), schema, txns })
    }

    struct Gen {
        write_ratio: f64,
    }

    impl OpGenerator for Gen {
        fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
            let cid = rng.range(0, 5000) as i64;
            let args: Bindings = [("cid".to_string(), Value::Int(cid))].into_iter().collect();
            if rng.chance(self.write_ratio) {
                if rng.chance(0.5) {
                    Operation { txn: 0, args }
                } else {
                    Operation { txn: 1, args }
                }
            } else {
                Operation { txn: 2, args }
            }
        }
    }

    fn run_par(n: usize, clients: usize, write_ratio: f64, threads: usize) -> ClusterReport {
        let app = app();
        let cfg = ClusterConfig {
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            parallel: threads,
            ..Default::default()
        };
        ClusterSim::new(
            &app,
            Topology::lan(n),
            ClientsConfig { n: clients, think_ms: 10.0, seed: 11, ..Default::default() },
            cfg,
            move |_| Box::new(Gen { write_ratio }),
        )
        .run()
    }

    fn run(n: usize, clients: usize, write_ratio: f64) -> ClusterReport {
        run_par(n, clients, write_ratio, 1)
    }

    #[test]
    fn single_server_is_all_local() {
        let r = run(1, 20, 0.5);
        assert!(r.metrics.completed > 500);
        // No remote coordination on one server.
        assert_eq!(r.metrics.global_latency.count(), 0);
    }

    #[test]
    fn distributed_fraction_appears_with_shards() {
        let r = run(4, 20, 0.5);
        let dist = r.metrics.global_latency.count() as f64;
        let local = r.metrics.local_latency.count() as f64;
        // With 4 shards most point ops are remote (3/4 expected).
        assert!(dist / (dist + local) > 0.5, "dist={dist} local={local}");
        // Distributed ops must be slower (they pay RTTs).
        assert!(r.metrics.global_latency.mean() > r.metrics.local_latency.mean() + 5.0);
    }

    #[test]
    fn write_heavy_suffers_more_than_read_heavy() {
        let wr = run(6, 40, 0.8);
        let rd = run(6, 40, 0.1);
        // Read-heavy completes more with the same offered load (reads take
        // no locks and only one round).
        assert!(
            rd.metrics.latency.mean() < wr.metrics.latency.mean(),
            "read mean {} vs write mean {}",
            rd.metrics.latency.mean(),
            wr.metrics.latency.mean()
        );
    }

    #[test]
    fn hot_key_contention_serializes() {
        // All writes to one cart: lock queueing must show up.
        struct HotGen;
        impl OpGenerator for HotGen {
            fn next_op(&mut self, _rng: &mut Rng, _site: usize, _n: usize) -> Operation {
                let args: Bindings =
                    [("cid".to_string(), Value::Int(7))].into_iter().collect();
                Operation { txn: 0, args }
            }
        }
        let app = app();
        let cfg = ClusterConfig {
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            ..Default::default()
        };
        let r = ClusterSim::new(
            &app,
            Topology::lan(3),
            ClientsConfig { n: 30, think_ms: 0.0, seed: 5, ..Default::default() },
            cfg,
            |_| Box::new(HotGen),
        )
        .run();
        assert!(r.lock_waits > 100, "lock_waits={}", r.lock_waits);
        // One hot key: its shard's table holds exactly that entry while
        // the queue is busy — never more than the keys actually in flight.
        assert!(r.lock_entries_peak <= 2, "peak={}", r.lock_entries_peak);
    }

    /// ISSUE bugfix regression: reservations are evicted on release, so
    /// the virtual lock table stays bounded on a sustained 10-second
    /// run. The old engine's global map only ever inserted — its size
    /// grew monotonically with every distinct key ever written (~50% of
    /// completions below), while the sharded table plateaus at the
    /// in-flight write concurrency (≤ one reservation per busy client).
    #[test]
    fn lock_table_is_bounded_on_sustained_hot_key_run() {
        struct HotColdGen;
        impl OpGenerator for HotColdGen {
            fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
                // One scorching key keeps a lock queue standing for the
                // whole run; a huge cold tail would have leaked an entry
                // per key in the old table.
                let cid = if rng.chance(0.2) { 7 } else { rng.range(0, 1_000_000) as i64 };
                let args: Bindings =
                    [("cid".to_string(), Value::Int(cid))].into_iter().collect();
                Operation { txn: 0, args }
            }
        }
        let app = app();
        let mk = |horizon_s: u64| {
            let cfg = ClusterConfig {
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(horizon_s),
                service: ServiceModel::fixed(5.0),
                ..Default::default()
            };
            ClusterSim::new(
                &app,
                Topology::lan(3),
                ClientsConfig { n: 40, think_ms: 0.0, seed: 5, ..Default::default() },
                cfg,
                |_| Box::new(HotColdGen),
            )
            .run()
        };
        let short = mk(4);
        let full = mk(10);
        // Sustained load: thousands of distinct keys written...
        assert!(full.metrics.completed > 1000, "completed={}", full.metrics.completed);
        assert!(full.metrics.completed > 2 * short.metrics.completed);
        // ...but live reservations stay bounded by concurrency (40
        // closed-loop clients → at most 40 write keys in flight)...
        assert!(full.lock_entries_peak <= 40, "peak={}", full.lock_entries_peak);
        // ...and the high-water mark *plateaus* rather than growing with
        // the horizon like the leaky table did.
        assert!(
            full.lock_entries_peak <= short.lock_entries_peak + 5,
            "peak grew with the horizon: {} -> {}",
            short.lock_entries_peak,
            full.lock_entries_peak
        );
        assert!(full.lock_entries <= full.lock_entries_peak);
    }

    #[test]
    fn deterministic() {
        let a = run(4, 25, 0.3);
        let b = run(4, 25, 0.3);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
        assert_eq!(a.lock_waits, b.lock_waits);
    }

    /// The window-engine property, checked cheaply here and exhaustively
    /// in `tests/parallel_determinism.rs`: any thread count produces
    /// bit-identical results.
    #[test]
    fn thread_count_does_not_change_results() {
        let base = run_par(4, 40, 0.5, 1);
        for threads in [2usize, 0] {
            let r = run_par(4, 40, 0.5, threads);
            assert_eq!(r.metrics.completed, base.metrics.completed, "threads={threads}");
            assert_eq!(r.events, base.events, "threads={threads}");
            assert_eq!(r.lock_waits, base.lock_waits, "threads={threads}");
            assert_eq!(r.lock_entries_peak, base.lock_entries_peak, "threads={threads}");
            assert!(
                (r.mean_latency_ms() - base.mean_latency_ms()).abs() < 1e-12,
                "threads={threads}"
            );
        }
    }

    /// The client-group property: sharding the client tier into K
    /// groups (scheduled over any thread count) is bit-identical to the
    /// single-group, single-thread run. Exhaustive matrix in
    /// `tests/parallel_determinism.rs`.
    #[test]
    fn client_group_count_does_not_change_results() {
        let run_k = |groups: usize, threads: usize| {
            let app = app();
            let cfg = ClusterConfig {
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..Default::default()
            };
            ClusterSim::new(
                &app,
                Topology::lan(4),
                ClientsConfig { n: 24, think_ms: 10.0, seed: 11, groups, ..Default::default() },
                cfg,
                |_| Box::new(Gen { write_ratio: 0.5 }),
            )
            .run()
        };
        let base = run_k(1, 1);
        assert!(base.metrics.completed > 200, "completed={}", base.metrics.completed);
        for (groups, threads) in [(2, 1), (2, 2), (24, 0), (0, 0)] {
            let r = run_k(groups, threads);
            let tag = format!("groups={groups} threads={threads}");
            assert_eq!(r.metrics.completed, base.metrics.completed, "{tag}");
            assert_eq!(r.events, base.events, "{tag}");
            assert_eq!(r.windows, base.windows, "{tag}");
            assert_eq!(r.lock_waits, base.lock_waits, "{tag}");
            assert_eq!(
                r.mean_latency_ms().to_bits(),
                base.mean_latency_ms().to_bits(),
                "{tag}"
            );
            assert_eq!(
                r.metrics.latency_hist.buckets(),
                base.metrics.latency_hist.buckets(),
                "{tag}"
            );
        }
    }

    /// Tentpole: a participant crash mid-2PC. Without timeouts the
    /// prepare rounds touching the dead shard freeze (coordinators hold
    /// row locks across the whole outage); with a timeout every such
    /// round aborts — the 2PC abort storm the conveyor's token protocol
    /// does not have (there, the belt stalls but nothing aborts).
    #[test]
    fn participant_crash_with_timeouts_produces_abort_storm() {
        let app = app();
        let mk = |crash: Option<CrashConfig>, timeout: Option<f64>, threads: usize| {
            let cfg = ClusterConfig {
                crash,
                txn_timeout_ms: timeout,
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..Default::default()
            };
            ClusterSim::new(
                &app,
                Topology::lan(4),
                ClientsConfig { n: 32, think_ms: 10.0, seed: 11, ..Default::default() },
                cfg,
                |_| Box::new(Gen { write_ratio: 0.5 }),
            )
            .run()
        };
        // A healthy LAN cluster never comes close to a 400 ms prepare
        // round: the timeout must be invisible.
        let clean = mk(None, Some(400.0), 1);
        assert_eq!(clean.aborts, 0, "timeouts fired on a healthy cluster");
        assert!(clean.crash.is_none());

        let cc = CrashConfig {
            server: 1,
            at: VTime::from_secs(4),
            restart_ms: 800.0,
            replay_per_record_ms: 0.05,
        };
        let crashed = mk(Some(cc.clone()), Some(400.0), 1);
        let o = crashed.crash.expect("crash outcome");
        assert_eq!(o.server, 1);
        assert_eq!(o.crashed_at, VTime::from_secs(4));
        assert!(o.replayed_records > 0, "shard 1 must have logged commits by 4s");
        assert!(o.held_events > 0, "2PC traffic must pile up during the outage");
        assert!(o.downtime_ms() >= 800.0);
        assert!(crashed.aborts > 10, "expected an abort storm, got {}", crashed.aborts);
        assert!(crashed.metrics.completed > 100);

        // Without timeouts the same crash aborts nothing: the affected
        // rounds (and their row locks) just wait out the outage.
        let frozen = mk(Some(cc.clone()), None, 1);
        assert_eq!(frozen.aborts, 0);
        assert!(frozen.crash.is_some());

        // Crash + abort handling is group-local: still bit-identical at
        // any thread count.
        let par = mk(Some(cc), Some(400.0), 2);
        assert_eq!(par.metrics.completed, crashed.metrics.completed);
        assert_eq!(par.events, crashed.events);
        assert_eq!(par.aborts, crashed.aborts);
        assert_eq!(par.crash, crashed.crash);
        assert_eq!(par.mean_latency_ms().to_bits(), crashed.mean_latency_ms().to_bits());
    }

    /// Satellite guard: the documented defaults the benches assume
    /// (`ClusterConfig::default()` inside `harness::experiments`). A
    /// silent retuning would skew every recorded Fig-3 baseline curve.
    #[test]
    fn documented_defaults_match_bench_assumptions() {
        let c = ClusterConfig::default();
        assert_eq!(c.workers, 8, "fair-baseline thread pool (same as Eliá servers)");
        assert!((c.remote_exec_frac - 0.8).abs() < 1e-12);
        assert!((c.msg_cpu_ms - 0.8).abs() < 1e-12);
        assert_eq!(c.parallel, 1, "sequential by default; benches opt in");
        assert!(c.crash.is_none(), "durability modeling is opt-in");
        assert!(c.txn_timeout_ms.is_none(), "2PC waits forever unless opted in");
        assert_eq!(c.warmup, VTime::from_secs(5));
        assert_eq!(c.horizon, VTime::from_secs(25));
        assert_eq!(c.seed, 0xC1B5);
    }
}
