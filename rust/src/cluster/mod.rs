//! The data-partitioning baseline: a MySQL-Cluster-like deployment with
//! horizontal partitioning, distributed transactions (row locks + 2PC)
//! and read-committed isolation — the system Eliá is compared against in
//! the paper's RQ1 experiments.

pub mod footprint;
pub mod sim;

pub use footprint::{footprint, Footprint, ShardDemand, StmtAccess};
pub use sim::{ClusterConfig, ClusterReport, ClusterSim};
