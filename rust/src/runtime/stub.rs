//! Offline stand-in for the PJRT-backed [`CostEvaluator`]: same surface,
//! artifact always reported as unavailable. Keeps the crate building
//! with zero external dependencies (see `runtime/mod.rs`).

use crate::analysis::elim::EliminationTensor;
use crate::analysis::score::{Assignment, BatchScorer};
use std::path::{Path, PathBuf};

/// Padded shapes baked into the artifact. Must match `python/compile/model.py`.
pub const ARTIFACT_B: usize = 256;
pub const ARTIFACT_T: usize = 32;
pub const ARTIFACT_K: usize = 8;

/// Default artifact file name.
pub const ARTIFACT_FILE: &str = "partition_cost.hlo.txt";

/// Resolve the artifacts directory: `$ELIA_ARTIFACTS`, else `./artifacts`,
/// else `<crate root>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("ELIA_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = PathBuf::from("artifacts");
    if local.exists() {
        return local;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const UNAVAILABLE: &str =
    "PJRT runtime not built: enable the `pjrt` cargo feature (requires the xla crate)";

/// Uninhabited in the stub build: [`CostEvaluator::load`] always fails
/// and [`CostEvaluator::try_default`] always returns `None`.
pub struct CostEvaluator {
    _priv: std::convert::Infallible,
}

impl std::fmt::Debug for CostEvaluator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostEvaluator").field("platform", &"stub").finish()
    }
}

impl CostEvaluator {
    /// Always fails in the stub build.
    pub fn load(_path: &Path) -> Result<Self, String> {
        Err(UNAVAILABLE.to_string())
    }

    /// Always `None` in the stub build (callers fall back to the scalar
    /// scorer).
    pub fn try_default() -> Option<Self> {
        None
    }

    pub fn platform(&self) -> &str {
        unreachable!("stub CostEvaluator cannot be constructed")
    }
}

impl BatchScorer for CostEvaluator {
    fn score(&self, _tensor: &EliminationTensor, _batch: &[Assignment]) -> Vec<f64> {
        unreachable!("stub CostEvaluator cannot be constructed")
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

/// Report the PJRT platform; always an error in the stub build.
pub fn platform() -> Result<String, String> {
    Err(UNAVAILABLE.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn stub_reports_unavailable() {
        assert!(CostEvaluator::try_default().is_none());
        assert!(CostEvaluator::load(Path::new("/nonexistent")).is_err());
        assert!(platform().is_err());
    }
}
