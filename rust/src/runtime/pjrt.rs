//! PJRT runtime: load the AOT-compiled partition-cost artifact (HLO text
//! produced by `python/compile/aot.py`) and execute it from the
//! partitioning search hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the L2 JAX
//! graph (which calls the L1 Pallas kernel) to HLO *text* once; this
//! module loads it with `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client, and exposes it as a [`BatchScorer`] for
//! [`crate::analysis::partition::optimize`].
//!
//! Artifact contract (shapes fixed at AOT time, see `python/compile/model.py`):
//!
//! ```text
//! inputs : cand [B, T, K] f32 one-hot   — candidate partitioning arrays
//!          cw   [T, T]    f32           — conflict[t,t'] * (w(t)+w(t'))
//!          elim [T, T, K, K] f32        — coverage bits
//! output : cost [B] f32
//! cost[b] = Σ_{t,t'} cw[t,t'] · (1 − Σ_{k,k'} cand[b,t,k]·cand[b,t',k']·elim[t,t',k,k'])
//! ```

use crate::analysis::elim::EliminationTensor;
use crate::analysis::score::{Assignment, BatchScorer};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Padded shapes baked into the artifact. Must match `python/compile/model.py`.
pub const ARTIFACT_B: usize = 256;
pub const ARTIFACT_T: usize = 32;
pub const ARTIFACT_K: usize = 8;

/// Default artifact file name.
pub const ARTIFACT_FILE: &str = "partition_cost.hlo.txt";

/// Resolve the artifacts directory: `$ELIA_ARTIFACTS`, else `./artifacts`,
/// else `<crate root>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("ELIA_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = PathBuf::from("artifacts");
    if local.exists() {
        return local;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A compiled partition-cost evaluator.
///
/// Thread-safety: PJRT execution itself is thread-safe, but we guard
/// execution with a mutex to keep buffer lifetimes simple — the search
/// calls are already batched so this is not a bottleneck.
pub struct CostEvaluator {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    platform: String,
}

// SAFETY: the `xla` crate's PJRT wrappers hold `Rc` handles, making them
// !Send/!Sync even though the underlying PJRT CPU client is thread-safe.
// Every access to `exe` (the only wrapper we retain, owning the only Rc
// chain to the client) goes through the Mutex, so Rc refcount updates are
// serialized and never race.
unsafe impl Send for CostEvaluator {}
unsafe impl Sync for CostEvaluator {}

impl std::fmt::Debug for CostEvaluator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostEvaluator").field("platform", &self.platform).finish()
    }
}

impl CostEvaluator {
    /// Load and compile the artifact at `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(CostEvaluator { exe: Mutex::new(exe), platform })
    }

    /// Load the default artifact if present (`None` if not built yet).
    pub fn try_default() -> Option<Self> {
        let path = artifacts_dir().join(ARTIFACT_FILE);
        if !path.exists() {
            return None;
        }
        match Self::load(&path) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("warning: failed to load {}: {err:#}", path.display());
                None
            }
        }
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Score up to [`ARTIFACT_B`] assignments in one artifact execution.
    fn score_chunk(&self, tensor: &EliminationTensor, chunk: &[Assignment]) -> Result<Vec<f64>> {
        assert!(chunk.len() <= ARTIFACT_B);
        assert!(
            tensor.n <= ARTIFACT_T && tensor.kmax <= ARTIFACT_K,
            "application exceeds artifact padding (T={} K={})",
            tensor.n,
            tensor.kmax
        );
        // One-hot candidates, padded.
        let mut cand = vec![0f32; ARTIFACT_B * ARTIFACT_T * ARTIFACT_K];
        for (b, assign) in chunk.iter().enumerate() {
            for (t, choice) in assign.iter().enumerate() {
                if let Some(k) = choice {
                    cand[(b * ARTIFACT_T + t) * ARTIFACT_K + k] = 1.0;
                }
            }
        }
        let (cw, elim) = tensor.to_f32(ARTIFACT_T, ARTIFACT_K);

        let cand_lit = xla::Literal::vec1(&cand)
            .reshape(&[ARTIFACT_B as i64, ARTIFACT_T as i64, ARTIFACT_K as i64])?;
        let cw_lit = xla::Literal::vec1(&cw).reshape(&[ARTIFACT_T as i64, ARTIFACT_T as i64])?;
        let elim_lit = xla::Literal::vec1(&elim).reshape(&[
            ARTIFACT_T as i64,
            ARTIFACT_T as i64,
            ARTIFACT_K as i64,
            ARTIFACT_K as i64,
        ])?;

        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[cand_lit, cw_lit, elim_lit])?[0][0]
            .to_literal_sync()?;
        drop(exe);
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let costs: Vec<f32> = out.to_vec()?;
        anyhow::ensure!(costs.len() == ARTIFACT_B, "bad output length {}", costs.len());
        Ok(costs[..chunk.len()].iter().map(|&x| x as f64).collect())
    }
}

impl BatchScorer for CostEvaluator {
    fn score(&self, tensor: &EliminationTensor, batch: &[Assignment]) -> Vec<f64> {
        let mut out = Vec::with_capacity(batch.len());
        for chunk in batch.chunks(ARTIFACT_B) {
            match self.score_chunk(tensor, chunk) {
                Ok(mut v) => out.append(&mut v),
                Err(e) => panic!("artifact scoring failed: {e:#}"),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}

/// Smoke helper: report the PJRT platform (used by the CLI `doctor`
/// command and tests).
pub fn platform() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pjrt_cpu_client_comes_up() {
        let p = platform().expect("PJRT CPU client");
        assert!(!p.is_empty());
    }

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    // Full artifact-vs-scalar parity lives in rust/tests/cost_parity.rs
    // (it needs `make artifacts` to have run).
}
