//! PJRT runtime dispatch.
//!
//! The real implementation (the private `pjrt` module) loads the
//! AOT-compiled partition-cost artifact (HLO text produced by
//! `python/compile/aot.py`)
//! and executes it on the PJRT CPU client. It needs the `xla` and
//! `anyhow` crates, which are not vendored in this offline build — so it
//! is gated behind the `pjrt` cargo feature. The default build uses
//! the `stub` module, which exposes the same surface but reports the artifact as
//! unavailable; every caller already handles that case (the scalar
//! scorer is the reference implementation).

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
