//! WAN baselines of the paper's RQ2 experiments (§7.2):
//!
//! * **Centralized** — one server (at the first site); clients at all
//!   sites pay the WAN round trip for every operation.
//! * **Read-only optimization** — replicas at the first `n` sites;
//!   read-only operations execute at the client's nearest replica
//!   without coordination, writes go to the primary (site 0) and are
//!   replicated asynchronously. "A common optimization offered by many
//!   systems."
//!
//! Both keep the application unmodified and serializable, like Eliá.

use crate::simnet::clients::{ClientPool, ClientsConfig};
use crate::simnet::events::EventQueue;
use crate::simnet::latency::LatencyMatrix;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::{OpGenerator, ServiceModel};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineMode {
    Centralized,
    /// Read-only ops at the nearest of `n_servers` replicas.
    ReadOnly { n_servers: usize },
}

#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub mode: BaselineMode,
    pub workers: usize,
    pub service: ServiceModel,
    /// CPU cost of applying one replicated write at a replica.
    pub apply_ms: f64,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl BaselineConfig {
    pub fn centralized() -> Self {
        BaselineConfig {
            mode: BaselineMode::Centralized,
            workers: 8,
            service: ServiceModel::default(),
            apply_ms: 0.5,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0xBA5E,
        }
    }

    pub fn read_only(n_servers: usize) -> Self {
        BaselineConfig { mode: BaselineMode::ReadOnly { n_servers }, ..Self::centralized() }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Job {
    Op(u64),
    /// Replicated-write application at a replica.
    Apply,
}

#[derive(Debug, Clone)]
enum Ev {
    Issue { client: usize },
    Arrive { op: u64 },
    ApplyArrive { server: usize },
    JobDone { server: usize, job: Job },
    Reply { op: u64 },
}

struct OpState {
    txn: usize,
    client: usize,
    issued: VTime,
    server: usize,
    write: bool,
}

pub struct BaselineSim<'a> {
    app: &'a AnalyzedApp,
    /// Latency matrix over *client sites*; servers occupy the first sites.
    sites: LatencyMatrix,
    cfg: BaselineConfig,
    gen: Box<dyn OpGenerator + 'a>,
    clients: ClientPool,
    stations: Vec<Station<Job>>,
    ops: Vec<OpState>,
    /// Per-server RNG streams (service sampling), derived statelessly
    /// from the seed — see `Rng::stream`.
    rngs: Vec<Rng>,
    pub metrics: SimMetrics,
    q: EventQueue<Ev>,
}

impl<'a> BaselineSim<'a> {
    /// `sites` is the full client-site latency matrix (all five paper
    /// sites in the WAN experiments); clients spread over all of them
    /// regardless of how many servers the mode deploys.
    pub fn new(
        app: &'a AnalyzedApp,
        sites: LatencyMatrix,
        clients_cfg: ClientsConfig,
        cfg: BaselineConfig,
        gen: Box<dyn OpGenerator + 'a>,
    ) -> Self {
        let n_sites = sites.n();
        let clients = ClientPool::new(ClientsConfig { sites: n_sites, ..clients_cfg });
        let n_servers = match cfg.mode {
            BaselineMode::Centralized => 1,
            BaselineMode::ReadOnly { n_servers } => n_servers.min(n_sites).max(1),
        };
        let stations = (0..n_servers).map(|_| Station::new(cfg.workers)).collect();
        let metrics = SimMetrics::new(cfg.warmup, cfg.horizon);
        let rngs = (0..n_servers).map(|i| Rng::stream(cfg.seed, i as u64)).collect();
        BaselineSim {
            app,
            sites,
            cfg,
            gen,
            clients,
            stations,
            ops: Vec::new(),
            rngs,
            metrics,
            q: EventQueue::new(),
        }
    }

    fn n_servers(&self) -> usize {
        self.stations.len()
    }

    /// The server with the lowest latency from a client site.
    fn nearest_server(&self, site: usize) -> usize {
        (0..self.n_servers()).min_by_key(|&s| self.sites.one_way(site, s)).unwrap_or(0)
    }

    pub fn run(mut self) -> BaselineReport {
        for c in 0..self.clients.n() {
            let jitter = VTime::from_micros((c as u64 % 97) * 13);
            self.q.schedule(jitter, Ev::Issue { client: c });
        }
        while let Some(t) = self.q.peek_time() {
            if t > self.cfg.horizon {
                break;
            }
            let (_, ev) = self.q.pop().unwrap();
            self.handle(ev);
        }
        let now = self.cfg.horizon;
        BaselineReport {
            metrics: self.metrics.clone(),
            utilization: self.stations.iter().map(|s| s.utilization(now)).collect(),
            events: self.q.processed(),
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Issue { client } => self.on_issue(client),
            Ev::Arrive { op } => {
                let (server, txn) = {
                    let o = &self.ops[op as usize];
                    (o.server, o.txn)
                };
                let service =
                    self.cfg.service.sample(&self.app.spec.txns[txn], &mut self.rngs[server]);
                self.submit(server, Job::Op(op), service);
            }
            Ev::ApplyArrive { server } => {
                let apply = VTime::from_millis_f64(self.cfg.apply_ms);
                self.submit(server, Job::Apply, apply);
            }
            Ev::JobDone { server, job } => self.on_job_done(server, job),
            Ev::Reply { op } => self.on_reply(op),
        }
    }

    fn submit(&mut self, server: usize, job: Job, service: VTime) {
        let now = self.q.now();
        if let Some(j) = self.stations[server].submit(now, job, service, false) {
            self.q.schedule(j.service, Ev::JobDone { server, job: j.payload });
        }
    }

    fn on_issue(&mut self, client: usize) {
        let site = self.clients.site(client);
        let n = self.n_servers();
        let op = {
            let mut r = self.clients.rng(client).fork();
            self.gen.next_op(&mut r, site, n)
        };
        let write = !self.app.spec.txns[op.txn].is_read_only();
        let server = match self.cfg.mode {
            BaselineMode::Centralized => 0,
            BaselineMode::ReadOnly { .. } => {
                if write {
                    0 // primary
                } else {
                    self.nearest_server(site)
                }
            }
        };
        let op_id = self.ops.len() as u64;
        self.ops.push(OpState { txn: op.txn, client, issued: self.q.now(), server, write });
        let delay = self.sites.one_way(site, server);
        self.q.schedule(delay, Ev::Arrive { op: op_id });
    }

    fn on_job_done(&mut self, server: usize, job: Job) {
        let now = self.q.now();
        if let Some(next) = self.stations[server].complete(now) {
            self.q.schedule(next.service, Ev::JobDone { server, job: next.payload });
        }
        if let Job::Op(op_id) = job {
            let (client, write) = {
                let o = &self.ops[op_id as usize];
                (o.client, o.write)
            };
            // Read-only mode: writes replicate asynchronously to replicas.
            if write && matches!(self.cfg.mode, BaselineMode::ReadOnly { .. }) {
                for s in 1..self.n_servers() {
                    let d = self.sites.one_way(server, s);
                    self.q.schedule(d, Ev::ApplyArrive { server: s });
                }
            }
            let site = self.clients.site(client);
            let d = self.sites.one_way(server, site);
            self.q.schedule(d, Ev::Reply { op: op_id });
        }
    }

    fn on_reply(&mut self, op_id: u64) {
        let (client, issued, write) = {
            let o = &self.ops[op_id as usize];
            (o.client, o.issued, o.write)
        };
        self.metrics.complete(issued, self.q.now(), write);
        let think = self.clients.think(client);
        self.q.schedule(think, Ev::Issue { client });
    }
}

#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub metrics: SimMetrics,
    pub utilization: Vec<f64>,
    pub events: u64,
}

impl BaselineReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.metrics.latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::simnet::latency::Topology;
    use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![TableSchema::new(
            "T",
            &[("K", ValueType::Int), ("V", ValueType::Int)],
            &["K"],
        )]);
        let txns = vec![
            TxnTemplate::new("read", &["k"], &[("q", "SELECT V FROM T WHERE K = ?k")], 1.0),
            TxnTemplate::new(
                "write",
                &["k"],
                &[("u", "UPDATE T SET V = V + 1 WHERE K = ?k")],
                1.0,
            ),
        ];
        AnalyzedApp::analyze(AppSpec { name: "kv".into(), schema, txns })
    }

    struct Gen {
        write_ratio: f64,
    }

    impl OpGenerator for Gen {
        fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
            let txn = if rng.chance(self.write_ratio) { 1 } else { 0 };
            let args: Bindings =
                [("k".to_string(), Value::Int(rng.range(0, 1000) as i64))].into_iter().collect();
            Operation { txn, args }
        }
    }

    fn run(mode: BaselineMode, clients: usize, write_ratio: f64) -> BaselineReport {
        let app = app();
        let cfg = BaselineConfig {
            mode,
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            ..BaselineConfig::centralized()
        };
        BaselineSim::new(
            &app,
            Topology::wan_full_client(5),
            ClientsConfig { n: clients, think_ms: 50.0, seed: 2, ..Default::default() },
            cfg,
            Box::new(Gen { write_ratio }),
        )
        .run()
    }

    #[test]
    fn centralized_pays_wan_round_trips() {
        let r = run(BaselineMode::Centralized, 10, 0.3);
        // Mean latency must reflect WAN RTTs (G clients see ~20ms, A
        // clients ~314ms; the cross-site mean is large).
        let mean = r.mean_latency_ms();
        assert!(mean > 100.0, "mean={mean}");
        assert!(r.metrics.completed > 100);
    }

    #[test]
    fn read_only_replicas_cut_read_latency() {
        let cen = run(BaselineMode::Centralized, 10, 0.0);
        let ro = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 0.0);
        // Pure reads: every client hits its local replica.
        assert!(
            ro.mean_latency_ms() < cen.mean_latency_ms() / 3.0,
            "ro={} cen={}",
            ro.mean_latency_ms(),
            cen.mean_latency_ms()
        );
    }

    #[test]
    fn writes_still_pay_primary_round_trip() {
        let ro_reads = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 0.0);
        let ro_writes = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 1.0);
        assert!(
            ro_writes.mean_latency_ms() > ro_reads.mean_latency_ms() * 2.0,
            "writes={} reads={}",
            ro_writes.mean_latency_ms(),
            ro_reads.mean_latency_ms()
        );
    }

    #[test]
    fn centralized_saturates_with_load() {
        let light = run(BaselineMode::Centralized, 5, 0.3);
        let heavy = run(BaselineMode::Centralized, 1500, 0.3);
        // One 8-thread server at 5 ms/op sustains ~1600 ops/s; the heavy
        // run must sit near that ceiling with far higher latency.
        assert!(heavy.throughput() < 1750.0, "tput={}", heavy.throughput());
        assert!(heavy.mean_latency_ms() > 3.0 * light.mean_latency_ms());
        assert!(heavy.utilization[0] > 0.9, "util={:?}", heavy.utilization);
    }

    #[test]
    fn deterministic() {
        let a = run(BaselineMode::ReadOnly { n_servers: 3 }, 20, 0.2);
        let b = run(BaselineMode::ReadOnly { n_servers: 3 }, 20, 0.2);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
    }
}
