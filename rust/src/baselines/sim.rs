//! WAN baselines of the paper's RQ2 experiments (§7.2):
//!
//! * **Centralized** — one server (at the first site); clients at all
//!   sites pay the WAN round trip for every operation.
//! * **Read-only optimization** — replicas at the first `n` sites;
//!   read-only operations execute at the client's nearest replica
//!   without coordination, writes go to the primary (site 0) and are
//!   replicated asynchronously. "A common optimization offered by many
//!   systems."
//!
//! Both keep the application unmodified and serializable, like Eliá.
//!
//! The simulation runs on the conservative window engine
//! ([`crate::simnet::parallel::run_windows`], shared with `ConveyorSim`
//! and `ClusterSim`): one group per deployed server (station + RNG
//! stream) plus K client groups, interacting only through
//! latency-paying messages (request, async replication, reply) —
//! results are bit-identical at any thread count
//! ([`BaselineConfig::parallel`]) and any client-group count
//! ([`ClientsConfig::groups`]).

use crate::simnet::clients::{
    ClientEv, ClientGroups, ClientTier, ClientsConfig, IssueReply, IssueRouter,
};
use crate::simnet::latency::LatencyMatrix;
use crate::simnet::metrics::SimMetrics;
use crate::simnet::parallel::{self, client_group_target, GroupCore, WindowGroup};
use crate::simnet::station::Station;
use crate::util::{Rng, VTime};
use crate::workload::analyzed::AnalyzedApp;
use crate::workload::generator::{OpGenerator, ServiceModel};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineMode {
    Centralized,
    /// Read-only ops at the nearest of `n_servers` replicas.
    ReadOnly { n_servers: usize },
    /// Warp-style acyclic commit over `n_servers` partitions:
    /// single-partition operations execute at their partition without any
    /// coordination; multi-partition ones traverse the servers in a fixed
    /// global order (an acyclic validation chain, so distributed commits
    /// cannot cycle), paying a one-way latency plus a validation step at
    /// every hop and executing at the final one. No rotating token — the
    /// comparison point for Eliá's fig3/fig4 curves.
    Warp { n_servers: usize },
}

#[derive(Debug, Clone)]
pub struct BaselineConfig {
    pub mode: BaselineMode,
    pub workers: usize,
    pub service: ServiceModel,
    /// CPU cost of applying one replicated write at a replica.
    pub apply_ms: f64,
    /// Worker threads for the window-parallel engine: `1` sequential
    /// (default), `0` all cores, `N` at most N threads. Results are
    /// bit-identical for every value.
    pub parallel: usize,
    pub warmup: VTime,
    pub horizon: VTime,
    pub seed: u64,
}

impl BaselineConfig {
    pub fn centralized() -> Self {
        BaselineConfig {
            mode: BaselineMode::Centralized,
            workers: 8,
            service: ServiceModel::default(),
            apply_ms: 0.5,
            parallel: 1,
            warmup: VTime::from_secs(5),
            horizon: VTime::from_secs(25),
            seed: 0xBA5E,
        }
    }

    pub fn read_only(n_servers: usize) -> Self {
        BaselineConfig { mode: BaselineMode::ReadOnly { n_servers }, ..Self::centralized() }
    }

    pub fn warp(n_servers: usize) -> Self {
        BaselineConfig { mode: BaselineMode::Warp { n_servers }, ..Self::centralized() }
    }
}

#[derive(Debug, Clone)]
enum Job {
    Op(OpEnvelope),
    /// Replicated-write application at a replica.
    Apply,
    /// One stop of a Warp validation chain; `hop` is this server's
    /// position (== its id). The final hop runs the full operation.
    Chain { op: OpEnvelope, hop: usize },
}

#[derive(Debug, Clone)]
enum Ev {
    /// Client (after thinking) issues its next operation. [client tier]
    Issue { client: usize },
    /// Reply reaches the client. [client tier]
    Reply { client: usize, issued: VTime, write: bool },
    /// Request arrives at its server. [server]
    Arrive { op: OpEnvelope },
    /// An async replicated write lands at a replica. [server]
    ApplyArrive,
    /// A Warp chain reaches its next server. [server]
    ChainArrive { op: OpEnvelope, hop: usize },
    /// A station job completed. [server]
    JobDone { job: Job },
}

/// An operation in flight, carried inside events and station jobs (the
/// engine has no global operation table).
#[derive(Debug, Clone)]
struct OpEnvelope {
    txn: usize,
    client: usize,
    client_site: usize,
    issued: VTime,
    write: bool,
}

/// Immutable context shared by every group during a window.
struct Shared<'s> {
    app: &'s AnalyzedApp,
    /// Latency matrix over *client sites*; servers occupy the first sites.
    sites: &'s LatencyMatrix,
    cfg: &'s BaselineConfig,
    n_servers: usize,
    /// Number of client groups (for routing replies to the right one).
    client_groups: usize,
}

impl Shared<'_> {
    /// The server with the lowest latency from a client site.
    fn nearest_server(&self, site: usize) -> usize {
        (0..self.n_servers).min_by_key(|&s| self.sites.one_way(site, s)).unwrap_or(0)
    }
}

/// One server group: a queueing station plus its RNG stream.
struct ServerGroup {
    id: usize,
    station: Station<Job>,
    /// Per-server RNG stream (service sampling) — see `Rng::stream`.
    rng: Rng,
    core: GroupCore<Ev>,
}

impl<'s> WindowGroup<Shared<'s>> for ServerGroup {
    type Ev = Ev;

    fn core(&self) -> &GroupCore<Ev> {
        &self.core
    }

    fn core_mut(&mut self) -> &mut GroupCore<Ev> {
        &mut self.core
    }

    fn handle(&mut self, ev: Ev, ctx: &Shared<'s>) {
        match ev {
            Ev::Arrive { op } => {
                let service =
                    ctx.cfg.service.sample(&ctx.app.spec.txns[op.txn], &mut self.rng);
                self.submit(Job::Op(op), service);
            }
            Ev::ApplyArrive => {
                let apply = VTime::from_millis_f64(ctx.cfg.apply_ms);
                self.submit(Job::Apply, apply);
            }
            Ev::ChainArrive { op, hop } => {
                // Intermediate hops pay a validation step; the final hop
                // executes the operation in full and replies.
                let service = if hop + 1 == ctx.n_servers {
                    ctx.cfg.service.sample(&ctx.app.spec.txns[op.txn], &mut self.rng)
                } else {
                    VTime::from_millis_f64(ctx.cfg.apply_ms)
                };
                self.submit(Job::Chain { op, hop }, service);
            }
            Ev::JobDone { job } => self.on_job_done(job, ctx),
            Ev::Issue { .. } | Ev::Reply { .. } => {
                unreachable!("client-tier event delivered to a server")
            }
        }
    }
}

impl ServerGroup {
    fn submit(&mut self, job: Job, service: VTime) {
        let now = self.core.now();
        if let Some(j) = self.station.submit(now, job, service, false) {
            self.core.q.schedule(j.service, Ev::JobDone { job: j.payload });
        }
    }

    fn on_job_done(&mut self, job: Job, ctx: &Shared<'_>) {
        let now = self.core.now();
        if let Some(next) = self.station.complete(now) {
            self.core.q.schedule(next.service, Ev::JobDone { job: next.payload });
        }
        match job {
            Job::Op(op) => {
                // Read-only mode: writes replicate async to replicas.
                if op.write && matches!(ctx.cfg.mode, BaselineMode::ReadOnly { .. }) {
                    for s in 0..ctx.n_servers {
                        if s == self.id {
                            continue;
                        }
                        let d = ctx.sites.one_way(self.id, s);
                        self.core.send(s, now + d, Ev::ApplyArrive);
                    }
                }
                let d = ctx.sites.one_way(self.id, op.client_site);
                let target = client_group_target(op.client, ctx.client_groups);
                let ev =
                    Ev::Reply { client: op.client, issued: op.issued, write: op.write };
                self.core.send(target, now + d, ev);
            }
            Job::Chain { op, hop } => {
                if hop + 1 == ctx.n_servers {
                    // Validated everywhere; executed here — reply.
                    let d = ctx.sites.one_way(self.id, op.client_site);
                    let target = client_group_target(op.client, ctx.client_groups);
                    let ev =
                        Ev::Reply { client: op.client, issued: op.issued, write: op.write };
                    self.core.send(target, now + d, ev);
                } else {
                    let next = hop + 1;
                    let d = ctx.sites.one_way(self.id, next);
                    self.core.send(next, now + d, Ev::ChainArrive { op, hop: next });
                }
            }
            Job::Apply => {}
        }
    }
}

impl IssueReply for Ev {
    fn classify(self) -> ClientEv<Ev> {
        match self {
            Ev::Issue { client } => ClientEv::Issue { client },
            Ev::Reply { client, issued, write } => {
                ClientEv::Reply { client, issued, flag: write }
            }
            other => ClientEv::Other(other),
        }
    }

    fn issue(client: usize) -> Ev {
        Ev::Issue { client }
    }
}

/// The baseline half of the shared client tier: reads go to the nearest
/// replica (read-only mode), writes and everything centralized to the
/// primary.
impl IssueRouter<Ev> for Shared<'_> {
    fn route_issue(&self, tier: &mut ClientTier<'_, Ev>, client: usize) {
        let site = tier.clients.site(client);
        let op = {
            let mut r = tier.clients.rng(client).fork();
            tier.gen.next_op(&mut r, site, self.n_servers)
        };
        let write = !self.app.spec.txns[op.txn].is_read_only();
        let now = tier.core.now();
        let env = OpEnvelope {
            txn: op.txn,
            client,
            client_site: site,
            issued: now,
            write,
        };
        let server = match self.cfg.mode {
            BaselineMode::Centralized => 0,
            BaselineMode::ReadOnly { .. } => {
                if write {
                    0 // primary
                } else {
                    self.nearest_server(site)
                }
            }
            BaselineMode::Warp { .. } => {
                use crate::workload::analyzed::Route;
                match self.app.route(&op, self.n_servers) {
                    Route::GlobalAt(_) => {
                        // Multi-partition: enter the acyclic chain at
                        // server 0 and validate in global id order.
                        let delay = self.sites.one_way(site, 0);
                        tier.core.send_tagged(
                            0,
                            now + delay,
                            client as u32,
                            Ev::ChainArrive { op: env, hop: 0 },
                        );
                        return;
                    }
                    // Single-partition (confluent ops included: Warp has
                    // no merge machinery, but one-partition commits need
                    // none): execute at the owning partition.
                    Route::LocalAt(s) | Route::ConfluentAt(s) => s,
                    Route::Any => self.nearest_server(site),
                }
            }
        };
        let delay = self.sites.one_way(site, server);
        // Tag with the global client id: issues from every client group
        // merge in one canonical `(time, source, client)` order, so the
        // schedule is bit-identical at any group count.
        tier.core.send_tagged(server, now + delay, client as u32, Ev::Arrive { op: env });
    }
}

pub struct BaselineSim<'a> {
    app: &'a AnalyzedApp,
    /// Latency matrix over *client sites*; servers occupy the first sites.
    sites: LatencyMatrix,
    cfg: BaselineConfig,
    clients: ClientGroups<'a, Ev>,
    servers: Vec<ServerGroup>,
}

impl<'a> BaselineSim<'a> {
    /// `sites` is the full client-site latency matrix (all five paper
    /// sites in the WAN experiments); clients spread over all of them
    /// regardless of how many servers the mode deploys. `gen` builds one
    /// generator per client group (the argument is the group index);
    /// rng-pure generators can ignore it.
    pub fn new(
        app: &'a AnalyzedApp,
        sites: LatencyMatrix,
        clients_cfg: ClientsConfig,
        cfg: BaselineConfig,
        gen: impl FnMut(usize) -> Box<dyn OpGenerator + 'a>,
    ) -> Self {
        let n_sites = sites.n();
        let n_servers = match cfg.mode {
            BaselineMode::Centralized => 1,
            BaselineMode::ReadOnly { n_servers } | BaselineMode::Warp { n_servers } => {
                n_servers.min(n_sites).max(1)
            }
        };
        let servers = (0..n_servers)
            .map(|id| ServerGroup {
                id,
                station: Station::new(cfg.workers),
                rng: Rng::stream(cfg.seed, id as u64),
                core: GroupCore::new(),
            })
            .collect();
        let clients = ClientGroups::new(clients_cfg, n_sites, cfg.warmup, cfg.horizon, gen);
        BaselineSim { app, sites, cfg, clients, servers }
    }

    /// The conservative lookahead: requests, replies and async
    /// replication all pay a one-way latency from the site matrix, so
    /// its minimum bounds every cross-group message (over-conservative
    /// if the tightest pair involves a server-less site — harmless, the
    /// window just gets narrower).
    fn lookahead(&self) -> VTime {
        self.sites.min_one_way()
    }

    pub fn run(mut self) -> BaselineReport {
        self.clients.boot();
        let lookahead = self.lookahead();
        let threads = parallel::resolve_threads(self.cfg.parallel);
        let horizon = self.cfg.horizon;

        let BaselineSim { app, sites, cfg, mut clients, mut servers } = self;
        let windows = {
            let ctx = Shared {
                app,
                sites: &sites,
                cfg: &cfg,
                n_servers: servers.len(),
                client_groups: clients.k(),
            };
            parallel::run_windows(
                threads,
                lookahead,
                horizon,
                &ctx,
                &mut servers,
                &mut clients.groups,
            )
        };

        let now = cfg.horizon;
        BaselineReport {
            metrics: clients.metrics(),
            utilization: servers.iter().map(|s| s.station.utilization(now)).collect(),
            events: clients.processed()
                + servers.iter().map(|s| s.core.q.processed()).sum::<u64>(),
            windows,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub metrics: SimMetrics,
    pub utilization: Vec<f64>,
    pub events: u64,
    /// Conservative windows the engine executed.
    pub windows: u64,
}

impl BaselineReport {
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput()
    }

    pub fn mean_latency_ms(&self) -> f64 {
        // Integer-sum mean: exact at any client-group count and defined
        // in bucketed-only mode too.
        self.metrics.mean_latency_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Schema, TableSchema, ValueType};
    use crate::db::{Bindings, Value};
    use crate::simnet::latency::Topology;
    use crate::workload::spec::{AppSpec, Operation, TxnTemplate};

    fn app() -> AnalyzedApp {
        let schema = Schema::new(vec![TableSchema::new(
            "T",
            &[("K", ValueType::Int), ("V", ValueType::Int)],
            &["K"],
        )]);
        let txns = vec![
            TxnTemplate::new("read", &["k"], &[("q", "SELECT V FROM T WHERE K = ?k")], 1.0),
            TxnTemplate::new(
                "write",
                &["k"],
                &[("u", "UPDATE T SET V = V + 1 WHERE K = ?k")],
                1.0,
            ),
        ];
        AnalyzedApp::analyze(AppSpec { name: "kv".into(), schema, txns })
    }

    struct Gen {
        write_ratio: f64,
    }

    impl OpGenerator for Gen {
        fn next_op(&mut self, rng: &mut Rng, _site: usize, _n: usize) -> Operation {
            let txn = if rng.chance(self.write_ratio) { 1 } else { 0 };
            let args: Bindings =
                [("k".to_string(), Value::Int(rng.range(0, 1000) as i64))].into_iter().collect();
            Operation { txn, args }
        }
    }

    fn run_par(
        mode: BaselineMode,
        clients: usize,
        write_ratio: f64,
        threads: usize,
    ) -> BaselineReport {
        let app = app();
        let cfg = BaselineConfig {
            mode,
            warmup: VTime::from_secs(2),
            horizon: VTime::from_secs(10),
            service: ServiceModel::fixed(5.0),
            parallel: threads,
            ..BaselineConfig::centralized()
        };
        BaselineSim::new(
            &app,
            Topology::wan_full_client(5),
            ClientsConfig { n: clients, think_ms: 50.0, seed: 2, ..Default::default() },
            cfg,
            move |_| Box::new(Gen { write_ratio }),
        )
        .run()
    }

    fn run(mode: BaselineMode, clients: usize, write_ratio: f64) -> BaselineReport {
        run_par(mode, clients, write_ratio, 1)
    }

    #[test]
    fn centralized_pays_wan_round_trips() {
        let r = run(BaselineMode::Centralized, 10, 0.3);
        // Mean latency must reflect WAN RTTs (G clients see ~20ms, A
        // clients ~314ms; the cross-site mean is large).
        let mean = r.mean_latency_ms();
        assert!(mean > 100.0, "mean={mean}");
        assert!(r.metrics.completed > 100);
    }

    #[test]
    fn read_only_replicas_cut_read_latency() {
        let cen = run(BaselineMode::Centralized, 10, 0.0);
        let ro = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 0.0);
        // Pure reads: every client hits its local replica.
        assert!(
            ro.mean_latency_ms() < cen.mean_latency_ms() / 3.0,
            "ro={} cen={}",
            ro.mean_latency_ms(),
            cen.mean_latency_ms()
        );
    }

    #[test]
    fn writes_still_pay_primary_round_trip() {
        let ro_reads = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 0.0);
        let ro_writes = run(BaselineMode::ReadOnly { n_servers: 5 }, 10, 1.0);
        assert!(
            ro_writes.mean_latency_ms() > ro_reads.mean_latency_ms() * 2.0,
            "writes={} reads={}",
            ro_writes.mean_latency_ms(),
            ro_reads.mean_latency_ms()
        );
    }

    #[test]
    fn centralized_saturates_with_load() {
        let light = run(BaselineMode::Centralized, 5, 0.3);
        let heavy = run(BaselineMode::Centralized, 1500, 0.3);
        // One 8-thread server at 5 ms/op sustains ~1600 ops/s; the heavy
        // run must sit near that ceiling with far higher latency.
        assert!(heavy.throughput() < 1750.0, "tput={}", heavy.throughput());
        assert!(heavy.mean_latency_ms() > 3.0 * light.mean_latency_ms());
        assert!(heavy.utilization[0] > 0.9, "util={:?}", heavy.utilization);
    }

    #[test]
    fn deterministic() {
        let a = run(BaselineMode::ReadOnly { n_servers: 3 }, 20, 0.2);
        let b = run(BaselineMode::ReadOnly { n_servers: 3 }, 20, 0.2);
        assert_eq!(a.metrics.completed, b.metrics.completed);
        assert_eq!(a.events, b.events);
    }

    /// The window-engine property, checked cheaply here and exhaustively
    /// in `tests/parallel_determinism.rs`: any thread count produces
    /// bit-identical results.
    #[test]
    fn thread_count_does_not_change_results() {
        let base = run_par(BaselineMode::ReadOnly { n_servers: 5 }, 40, 0.3, 1);
        for threads in [2usize, 0] {
            let r = run_par(BaselineMode::ReadOnly { n_servers: 5 }, 40, 0.3, threads);
            assert_eq!(r.metrics.completed, base.metrics.completed, "threads={threads}");
            assert_eq!(r.events, base.events, "threads={threads}");
            assert!(
                (r.mean_latency_ms() - base.mean_latency_ms()).abs() < 1e-12,
                "threads={threads}"
            );
        }
    }

    /// The client-group property: sharding the client tier into K
    /// groups (scheduled over any thread count) is bit-identical to the
    /// single-group, single-thread run. Exhaustive matrix in
    /// `tests/parallel_determinism.rs`.
    #[test]
    fn client_group_count_does_not_change_results() {
        let run_k = |groups: usize, threads: usize| {
            let app = app();
            let cfg = BaselineConfig {
                mode: BaselineMode::ReadOnly { n_servers: 5 },
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                parallel: threads,
                ..BaselineConfig::centralized()
            };
            BaselineSim::new(
                &app,
                Topology::wan_full_client(5),
                ClientsConfig { n: 20, think_ms: 50.0, seed: 2, groups, ..Default::default() },
                cfg,
                |_| Box::new(Gen { write_ratio: 0.3 }),
            )
            .run()
        };
        let base = run_k(1, 1);
        assert!(base.metrics.completed > 100, "completed={}", base.metrics.completed);
        for (groups, threads) in [(2, 1), (2, 2), (20, 0), (0, 0)] {
            let r = run_k(groups, threads);
            let tag = format!("groups={groups} threads={threads}");
            assert_eq!(r.metrics.completed, base.metrics.completed, "{tag}");
            assert_eq!(r.events, base.events, "{tag}");
            assert_eq!(r.windows, base.windows, "{tag}");
            assert_eq!(
                r.mean_latency_ms().to_bits(),
                base.mean_latency_ms().to_bits(),
                "{tag}"
            );
            assert_eq!(
                r.metrics.latency_hist.buckets(),
                base.metrics.latency_hist.buckets(),
                "{tag}"
            );
        }
    }

    /// Tentpole satellite: the open-loop client model produces an
    /// overload curve the closed-loop model cannot. At the same nominal
    /// per-client rate (1000/think = 20 ops/s), the closed loop
    /// self-limits — each client waits for its reply — while Poisson
    /// arrivals keep coming after the centralized server saturates, so
    /// throughput pins at the service ceiling and latency grows with
    /// the standing queue.
    #[test]
    fn open_loop_overload_is_distinct_from_closed_loop() {
        let app = app();
        let mk = |arrival_rate: Option<f64>| {
            let cfg = BaselineConfig {
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                ..BaselineConfig::centralized()
            };
            BaselineSim::new(
                &app,
                Topology::wan_full_client(5),
                ClientsConfig {
                    n: 100,
                    think_ms: 50.0,
                    seed: 2,
                    arrival_rate,
                    ..Default::default()
                },
                cfg,
                |_| Box::new(Gen { write_ratio: 0.3 }),
            )
            .run()
        };
        let closed = mk(None);
        // 100 clients × 20 ops/s = 2000 ops/s offered against an
        // 8-thread, 5 ms/op server (~1600 ops/s capacity).
        let open = mk(Some(20.0));
        // Closed loop self-limits well below capacity (WAN replies gate
        // each client's next issue)...
        assert!(closed.throughput() < 1000.0, "closed tput={}", closed.throughput());
        // ...the open loop pins the server at its ceiling...
        assert!(open.throughput() > 1400.0, "open tput={}", open.throughput());
        assert!(open.throughput() < 1750.0, "open tput={}", open.throughput());
        assert!(open.utilization[0] > 0.95, "util={:?}", open.utilization);
        // ...and queueing delay dwarfs the closed-loop latency.
        assert!(
            open.mean_latency_ms() > 3.0 * closed.mean_latency_ms(),
            "open={} closed={}",
            open.mean_latency_ms(),
            closed.mean_latency_ms()
        );
    }

    /// Satellite guard: the documented defaults the benches assume. A
    /// silent retuning would skew every recorded Fig-4/Table-3 curve.
    #[test]
    fn documented_defaults_match_bench_assumptions() {
        let c = BaselineConfig::centralized();
        assert_eq!(c.mode, BaselineMode::Centralized);
        assert_eq!(c.workers, 8);
        assert!((c.apply_ms - 0.5).abs() < 1e-12);
        assert_eq!(c.parallel, 1, "sequential by default; benches opt in");
        assert_eq!(c.warmup, VTime::from_secs(5));
        assert_eq!(c.horizon, VTime::from_secs(25));
        assert_eq!(c.seed, 0xBA5E);
        assert_eq!(BaselineConfig::read_only(3).mode, BaselineMode::ReadOnly { n_servers: 3 });
        assert_eq!(BaselineConfig::warp(3).mode, BaselineMode::Warp { n_servers: 3 });
    }

    /// Two tables so the read never conflicts with the global writer:
    /// `read` stays coordination-free while `gwrite` (opaque write
    /// target) is Global and must traverse Warp's validation chain.
    fn chain_app() -> AnalyzedApp {
        let schema = Schema::new(vec![
            TableSchema::new("T", &[("K", ValueType::Int), ("V", ValueType::Int)], &["K"]),
            TableSchema::new("S", &[("K", ValueType::Int), ("V", ValueType::Int)], &["K"]),
        ]);
        let txns = vec![
            TxnTemplate::new("read", &["k"], &[("q", "SELECT V FROM T WHERE K = ?k")], 1.0),
            TxnTemplate::new(
                "gwrite",
                &["k"],
                &[("u", "UPDATE S SET V = V + 1 WHERE K = ?derived")],
                1.0,
            ),
        ];
        let app = AnalyzedApp::analyze(AppSpec { name: "chain".into(), schema, txns });
        assert_eq!(*app.class(1), crate::analysis::OpClass::Global);
        app
    }

    /// Tentpole satellite: the Warp-style baseline. Single-partition ops
    /// never coordinate; multi-partition commits pay the acyclic chain —
    /// so their latency grows with the chain length, unlike Eliá where
    /// the token amortizes over every queued global.
    #[test]
    fn warp_chain_prices_multi_partition_commits() {
        let app = chain_app();
        let mk = |n: usize, write_ratio: f64| {
            let cfg = BaselineConfig {
                warmup: VTime::from_secs(2),
                horizon: VTime::from_secs(10),
                service: ServiceModel::fixed(5.0),
                ..BaselineConfig::warp(n)
            };
            BaselineSim::new(
                &app,
                Topology::wan_full_client(5),
                ClientsConfig { n: 20, think_ms: 50.0, seed: 2, ..Default::default() },
                cfg,
                move |_| Box::new(Gen { write_ratio }),
            )
            .run()
        };
        let w5 = mk(5, 0.3);
        assert!(w5.metrics.completed > 100);
        assert!(w5.metrics.global_latency.count() > 20, "chained commits must complete");
        // Reads run at their own partition: far cheaper than the chain.
        assert!(
            w5.metrics.global_latency.mean() > 3.0 * w5.metrics.local_latency.mean(),
            "chain={} local={}",
            w5.metrics.global_latency.mean(),
            w5.metrics.local_latency.mean()
        );
        // The chain cost scales with its length: a 1-server "chain" is
        // just a local commit at site 0.
        let w1 = mk(1, 0.3);
        assert!(
            w5.metrics.global_latency.mean() > w1.metrics.global_latency.mean() + 50.0,
            "w5={} w1={}",
            w5.metrics.global_latency.mean(),
            w1.metrics.global_latency.mean()
        );
        // Determinism at 2 threads, like every other mode.
        let again = mk(5, 0.3);
        assert_eq!(again.metrics.completed, w5.metrics.completed);
        assert_eq!(again.events, w5.events);
    }
}
