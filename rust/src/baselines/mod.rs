//! Single-server and read-only-optimized baselines (paper §7.2).

pub mod sim;

pub use sim::{BaselineConfig, BaselineMode, BaselineReport, BaselineSim};
