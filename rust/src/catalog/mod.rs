//! Table schemas (the "catalog") shared by the engine and the analysis.

use std::collections::HashMap;

/// Column data types. The engine coerces bound values into the declared
/// type on write, so storage stays uniformly typed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueType {
    Int,
    Float,
    Str,
}

/// A column definition.
#[derive(Debug, Clone)]
pub struct ColumnDef {
    pub name: String,
    pub ty: ValueType,
}

/// Schema of one table: ordered columns, primary-key columns (a prefix of
/// typical OLTP designs, but any subset is allowed), and secondary
/// single-column hash indexes.
#[derive(Debug, Clone)]
pub struct TableSchema {
    pub name: String,
    pub columns: Vec<ColumnDef>,
    pub primary_key: Vec<String>,
    pub indexes: Vec<String>,
}

impl TableSchema {
    pub fn new(name: &str, columns: &[(&str, ValueType)], primary_key: &[&str]) -> Self {
        TableSchema {
            name: name.to_string(),
            columns: columns
                .iter()
                .map(|(n, t)| ColumnDef { name: n.to_string(), ty: *t })
                .collect(),
            primary_key: primary_key.iter().map(|s| s.to_string()).collect(),
            indexes: Vec::new(),
        }
    }

    pub fn with_index(mut self, col: &str) -> Self {
        assert!(self.col_index(col).is_some(), "index on unknown column {col}");
        self.indexes.push(col.to_string());
        self
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.name.eq_ignore_ascii_case(name))
    }

    pub fn col_type(&self, name: &str) -> Option<ValueType> {
        self.col_index(name).map(|i| self.columns[i].ty)
    }

    /// Column indexes of the primary key, in declaration order.
    pub fn pk_indices(&self) -> Vec<usize> {
        self.primary_key
            .iter()
            .map(|c| self.col_index(c).expect("pk column must exist"))
            .collect()
    }

    pub fn ncols(&self) -> usize {
        self.columns.len()
    }
}

/// A database schema: a set of tables with stable integer ids.
#[derive(Debug, Clone, Default)]
pub struct Schema {
    tables: Vec<TableSchema>,
    by_name: HashMap<String, usize>,
}

impl Schema {
    pub fn new(tables: Vec<TableSchema>) -> Self {
        let mut by_name = HashMap::new();
        for (i, t) in tables.iter().enumerate() {
            let prev = by_name.insert(t.name.to_ascii_uppercase(), i);
            assert!(prev.is_none(), "duplicate table {}", t.name);
        }
        Schema { tables, by_name }
    }

    pub fn table_id(&self, name: &str) -> Option<usize> {
        self.by_name.get(&name.to_ascii_uppercase()).copied()
    }

    pub fn table(&self, id: usize) -> &TableSchema {
        &self.tables[id]
    }

    pub fn table_by_name(&self, name: &str) -> Option<&TableSchema> {
        self.table_id(name).map(|i| &self.tables[i])
    }

    pub fn tables(&self) -> &[TableSchema] {
        &self.tables
    }

    pub fn ntables(&self) -> usize {
        self.tables.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "ITEMS",
                &[("ID", ValueType::Int), ("TITLE", ValueType::Str), ("STOCK", ValueType::Int)],
                &["ID"],
            )
            .with_index("TITLE"),
            TableSchema::new(
                "CARTS",
                &[("ID", ValueType::Int), ("OWNER", ValueType::Int)],
                &["ID"],
            ),
        ])
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let s = sample();
        assert_eq!(s.table_id("items"), Some(0));
        assert_eq!(s.table_id("Carts"), Some(1));
        assert_eq!(s.table_id("NOPE"), None);
        assert_eq!(s.table(0).col_index("stock"), Some(2));
    }

    #[test]
    fn pk_indices_resolve() {
        let s = sample();
        assert_eq!(s.table(0).pk_indices(), vec![0]);
    }

    #[test]
    #[should_panic(expected = "duplicate table")]
    fn duplicate_table_panics() {
        Schema::new(vec![
            TableSchema::new("T", &[("A", ValueType::Int)], &["A"]),
            TableSchema::new("t", &[("A", ValueType::Int)], &["A"]),
        ]);
    }

    #[test]
    #[should_panic(expected = "unknown column")]
    fn index_on_unknown_column_panics() {
        let _ = TableSchema::new("T", &[("A", ValueType::Int)], &["A"]).with_index("B");
    }
}
