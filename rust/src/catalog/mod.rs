//! Table schemas (the "catalog") shared by the engine and the analysis.

use std::collections::HashMap;

/// Column data types. The engine coerces bound values into the declared
/// type on write, so storage stays uniformly typed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueType {
    Int,
    Float,
    Str,
}

/// A column definition.
#[derive(Debug, Clone)]
pub struct ColumnDef {
    pub name: String,
    pub ty: ValueType,
}

/// A declared data invariant on one table column. Invariants are the
/// input to the coordination-avoidance pass (`analysis::confluence`):
/// a pair of conflicting writes is mergeable without coordination only
/// when their worst-case composition provably preserves every declared
/// invariant (I-confluence, "Coordination Avoidance in Database
/// Systems"). The engine also enforces `NonNegative` at commit time
/// (bounded apply): a confluent decrement validates locally and aborts
/// instead of coordinating.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Invariant {
    /// The column value never drops below zero (escrow-style resource
    /// counter, e.g. stock levels).
    NonNegative { col: String },
    /// No two rows share a value in this column (uniqueness is enforced
    /// structurally when the column is the primary key / part of it:
    /// duplicate inserts abort locally).
    Unique { col: String },
    /// Every value in this column references an existing key of the
    /// `parent` table. Declared for completeness of the workload spec;
    /// the confluence pass treats it conservatively (never a merge
    /// licence on its own).
    ForeignKey { col: String, parent: String },
}

/// Schema of one table: ordered columns, primary-key columns (a prefix of
/// typical OLTP designs, but any subset is allowed), and secondary
/// single-column hash indexes.
#[derive(Debug, Clone)]
pub struct TableSchema {
    pub name: String,
    pub columns: Vec<ColumnDef>,
    pub primary_key: Vec<String>,
    pub indexes: Vec<String>,
    /// Declared per-column invariants (see [`Invariant`]). Empty by
    /// default: undeclared tables get the conservative (conflict-only)
    /// classification and no engine-side validation.
    pub invariants: Vec<Invariant>,
}

impl TableSchema {
    pub fn new(name: &str, columns: &[(&str, ValueType)], primary_key: &[&str]) -> Self {
        TableSchema {
            name: name.to_string(),
            columns: columns
                .iter()
                .map(|(n, t)| ColumnDef { name: n.to_string(), ty: *t })
                .collect(),
            primary_key: primary_key.iter().map(|s| s.to_string()).collect(),
            indexes: Vec::new(),
            invariants: Vec::new(),
        }
    }

    pub fn with_index(mut self, col: &str) -> Self {
        assert!(self.col_index(col).is_some(), "index on unknown column {col}");
        self.indexes.push(col.to_string());
        self
    }

    /// Declare that `col` must never go negative (escrow counter).
    pub fn with_nonnegative(mut self, col: &str) -> Self {
        assert!(self.col_index(col).is_some(), "invariant on unknown column {col}");
        self.invariants.push(Invariant::NonNegative { col: col.to_string() });
        self
    }

    /// Declare that `col` is unique across rows (duplicate inserts are
    /// rejected structurally — `col` must belong to the primary key).
    pub fn with_unique(mut self, col: &str) -> Self {
        assert!(self.col_index(col).is_some(), "invariant on unknown column {col}");
        assert!(
            self.primary_key.iter().any(|p| p.eq_ignore_ascii_case(col)),
            "Unique({col}) must be backed by the primary key — the engine only \
             enforces uniqueness structurally via duplicate-key aborts"
        );
        self.invariants.push(Invariant::Unique { col: col.to_string() });
        self
    }

    /// Declare a foreign key `col` → `parent` (documentary; the
    /// confluence pass never treats it as a merge licence).
    pub fn with_foreign_key(mut self, col: &str, parent: &str) -> Self {
        assert!(self.col_index(col).is_some(), "invariant on unknown column {col}");
        self.invariants
            .push(Invariant::ForeignKey { col: col.to_string(), parent: parent.to_string() });
        self
    }

    /// Is column `ci` covered by a `NonNegative` declaration?
    pub fn nonneg(&self, ci: usize) -> bool {
        self.invariants.iter().any(|inv| match inv {
            Invariant::NonNegative { col } => self.col_index(col) == Some(ci),
            _ => false,
        })
    }

    /// Is column `ci` covered by a `Unique` declaration?
    pub fn unique(&self, ci: usize) -> bool {
        self.invariants.iter().any(|inv| match inv {
            Invariant::Unique { col } => self.col_index(col) == Some(ci),
            _ => false,
        })
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.name.eq_ignore_ascii_case(name))
    }

    pub fn col_type(&self, name: &str) -> Option<ValueType> {
        self.col_index(name).map(|i| self.columns[i].ty)
    }

    /// Column indexes of the primary key, in declaration order.
    pub fn pk_indices(&self) -> Vec<usize> {
        self.primary_key
            .iter()
            .map(|c| self.col_index(c).expect("pk column must exist"))
            .collect()
    }

    pub fn ncols(&self) -> usize {
        self.columns.len()
    }
}

/// A database schema: a set of tables with stable integer ids.
#[derive(Debug, Clone, Default)]
pub struct Schema {
    tables: Vec<TableSchema>,
    by_name: HashMap<String, usize>,
}

impl Schema {
    pub fn new(tables: Vec<TableSchema>) -> Self {
        let mut by_name = HashMap::new();
        for (i, t) in tables.iter().enumerate() {
            let prev = by_name.insert(t.name.to_ascii_uppercase(), i);
            assert!(prev.is_none(), "duplicate table {}", t.name);
        }
        Schema { tables, by_name }
    }

    pub fn table_id(&self, name: &str) -> Option<usize> {
        self.by_name.get(&name.to_ascii_uppercase()).copied()
    }

    pub fn table(&self, id: usize) -> &TableSchema {
        &self.tables[id]
    }

    pub fn table_by_name(&self, name: &str) -> Option<&TableSchema> {
        self.table_id(name).map(|i| &self.tables[i])
    }

    pub fn tables(&self) -> &[TableSchema] {
        &self.tables
    }

    pub fn ntables(&self) -> usize {
        self.tables.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Schema {
        Schema::new(vec![
            TableSchema::new(
                "ITEMS",
                &[("ID", ValueType::Int), ("TITLE", ValueType::Str), ("STOCK", ValueType::Int)],
                &["ID"],
            )
            .with_index("TITLE"),
            TableSchema::new(
                "CARTS",
                &[("ID", ValueType::Int), ("OWNER", ValueType::Int)],
                &["ID"],
            ),
        ])
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let s = sample();
        assert_eq!(s.table_id("items"), Some(0));
        assert_eq!(s.table_id("Carts"), Some(1));
        assert_eq!(s.table_id("NOPE"), None);
        assert_eq!(s.table(0).col_index("stock"), Some(2));
    }

    #[test]
    fn pk_indices_resolve() {
        let s = sample();
        assert_eq!(s.table(0).pk_indices(), vec![0]);
    }

    #[test]
    #[should_panic(expected = "duplicate table")]
    fn duplicate_table_panics() {
        Schema::new(vec![
            TableSchema::new("T", &[("A", ValueType::Int)], &["A"]),
            TableSchema::new("t", &[("A", ValueType::Int)], &["A"]),
        ]);
    }

    #[test]
    #[should_panic(expected = "unknown column")]
    fn index_on_unknown_column_panics() {
        let _ = TableSchema::new("T", &[("A", ValueType::Int)], &["A"]).with_index("B");
    }

    #[test]
    fn invariant_declarations_resolve_by_column_index() {
        let t = TableSchema::new(
            "T",
            &[("ID", ValueType::Int), ("LEVEL", ValueType::Int), ("OWNER", ValueType::Int)],
            &["ID"],
        )
        .with_nonnegative("LEVEL")
        .with_unique("ID")
        .with_foreign_key("OWNER", "USERS");
        assert!(t.nonneg(1));
        assert!(!t.nonneg(0));
        assert!(t.unique(0));
        assert!(!t.unique(1));
        assert_eq!(t.invariants.len(), 3);
        // Undeclared tables stay invariant-free (the conservative default).
        let plain = TableSchema::new("U", &[("A", ValueType::Int)], &["A"]);
        assert!(plain.invariants.is_empty());
        assert!(!plain.nonneg(0));
    }

    #[test]
    #[should_panic(expected = "backed by the primary key")]
    fn unique_off_primary_key_panics() {
        let _ = TableSchema::new("T", &[("A", ValueType::Int), ("B", ValueType::Int)], &["A"])
            .with_unique("B");
    }
}
