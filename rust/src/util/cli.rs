//! Minimal command-line flag parsing for the `elia` binary, examples and
//! bench harnesses (stand-in for `clap`, unavailable offline).
//!
//! Supports `--flag`, `--key value`, and `--key=value` forms plus
//! positional arguments.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv\[0\]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (used in tests).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Typed getter with default; panics with a clear message on a
    /// malformed value (fail-fast is the right behaviour for a bench CLI).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?} as {}", std::any::type_name::<T>())),
        }
    }

    /// Integer getter that tolerates `_` digit separators, so scaling
    /// flags read naturally: `--clients 1_000_000`.
    pub fn get_count(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            None => default,
            Some(v) => v
                .replace('_', "")
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?} as a count")),
        }
    }

    /// Comma-separated list getter, e.g. `--servers 1,2,4,8`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad list element {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = parse(&["serve", "--servers", "4", "--verbose", "--mix=shopping"]);
        assert_eq!(a.command(), Some("serve"));
        assert_eq!(a.get("servers"), Some("4"));
        assert_eq!(a.get("mix"), Some("shopping"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "12", "--ratio", "0.5"]);
        assert_eq!(a.get_parse("n", 0usize), 12);
        assert!((a.get_parse("ratio", 0.0f64) - 0.5).abs() < 1e-12);
        assert_eq!(a.get_parse("missing", 7u32), 7);
    }

    #[test]
    fn count_getter_tolerates_underscores() {
        let a = parse(&["--clients", "1_000_000", "--plain", "42"]);
        assert_eq!(a.get_count("clients", 0), 1_000_000);
        assert_eq!(a.get_count("plain", 0), 42);
        assert_eq!(a.get_count("missing", 7), 7);
    }

    #[test]
    fn list_getter() {
        let a = parse(&["--servers", "1,2,4,8"]);
        assert_eq!(a.get_list("servers", &[0usize]), vec![1, 2, 4, 8]);
        assert_eq!(a.get_list::<usize>("absent", &[3]), vec![3]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_typed_value_panics() {
        let a = parse(&["--n", "abc"]);
        let _: usize = a.get_parse("n", 0);
    }
}
