//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 core: tiny, fast, passes BigCrush for our purposes
//! (workload generation and property testing), and — crucially for the
//! simulator — fully deterministic across runs and platforms.

/// A deterministic 64-bit PRNG (SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent child generator (used to give each simulated
    /// client its own stream so event interleaving does not perturb
    /// workloads).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Derive the `stream`-th independent generator of a seed *without*
    /// consuming state from a parent: `stream(seed, i)` always yields the
    /// same generator no matter when or on which thread it is created.
    ///
    /// This is what the parallel simulator uses for per-server RNG
    /// streams: every server owns `Rng::stream(cfg.seed, server_id)`, so
    /// the order in which servers are ticked (or the number of worker
    /// threads ticking them) cannot perturb any server's randomness.
    pub fn stream(seed: u64, stream: u64) -> Rng {
        // Scramble (seed, stream) through two SplitMix64 outputs so that
        // nearby seeds/stream-ids decorrelate; SplitMix64's output
        // function is a bijection, so distinct inputs stay distinct.
        let a = Rng::new(seed).next_u64();
        let b = Rng::new(stream ^ 0xA5A5_5A5A_C3C3_3C3C).next_u64();
        Rng::new(a ^ b.rotate_left(17))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, n)`. `n` must be non-zero.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; the
        // modulo bias is < 2^-32 for every n we use (n << 2^32).
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an index according to a weight vector (weights need not be
    /// normalized). Panics on an empty or all-zero vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() requires positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniform element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Exponentially distributed value with the given mean (used for
    /// Poisson inter-arrival times in open-loop workloads).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Zipf-distributed value in `[0, n)` with exponent `theta` (used for
    /// skewed key popularity in ablation workloads). Rejection-inversion
    /// is overkill here; we use the classic cumulative method with a
    /// cached normalizer for small n, and a power-law approximation for
    /// large n.
    pub fn zipf(&mut self, n: usize, theta: f64) -> usize {
        debug_assert!(n > 0);
        if theta <= 0.0 {
            return self.range(0, n);
        }
        // Inverse-CDF approximation of the zeta distribution.
        let u = self.f64().max(1e-12);
        let s = 1.0 - theta;
        if s.abs() < 1e-9 {
            // theta == 1: CDF ~ ln(k)/ln(n)
            let k = ((n as f64).powf(u)).floor() as usize;
            return k.min(n - 1);
        }
        let k = ((u * ((n as f64).powf(s) - 1.0) + 1.0).powf(1.0 / s) - 1.0).floor() as usize;
        k.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let n = 1 + r.next_u64() % 1000;
            assert!(r.gen_range(n) < n);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.weighted(&[1.0, 2.0, 3.0])] += 1;
        }
        assert!(counts[0] < counts[1] && counts[1] < counts[2]);
        // ~10k / 20k / 30k
        assert!((counts[0] as i64 - 10_000).abs() < 1000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..100_000).map(|_| r.exp(5.0)).sum::<f64>() / 100_000.0;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(13);
        let mut lo = 0;
        for _ in 0..10_000 {
            if r.zipf(1000, 0.99) < 10 {
                lo += 1;
            }
        }
        // With theta ~1, the first 10 of 1000 keys get a large share.
        assert!(lo > 2000, "lo={lo}");
        // theta = 0 degenerates to uniform
        let mut lo_u = 0;
        for _ in 0..10_000 {
            if r.zipf(1000, 0.0) < 10 {
                lo_u += 1;
            }
        }
        assert!(lo_u < 300, "lo_u={lo_u}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn stream_is_stateless_and_deterministic() {
        // Same (seed, stream) -> identical generator, regardless of how
        // many other streams were derived in between.
        let mut a = Rng::stream(42, 3);
        let _ = Rng::stream(42, 999);
        let mut b = Rng::stream(42, 3);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_decorrelate_across_ids_and_seeds() {
        let mut seen = std::collections::HashSet::new();
        for seed in 0..8u64 {
            for stream in 0..32u64 {
                let mut r = Rng::stream(seed, stream);
                assert!(seen.insert(r.next_u64()), "stream collision at ({seed},{stream})");
            }
        }
        // First outputs of adjacent streams should look uniform, not
        // clustered: check a crude mean over the unit interval.
        let mean: f64 =
            (0..1000).map(|i| Rng::stream(7, i).f64()).sum::<f64>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }
}
