//! Small self-contained utilities.
//!
//! The build environment vendors only the `xla` crate closure, so the
//! usual ecosystem crates (`rand`, `serde`, `proptest`, `clap`) are
//! implemented in-repo at the small scale this project needs:
//!
//! * [`rng`] — SplitMix64 PRNG + distribution helpers,
//! * [`stats`] — mean / percentiles / histograms / time-series,
//! * [`qcheck`] — a miniature property-testing harness,
//! * [`vtime`] — virtual-time types shared by the simulator,
//! * [`cli`] — flag parsing for the binary, examples and benches.

pub mod cli;
pub mod qcheck;
pub mod rng;
pub mod stats;
pub mod vtime;

pub use rng::Rng;
pub use stats::Summary;
pub use vtime::VTime;
