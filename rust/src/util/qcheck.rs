//! A miniature property-testing harness (stand-in for `proptest`, which
//! is not available in the offline build environment).
//!
//! Supports: seeded case generation through [`Rng`], a configurable number
//! of cases, and greedy input shrinking for `Vec`-shaped inputs. Failures
//! report the seed so a case can be replayed deterministically.
//!
//! ```no_run
//! use elia::util::qcheck::{check, Config};
//! check(Config::default().cases(200), |rng| {
//!     let n = rng.range(0, 1000);
//!     assert!(n < 1000);
//! });
//! ```

use super::rng::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub name: &'static str,
}

impl Default for Config {
    fn default() -> Self {
        // Honor QCHECK_SEED for replay, QCHECK_CASES for soak runs.
        let seed = std::env::var("QCHECK_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xE11A);
        let cases = std::env::var("QCHECK_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(100);
        Config { cases, seed, name: "property" }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn name(mut self, n: &'static str) -> Self {
        self.name = n;
        self
    }
}

/// Run `prop` against `cfg.cases` seeded generators. The property signals
/// failure by panicking (plain `assert!` works). On failure the harness
/// re-panics with the case seed embedded so the exact case can be replayed
/// with `QCHECK_SEED`.
pub fn check<F>(cfg: Config, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{}' failed at case {}/{} (case_seed={:#x}, run QCHECK_SEED={} QCHECK_CASES=1 to replay): {}",
                cfg.name, case + 1, cfg.cases, case_seed, case_seed, msg
            );
        }
    }
}

/// Outcome of one property invocation: `None` = passed; `Some(detail)` =
/// failed, carrying the panic message when the property signalled failure
/// by panicking (plain `assert!` works) rather than returning `false`.
fn prop_failure<T, F>(prop: &F, xs: &[T]) -> Option<String>
where
    T: std::panic::RefUnwindSafe,
    F: Fn(&[T]) -> bool + std::panic::RefUnwindSafe,
{
    match std::panic::catch_unwind(|| prop(xs)) {
        Ok(true) => None,
        Ok(false) => Some("property returned false".into()),
        Err(payload) => Some(
            payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into()),
        ),
    }
}

/// Run a property over generated `Vec<T>` inputs with greedy shrinking:
/// on failure, repeatedly try dropping chunks of the input while the
/// property still fails, then report the minimized counterexample along
/// with *its* failure message (not the original, larger case's).
pub fn check_vec<T, G, F>(cfg: Config, gen_item: G, max_len: usize, prop: F)
where
    T: Clone + std::fmt::Debug + std::panic::RefUnwindSafe,
    G: Fn(&mut Rng) -> T,
    F: Fn(&[T]) -> bool + std::panic::RefUnwindSafe,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        let len = rng.range(0, max_len + 1);
        let input: Vec<T> = (0..len).map(|_| gen_item(&mut rng)).collect();
        if prop_failure(&prop, &input).is_some() {
            let minimized = shrink(&input, &prop);
            let detail = prop_failure(&prop, &minimized)
                .unwrap_or_else(|| "<minimized case passes — flaky property?>".into());
            panic!(
                "property '{}' failed at case {}/{} (case_seed={:#x});\n  minimized input ({} items): {:?}\n  failure: {}",
                cfg.name,
                case + 1,
                cfg.cases,
                case_seed,
                minimized.len(),
                minimized,
                detail
            );
        }
    }
}

/// Greedy delta-debugging shrink: try removing halves, quarters, ... then
/// single elements, keeping any removal that still fails the property.
fn shrink<T, F>(input: &[T], prop: &F) -> Vec<T>
where
    T: Clone + std::panic::RefUnwindSafe,
    F: Fn(&[T]) -> bool + std::panic::RefUnwindSafe,
{
    let fails = |xs: &[T]| !std::panic::catch_unwind(|| prop(xs)).unwrap_or(false);
    let mut cur: Vec<T> = input.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 && !cur.is_empty() {
        let mut progressed = false;
        let mut start = 0;
        while start < cur.len() {
            let end = (start + chunk).min(cur.len());
            let mut candidate = Vec::with_capacity(cur.len() - (end - start));
            candidate.extend_from_slice(&cur[..start]);
            candidate.extend_from_slice(&cur[end..]);
            if fails(&candidate) {
                cur = candidate;
                progressed = true;
                // retry same offset with new (shorter) vector
            } else {
                start += chunk;
            }
        }
        if !progressed {
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(50).name("tautology"), |rng| {
            let x = rng.range(0, 10);
            assert!(x < 10);
        });
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_reports_seed() {
        check(Config::default().cases(5).name("always-false"), |_rng| {
            panic!("always-false");
        });
    }

    #[test]
    fn check_vec_passes_on_valid_property() {
        check_vec(
            Config::default().cases(30),
            |rng| rng.range(0, 100) as i64,
            20,
            |xs| xs.iter().all(|&x| x < 100),
        );
    }

    #[test]
    fn shrink_minimizes_to_single_culprit() {
        // Property: no element equals 7. Counterexample should shrink to [7].
        let input: Vec<i64> = vec![1, 2, 7, 3, 4, 5, 6];
        let minimized = shrink(&input, &|xs: &[i64]| !xs.contains(&7));
        assert_eq!(minimized, vec![7]);
    }

    #[test]
    #[should_panic(expected = "minimized input (1 items)")]
    fn check_vec_shrinks_failure() {
        check_vec(
            Config::default().cases(200).name("no-42"),
            |rng| rng.range(0, 50) as i64,
            30,
            |xs| !xs.contains(&42),
        );
    }

    #[test]
    #[should_panic(expected = "boom on 42")]
    fn check_vec_surfaces_inner_panic_message() {
        check_vec(
            Config::default().cases(200).name("panic-msg"),
            |rng| rng.range(0, 50) as i64,
            30,
            |xs| {
                assert!(!xs.contains(&42), "boom on 42");
                true
            },
        );
    }
}
