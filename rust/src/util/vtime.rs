//! Virtual time for the discrete-event simulator.
//!
//! All simulated timestamps, latencies and service times are expressed as
//! [`VTime`] — integer microseconds since the start of the simulation.
//! Integer micros keep event ordering exact (no float-comparison
//! nondeterminism) while giving sub-millisecond resolution, enough for
//! LAN latencies of a few hundred microseconds.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (microseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct VTime(pub u64);

impl VTime {
    pub const ZERO: VTime = VTime(0);

    pub fn from_micros(us: u64) -> Self {
        VTime(us)
    }

    pub fn from_millis(ms: u64) -> Self {
        VTime(ms * 1_000)
    }

    pub fn from_millis_f64(ms: f64) -> Self {
        VTime((ms * 1_000.0).round().max(0.0) as u64)
    }

    pub fn from_secs(s: u64) -> Self {
        VTime(s * 1_000_000)
    }

    pub fn as_micros(self) -> u64 {
        self.0
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    pub fn saturating_sub(self, other: VTime) -> VTime {
        VTime(self.0.saturating_sub(other.0))
    }
}

impl Add for VTime {
    type Output = VTime;
    fn add(self, rhs: VTime) -> VTime {
        VTime(self.0 + rhs.0)
    }
}

impl AddAssign for VTime {
    fn add_assign(&mut self, rhs: VTime) {
        self.0 += rhs.0;
    }
}

impl Sub for VTime {
    type Output = VTime;
    fn sub(self, rhs: VTime) -> VTime {
        VTime(self.0.checked_sub(rhs.0).expect("VTime underflow"))
    }
}

impl fmt::Display for VTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.2}ms", self.as_millis_f64())
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(VTime::from_millis(5).as_micros(), 5_000);
        assert_eq!(VTime::from_secs(2).as_millis_f64(), 2_000.0);
        assert_eq!(VTime::from_millis_f64(0.35).as_micros(), 350);
    }

    #[test]
    fn arithmetic_and_ordering() {
        let a = VTime::from_millis(10);
        let b = VTime::from_millis(3);
        assert_eq!((a + b).as_micros(), 13_000);
        assert_eq!((a - b).as_micros(), 7_000);
        assert!(b < a);
        assert_eq!(b.saturating_sub(a), VTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = VTime::from_millis(1) - VTime::from_millis(2);
    }

    #[test]
    fn display_formats() {
        assert_eq!(VTime::from_micros(12).to_string(), "12us");
        assert_eq!(VTime::from_millis(12).to_string(), "12.00ms");
        assert_eq!(VTime::from_secs(3).to_string(), "3.000s");
    }
}
