//! Latency / throughput statistics used by the experiment harness.

/// Accumulates scalar samples (typically latencies in milliseconds) and
/// reports summary statistics. Percentiles are exact (sorted copy) —
/// sample counts in this project stay far below the point where a sketch
/// would be needed.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile via nearest-rank on the sorted samples.
    /// `p` in `[0, 100]`.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = ((p / 100.0) * (self.samples.len() - 1) as f64).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }
}

/// A fixed-width histogram, used for latency distribution plots in
/// EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    width: f64,
    buckets: Vec<u64>,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram { lo, width: (hi - lo) / nbuckets as f64, buckets: vec![0; nbuckets], overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.buckets[0] += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Render a compact ASCII sparkline of the distribution.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&b| GLYPHS[(b * 7 / max) as usize])
            .collect()
    }
}

/// Throughput bookkeeping over a measurement window: completed operations
/// divided by window length.
#[derive(Debug, Clone, Default)]
pub struct Throughput {
    completed: u64,
    window_secs: f64,
}

impl Throughput {
    pub fn new(window_secs: f64) -> Self {
        Throughput { completed: 0, window_secs }
    }

    pub fn record(&mut self) {
        self.completed += 1;
    }

    pub fn record_n(&mut self, n: u64) {
        self.completed += n;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn ops_per_sec(&self) -> f64 {
        if self.window_secs <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.window_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn summary_merge() {
        let mut a = Summary::new();
        a.add(1.0);
        let mut b = Summary::new();
        b.add(3.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut s = Summary::new();
        for _ in 0..10 {
            s.add(4.0);
        }
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn histogram_counts_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, 25.0] {
            h.add(x);
        }
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 2);
        assert_eq!(h.buckets()[9], 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn throughput_math() {
        let mut t = Throughput::new(2.0);
        t.record_n(100);
        assert!((t.ops_per_sec() - 50.0).abs() < 1e-12);
    }
}
