//! `elia` — the command-line front end.
//!
//! ```text
//! elia analyze  --workload tpcw|rubis       static analysis report
//! elia serve    --workload tpcw --servers 4 real-threads deployment demo
//! elia bench    --exp fig3|fig4|fig5|fig6|table1|table3 [--quick]
//! elia doctor                               check PJRT + artifact health
//! ```

use elia::harness::experiments::{self, ExpScale, Workload};
use elia::harness::report;
use elia::util::cli::Args;

fn workload_of(args: &Args) -> Workload {
    match args.get_or("workload", "tpcw") {
        "rubis" => Workload::Rubis,
        _ => Workload::Tpcw,
    }
}

fn main() {
    let args = Args::from_env();
    match args.command() {
        Some("analyze") => {
            let w = workload_of(&args);
            let app = w.analyzed_with(!args.has("no-confluence"));
            let (l, g, c, lg, cf, ro, total) = app.table1_row();
            println!("{}: {total} transactions over {} tables", w.name(), app.spec.schema.ntables());
            println!(
                "classes: {l} local / {g} global / {c} commutative / {lg} local-global / {cf} confluent; {ro} read-only"
            );
            println!("partitioning cost: {:.1} (exact: {})", app.partitioning.cost, app.partitioning.exact);
            for (t, tpl) in app.spec.txns.iter().enumerate() {
                let routing: Vec<&str> = app.classification.routing_params[t]
                    .iter()
                    .map(|&k| tpl.params[k].as_str())
                    .collect();
                println!("  {:<24} {:<12} routes by {:?}", tpl.name, format!("{:?}", app.class(t)), routing);
            }
        }
        Some("bench") => {
            let scale = if args.has("quick") { ExpScale::quick() } else { ExpScale::full() };
            let w = workload_of(&args);
            match args.get_or("exp", "table1") {
                "table1" => {
                    let rows = if args.has("no-confluence") {
                        experiments::table1_with(false)
                    } else {
                        experiments::table1()
                    };
                    for row in rows {
                        println!("{row:?}");
                    }
                }
                "table3" => {
                    for (label, ms) in experiments::table3(w, &scale) {
                        println!("{label:<16} {ms:.0}ms");
                    }
                }
                "fig3" => {
                    let rows = experiments::fig3(w, &args.get_list("servers", &[1, 2, 4, 8]), &scale);
                    let table_rows: Vec<_> =
                        rows.iter()
                            .map(|(s, n, c)| {
                                // Render the all-points-violate fallback as a
                                // missing point, not a fake peak.
                                let p = c.peak(2000.0).and_then(|p| p.met_sla.then(|| p.point.clone()));
                                (s.clone(), *n, p)
                            })
                            .collect();
                    println!("{}", report::scalability_table(&table_rows, 2000.0));
                }
                "fig4" => {
                    let curves = experiments::fig4(w, args.get_parse("sites", 5), &scale);
                    println!("{}", report::curves_table(&curves));
                }
                "fig5" => {
                    let curves = experiments::fig5(&args.get_list("ratios", &[0.3, 0.6, 0.9]), &scale);
                    println!("{}", report::curves_table(&curves));
                }
                "fig6" => {
                    for row in experiments::fig6(&args.get_list("ratios", &[0.1, 0.5, 0.9]), 64, &scale) {
                        println!("{row:?}");
                    }
                }
                other => eprintln!("unknown experiment {other}"),
            }
        }
        Some("doctor") => {
            match elia::runtime::platform() {
                Ok(p) => println!("PJRT CPU client: ok ({p})"),
                Err(e) => println!("PJRT CPU client: FAILED ({e:#})"),
            }
            match elia::runtime::CostEvaluator::try_default() {
                Some(e) => println!("partition-cost artifact: ok (platform {})", e.platform()),
                None => println!("partition-cost artifact: missing — run `make artifacts`"),
            }
        }
        _ => {
            eprintln!(
                "usage: elia <analyze|bench|doctor> [--workload tpcw|rubis] [--exp fig3|...] [--quick] [--no-confluence]"
            );
            eprintln!("examples and bench binaries cover the full evaluation; see README.md");
        }
    }
}
