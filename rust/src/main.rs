//! `elia` — the command-line front end.
//!
//! ```text
//! elia analyze  --workload tpcw|rubis       static analysis report
//! elia serve    --workload tpcw --servers 3 [--port 7400] [--wal DIR]
//!                                           run a served cluster (TCP)
//! elia client   --workload tpcw --servers 3 [--port 7400] [--clients 4]
//!                                           [--ops 200] drive a cluster
//! elia bench    --exp fig3|fig4|fig5|fig6|table1|table3 [--quick]
//! elia doctor                               check PJRT + artifact health
//! ```

use elia::harness::experiments::{self, ExpScale, Workload};
use elia::harness::report;
use elia::net::{ClientConfig, Cluster, NetClient, NetError, ServeConfig, Tcp, Transport};
use elia::util::cli::Args;
use std::sync::Arc;

fn workload_of(args: &Args) -> Workload {
    match args.get_or("workload", "tpcw") {
        "rubis" => Workload::Rubis,
        _ => Workload::Tpcw,
    }
}

fn main() {
    let args = Args::from_env();
    match args.command() {
        Some("analyze") => {
            let w = workload_of(&args);
            let app = w.analyzed_with(!args.has("no-confluence"));
            let (l, g, c, lg, cf, ro, total) = app.table1_row();
            println!("{}: {total} transactions over {} tables", w.name(), app.spec.schema.ntables());
            println!(
                "classes: {l} local / {g} global / {c} commutative / {lg} local-global / {cf} confluent; {ro} read-only"
            );
            println!("partitioning cost: {:.1} (exact: {})", app.partitioning.cost, app.partitioning.exact);
            for (t, tpl) in app.spec.txns.iter().enumerate() {
                let routing: Vec<&str> = app.classification.routing_params[t]
                    .iter()
                    .map(|&k| tpl.params[k].as_str())
                    .collect();
                println!("  {:<24} {:<12} routes by {:?}", tpl.name, format!("{:?}", app.class(t)), routing);
            }
        }
        Some("bench") => {
            let scale = if args.has("quick") { ExpScale::quick() } else { ExpScale::full() };
            let w = workload_of(&args);
            match args.get_or("exp", "table1") {
                "table1" => {
                    let rows = if args.has("no-confluence") {
                        experiments::table1_with(false)
                    } else {
                        experiments::table1()
                    };
                    for row in rows {
                        println!("{row:?}");
                    }
                }
                "table3" => {
                    for (label, ms) in experiments::table3(w, &scale) {
                        println!("{label:<16} {ms:.0}ms");
                    }
                }
                "fig3" => {
                    let rows = experiments::fig3(w, &args.get_list("servers", &[1, 2, 4, 8]), &scale);
                    let table_rows: Vec<_> =
                        rows.iter()
                            .map(|(s, n, c)| {
                                // Render the all-points-violate fallback as a
                                // missing point, not a fake peak.
                                let p = c.peak(2000.0).and_then(|p| p.met_sla.then(|| p.point.clone()));
                                (s.clone(), *n, p)
                            })
                            .collect();
                    println!("{}", report::scalability_table(&table_rows, 2000.0));
                }
                "fig4" => {
                    let curves = experiments::fig4(w, args.get_parse("sites", 5), &scale);
                    println!("{}", report::curves_table(&curves));
                }
                "fig5" => {
                    let curves = experiments::fig5(&args.get_list("ratios", &[0.3, 0.6, 0.9]), &scale);
                    println!("{}", report::curves_table(&curves));
                }
                "fig6" => {
                    for row in experiments::fig6(&args.get_list("ratios", &[0.1, 0.5, 0.9]), 64, &scale) {
                        println!("{row:?}");
                    }
                }
                other => eprintln!("unknown experiment {other}"),
            }
        }
        Some("serve") => {
            let w = workload_of(&args);
            let n: usize = args.get_parse("servers", 3);
            let port: u16 = args.get_parse("port", 7400);
            let mut cfg = ServeConfig::tcp(n, port);
            if let Some(dir) = args.get("wal") {
                cfg.wal_dir = Some(std::path::PathBuf::from(dir));
            }
            let app = Arc::new(w.analyzed());
            let transport: Arc<dyn Transport> = Arc::new(Tcp);
            let cluster = match Cluster::start(app, cfg, transport, |db| w.seed_db(db)) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("serve failed: {e}");
                    std::process::exit(1);
                }
            };
            println!("serving {} on {} servers:", w.name(), n);
            for (p, addr) in cluster.client_addrs().iter().enumerate() {
                println!("  server {p}: {addr}");
            }
            println!("(ctrl-c to stop)");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Some("client") => {
            let w = workload_of(&args);
            let n: usize = args.get_parse("servers", 3);
            let port: u16 = args.get_parse("port", 7400);
            let clients: usize = args.get_parse("clients", 4);
            let ops: u64 = args.get_parse("ops", 200);
            let app = Arc::new(w.analyzed());
            let addrs: Vec<String> =
                (0..n).map(|p| format!("127.0.0.1:{}", port + 2 * p as u16)).collect();
            let start = std::time::Instant::now();
            let mut handles = Vec::new();
            for g in 0..clients {
                let app = Arc::clone(&app);
                let addrs = addrs.clone();
                handles.push(std::thread::spawn(move || {
                    let transport: Arc<dyn Transport> = Arc::new(Tcp);
                    let mut client =
                        NetClient::connect(Arc::clone(&app), transport, addrs, ClientConfig::default())
                            .unwrap_or_else(|e| {
                                eprintln!("connect failed: {e}");
                                std::process::exit(1);
                            });
                    let mut generator = w.generator_for(&app, n, g);
                    let mut rng = elia::util::Rng::stream(0xF16, g as u64);
                    let (mut ok, mut errs) = (0u64, 0u64);
                    for _ in 0..ops {
                        let op = generator.next_op(&mut rng, g % n, n);
                        match client.submit(&op) {
                            Ok(_) => ok += 1,
                            Err(NetError::Server(_)) => errs += 1,
                            Err(NetError::Transport(e)) => {
                                eprintln!("transport failure: {e}");
                                std::process::exit(1);
                            }
                        }
                    }
                    (ok, errs, client.retries)
                }));
            }
            let (mut ok, mut errs, mut retries) = (0u64, 0u64, 0u64);
            for h in handles {
                let (o, e, r) = h.join().expect("client thread");
                ok += o;
                errs += e;
                retries += r;
            }
            let secs = start.elapsed().as_secs_f64();
            println!(
                "{ok} ops in {secs:.2}s ({:.0} ops/s), {errs} semantic errors, {retries} retries",
                ok as f64 / secs.max(1e-9)
            );
        }
        Some("doctor") => {
            match elia::runtime::platform() {
                Ok(p) => println!("PJRT CPU client: ok ({p})"),
                Err(e) => println!("PJRT CPU client: FAILED ({e:#})"),
            }
            match elia::runtime::CostEvaluator::try_default() {
                Some(e) => println!("partition-cost artifact: ok (platform {})", e.platform()),
                None => println!("partition-cost artifact: missing — run `make artifacts`"),
            }
        }
        _ => {
            eprintln!(
                "usage: elia <analyze|serve|client|bench|doctor> [--workload tpcw|rubis] [--servers N] [--port P] [--exp fig3|...] [--quick] [--no-confluence]"
            );
            eprintln!("examples and bench binaries cover the full evaluation; see README.md");
        }
    }
}
