//! Transaction state and error types.

use super::lockmgr::LockError;
use super::update::StateUpdate;
use super::value::{Key, Row};
use std::collections::HashMap;

/// Isolation levels the engine offers.
///
/// * `Serializable` — strict 2PL with table-level scan locks: what Eliá
///   requires from its local DBMS (paper §5 assumes pessimistic locking).
/// * `ReadCommitted` — reads take no locks and observe the latest
///   committed state; writes still take exclusive locks. This is the only
///   level MySQL Cluster offers and is what the data-partitioning
///   baseline runs with (paper §7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationLevel {
    Serializable,
    ReadCommitted,
}

/// Errors surfaced to transaction code.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum TxnError {
    /// Wait-die abort or lock timeout; the caller should retry the whole
    /// transaction (the harness and Conveyor Belt servers do).
    #[error("lock conflict: {0}")]
    Lock(#[from] LockError),
    #[error("duplicate primary key {key} in table {table}")]
    DuplicateKey { table: String, key: String },
    #[error("sql error: {0}")]
    Sql(String),
    #[error("transaction already finished")]
    Finished,
}

impl TxnError {
    /// True when retrying the transaction may succeed (concurrency
    /// victim), false for semantic errors.
    pub fn is_retryable(&self) -> bool {
        matches!(self, TxnError::Lock(_))
    }
}

/// The buffered, not-yet-committed effects of a running transaction.
#[derive(Debug, Default)]
pub struct TxnState {
    /// Write overlay: `Some(row)` = inserted/updated image, `None` =
    /// deleted. Reads go through this before committed storage.
    pub overlay: HashMap<(usize, Key), Option<Row>>,
    /// Ordered redo log — becomes the operation's [`StateUpdate`].
    pub update: StateUpdate,
}

impl TxnState {
    pub fn visible<'a>(
        &'a self,
        table: usize,
        key: &Key,
        committed: Option<&'a Row>,
    ) -> Option<&'a Row> {
        match self.overlay.get(&(table, key.clone())) {
            Some(Some(row)) => Some(row),
            Some(None) => None,
            None => committed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::value::Value;

    #[test]
    fn overlay_precedence() {
        let mut st = TxnState::default();
        let key = Key::single(Value::Int(1));
        let committed = vec![Value::Int(1), Value::Int(10)];

        // No overlay: committed row visible.
        assert_eq!(st.visible(0, &key, Some(&committed)), Some(&committed));

        // Updated: overlay image wins.
        let img = vec![Value::Int(1), Value::Int(99)];
        st.overlay.insert((0, key.clone()), Some(img.clone()));
        assert_eq!(st.visible(0, &key, Some(&committed)), Some(&img));

        // Deleted: nothing visible even though committed exists.
        st.overlay.insert((0, key.clone()), None);
        assert_eq!(st.visible(0, &key, Some(&committed)), None);
    }

    #[test]
    fn retryability() {
        use crate::db::lockmgr::LockError;
        assert!(TxnError::Lock(LockError::Aborted { txn: 1, target: "t".into() }).is_retryable());
        assert!(!TxnError::Sql("boom".into()).is_retryable());
    }
}
