//! Transaction state and error types.

use super::lockmgr::LockError;
use super::update::StateUpdate;
use super::value::{Key, Row};
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

/// Isolation levels the engine offers.
///
/// * `Serializable` — strict 2PL with table-level scan locks: what Eliá
///   requires from its local DBMS (paper §5 assumes pessimistic locking).
/// * `ReadCommitted` — reads take no locks and observe the latest
///   committed state; writes still take exclusive locks. This is the only
///   level MySQL Cluster offers and is what the data-partitioning
///   baseline runs with (paper §7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationLevel {
    /// Strict 2PL with table-level scan locks (full serializability).
    Serializable,
    /// Lock-free reads of the latest committed state.
    ReadCommitted,
}

/// Errors surfaced to transaction code.
#[derive(Debug, Clone, PartialEq)]
pub enum TxnError {
    /// Wait-die abort or lock timeout; the caller should retry the whole
    /// transaction (the harness and Conveyor Belt servers do).
    Lock(LockError),
    /// INSERT collided with an existing primary key.
    DuplicateKey {
        /// Table name.
        table: String,
        /// Rendered key value.
        key: String,
    },
    /// Semantic SQL error (unknown column, unbound parameter, ...).
    Sql(String),
    /// The transaction handle was already committed or aborted.
    Finished,
    /// The write-ahead log could not persist or recover a commit record
    /// (I/O error, corrupt log). Carries the rendered `io::Error` so the
    /// variant stays `Clone`. Not retryable: once an append fails the
    /// log is poisoned and every later commit fails too (see
    /// [`crate::db::wal::Wal`]).
    Durability(String),
    /// A declared schema invariant would be violated by this write (the
    /// bounded-apply check: e.g. a `NonNegative` column driven below
    /// zero). Confluent operations rely on this local validation instead
    /// of coordinating — the abort is semantic, not a concurrency
    /// victim, so it is not retryable.
    Invariant {
        /// Table name.
        table: String,
        /// Violated column name.
        column: String,
        /// Rendered post-image value that failed validation.
        value: String,
    },
    /// The request was routed under a superseded routing epoch (live
    /// re-partitioning, `analysis::drift`): the server's installed epoch
    /// homes the operation elsewhere. Retryable — the client refreshes
    /// its epoch (re-handshake) and re-routes; the operation was not
    /// executed.
    StaleEpoch {
        /// The epoch version installed at the rejecting server.
        installed: u64,
    },
}

impl fmt::Display for TxnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TxnError::Lock(e) => write!(f, "lock conflict: {e}"),
            TxnError::DuplicateKey { table, key } => {
                write!(f, "duplicate primary key {key} in table {table}")
            }
            TxnError::Sql(msg) => write!(f, "sql error: {msg}"),
            TxnError::Finished => write!(f, "transaction already finished"),
            TxnError::Durability(msg) => write!(f, "durability error: {msg}"),
            TxnError::Invariant { table, column, value } => {
                write!(f, "invariant violation: {table}.{column} = {value}")
            }
            TxnError::StaleEpoch { installed } => {
                write!(f, "stale routing epoch: server is on epoch {installed}")
            }
        }
    }
}

impl std::error::Error for TxnError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TxnError::Lock(e) => Some(e),
            _ => None,
        }
    }
}

impl From<LockError> for TxnError {
    fn from(e: LockError) -> Self {
        TxnError::Lock(e)
    }
}

/// Coarse retryability classification of a [`TxnError`], carried over
/// the wire (`net::proto`) so remote client stubs can auto-retry without
/// matching on every variant. [`Deployment::submit`] callers get the
/// same signal via [`TxnError::classify`].
///
/// [`Deployment::submit`]: crate::conveyor::Deployment::submit
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Retryable {
    /// A concurrency victim (wait-die abort, lock timeout): retrying the
    /// whole transaction may succeed — the Conveyor Belt servers and the
    /// net client stub do, with capped backoff.
    Transient,
    /// A semantic or environmental failure (SQL error, duplicate key,
    /// violated invariant, poisoned WAL): retrying cannot succeed and
    /// must surface to the caller.
    Fatal,
}

impl TxnError {
    /// True when retrying the transaction may succeed (concurrency
    /// victim), false for semantic errors.
    pub fn is_retryable(&self) -> bool {
        matches!(self, TxnError::Lock(_) | TxnError::StaleEpoch { .. })
    }

    /// Classify this error for retry loops: [`Retryable::Transient`] iff
    /// [`TxnError::is_retryable`] ([`TxnError::Lock`],
    /// [`TxnError::StaleEpoch`]), [`Retryable::Fatal`] otherwise
    /// ([`TxnError::Invariant`], [`TxnError::Sql`],
    /// [`TxnError::DuplicateKey`], [`TxnError::Durability`],
    /// [`TxnError::Finished`]).
    pub fn classify(&self) -> Retryable {
        if self.is_retryable() {
            Retryable::Transient
        } else {
            Retryable::Fatal
        }
    }
}

/// The buffered, not-yet-committed effects of a running transaction.
///
/// Rows are shared via `Arc`: the read path hands out handles into
/// committed storage without deep-cloning; a write clones the row once
/// (copy-on-write) when it builds the new image.
#[derive(Debug, Default)]
pub struct TxnState {
    /// Write overlay per table: `Some(row)` = inserted/updated image,
    /// `None` = deleted. Reads go through this before committed storage.
    pub overlay: HashMap<usize, HashMap<Key, Option<Arc<Row>>>>,
    /// Ordered redo log — becomes the operation's [`StateUpdate`].
    pub update: StateUpdate,
}

impl TxnState {
    /// Record an overlay image for `(table, key)`.
    pub fn overlay_put(&mut self, table: usize, key: Key, img: Option<Arc<Row>>) {
        self.overlay.entry(table).or_default().insert(key, img);
    }

    /// The overlay entries of one table (scan/index paths).
    pub fn overlay_table(&self, table: usize) -> Option<&HashMap<Key, Option<Arc<Row>>>> {
        self.overlay.get(&table)
    }

    /// The row image visible to this transaction: its own overlay first,
    /// then the committed row. No key clone, no row clone.
    pub fn visible<'a>(
        &'a self,
        table: usize,
        key: &Key,
        committed: Option<&'a Arc<Row>>,
    ) -> Option<&'a Arc<Row>> {
        if self.overlay.is_empty() {
            return committed;
        }
        match self.overlay.get(&table).and_then(|m| m.get(key)) {
            Some(Some(row)) => Some(row),
            Some(None) => None,
            None => committed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::value::Value;

    #[test]
    fn overlay_precedence() {
        let mut st = TxnState::default();
        let key = Key::single(Value::Int(1));
        let committed = Arc::new(vec![Value::Int(1), Value::Int(10)]);

        // No overlay: committed row visible.
        assert_eq!(st.visible(0, &key, Some(&committed)), Some(&committed));

        // Updated: overlay image wins.
        let img = Arc::new(vec![Value::Int(1), Value::Int(99)]);
        st.overlay_put(0, key.clone(), Some(Arc::clone(&img)));
        assert_eq!(st.visible(0, &key, Some(&committed)), Some(&img));

        // Deleted: nothing visible even though committed exists.
        st.overlay_put(0, key.clone(), None);
        assert_eq!(st.visible(0, &key, Some(&committed)), None);

        // Other tables unaffected.
        assert_eq!(st.visible(1, &key, Some(&committed)), Some(&committed));
    }

    #[test]
    fn retryability() {
        use crate::db::lockmgr::LockError;
        assert!(TxnError::Lock(LockError::Aborted { txn: 1, target: "t".into() }).is_retryable());
        assert!(TxnError::StaleEpoch { installed: 3 }.is_retryable());
        assert!(!TxnError::Sql("boom".into()).is_retryable());
    }

    #[test]
    fn classification_matches_retryability() {
        use crate::db::lockmgr::LockError;
        let lock = TxnError::Lock(LockError::Aborted { txn: 1, target: "t".into() });
        assert_eq!(lock.classify(), Retryable::Transient);
        // An epoch misroute is a routing race, not a semantic failure:
        // the client re-handshakes and retries under the new epoch.
        assert_eq!(TxnError::StaleEpoch { installed: 1 }.classify(), Retryable::Transient);
        for fatal in [
            TxnError::Sql("boom".into()),
            TxnError::DuplicateKey { table: "T".into(), key: "1".into() },
            TxnError::Finished,
            TxnError::Durability("disk".into()),
            TxnError::Invariant { table: "T".into(), column: "C".into(), value: "-1".into() },
        ] {
            assert_eq!(fatal.classify(), Retryable::Fatal, "{fatal:?}");
        }
    }
}
