//! Write-ahead logging for the engine's logical redo stream.
//!
//! The engine already produces a replayable description of every commit
//! — the [`StateUpdate`] the Conveyor Belt ships between servers (paper
//! §5). Durability is the same stream pointed at a file: each commit
//! appends one checksummed binary record *while the transaction still
//! holds all its locks*, so the log order is a strict-2PL serialization
//! order and recovery is exactly the replica replay path
//! ([`crate::db::Db::apply_update`]) reading from disk instead of from
//! the token.
//!
//! ## On-disk format
//!
//! ```text
//! "ELIAWAL1"                                      8-byte magic
//! repeated:  [len: u32 LE] [fnv1a64(payload): u64 LE] [payload]
//! ```
//!
//! The payload encodes one [`StateUpdate`] (record count, then per
//! [`WriteRecord`] a kind tag, table index, key values, and the
//! row/column payload; values are tag-prefixed little-endian). A record
//! is *committed* iff its length, checksum and payload are all intact;
//! recovery replays the longest intact prefix and truncates the rest —
//! a torn tail from a crash mid-write loses only commits that were
//! never acknowledged under [`SyncPolicy::Always`].
//!
//! ## Group commit
//!
//! Under [`SyncPolicy::Always`] concurrent committers batch their
//! fsyncs: every appender buffers its record under the mutex, then
//! either becomes the *leader* (writes + fsyncs everything buffered so
//! far, including records that arrived while the previous leader was
//! syncing) or waits on a condvar until a leader's sync covers its
//! record. One fsync thus acknowledges many commits under load while
//! every acknowledged commit is on disk. [`SyncPolicy::Batch`] keeps
//! records in user-space memory and only writes + syncs every n-th
//! commit — an in-process crash genuinely loses the unflushed tail,
//! which is what the kill-and-recover tests simulate. [`SyncPolicy::Os`]
//! writes every record to the OS but never syncs.

use super::txn::TxnError;
use super::update::{ColOp, StateUpdate, WriteRecord};
use super::value::{Key, Value};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Condvar, Mutex, MutexGuard};

/// File header identifying an Eliá WAL (and its format version).
const MAGIC: &[u8; 8] = b"ELIAWAL1";

/// Bytes of per-record framing: u32 payload length + u64 checksum.
const FRAME: usize = 12;

fn io_err(e: std::io::Error) -> TxnError {
    TxnError::Durability(e.to_string())
}

/// FNV-1a over the record payload. Not cryptographic — it guards
/// against torn writes and bit rot, not adversaries — but it is
/// dependency-free and byte-order independent.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// When appended records are made durable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync before every commit acknowledgment, amortized by group
    /// commit: concurrent committers share one fsync. No acknowledged
    /// commit is ever lost. The default.
    Always,
    /// Buffer records in user space and write + fsync every n-th
    /// append. A crash loses at most `n - 1` of the latest acknowledged
    /// commits — the classic throughput/durability trade.
    Batch(usize),
    /// Write every record to the OS page cache, never fsync. Survives
    /// process death, not power loss.
    Os,
}

impl SyncPolicy {
    /// The policy selected by the `ELIA_WAL_BATCH` environment variable:
    /// unset, `1` or garbage → [`SyncPolicy::Always`]; an integer
    /// `n > 1` → [`SyncPolicy::Batch`]`(n)`; `os` → [`SyncPolicy::Os`].
    pub fn from_env() -> SyncPolicy {
        Self::parse(std::env::var("ELIA_WAL_BATCH").ok().as_deref())
    }

    fn parse(v: Option<&str>) -> SyncPolicy {
        match v {
            Some(s) if s.trim().eq_ignore_ascii_case("os") => SyncPolicy::Os,
            Some(s) => match s.trim().parse::<usize>() {
                Ok(n) if n > 1 => SyncPolicy::Batch(n),
                _ => SyncPolicy::Always,
            },
            None => SyncPolicy::Always,
        }
    }
}

/// Where and how a [`crate::db::Db`] persists its redo stream. Off by
/// default: a `Db` built without one of these never touches a file, so
/// simulators and hot-path benches are byte-identical to the pre-WAL
/// engine.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Log file path.
    pub path: PathBuf,
    /// Sync policy (see [`SyncPolicy::from_env`] for the env knob).
    pub policy: SyncPolicy,
}

impl DurabilityConfig {
    /// A config for `path` with the policy taken from `ELIA_WAL_BATCH`.
    pub fn new(path: impl Into<PathBuf>) -> DurabilityConfig {
        DurabilityConfig { path: path.into(), policy: SyncPolicy::from_env() }
    }

    /// Override the sync policy.
    pub fn with_policy(mut self, policy: SyncPolicy) -> DurabilityConfig {
        self.policy = policy;
        self
    }
}

/// Shared appender state behind the mutex.
#[derive(Debug, Default)]
struct WalState {
    /// Encoded records accepted but not yet handed to the OS.
    buf: Vec<u8>,
    /// Records currently in `buf` (drives [`SyncPolicy::Batch`]).
    buffered: usize,
    /// Sequence number of the last accepted record.
    next_seq: u64,
    /// Sequence number through which records are flushed per the
    /// policy's durability promise.
    synced_seq: u64,
    /// A group-commit leader is writing outside the mutex.
    leader: bool,
    /// Sticky first I/O failure; every later append fails with it.
    failed: Option<String>,
}

/// An open write-ahead log. Appends are thread-safe (`&self`); the
/// engine calls [`Wal::append`] from [`crate::db::TxnHandle::commit`]
/// and from the replica replay path while the committing transaction
/// still holds its locks.
#[derive(Debug)]
pub struct Wal {
    file: File,
    policy: SyncPolicy,
    state: Mutex<WalState>,
    synced: Condvar,
}

impl Wal {
    /// Create (or truncate) the log at `cfg.path` and write the header.
    pub fn create(cfg: &DurabilityConfig) -> Result<Wal, TxnError> {
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&cfg.path)
            .map_err(io_err)?;
        file.write_all(MAGIC).map_err(io_err)?;
        file.sync_data().map_err(io_err)?;
        Ok(Wal::with_file(file, cfg.policy))
    }

    /// Open an existing log for appending — the post-recovery path,
    /// after [`recover_log`] has verified the contents and truncated
    /// any torn tail.
    pub fn open_append(cfg: &DurabilityConfig) -> Result<Wal, TxnError> {
        let mut file =
            OpenOptions::new().read(true).write(true).open(&cfg.path).map_err(io_err)?;
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic).map_err(io_err)?;
        if &magic != MAGIC {
            return Err(TxnError::Durability(format!(
                "{}: not an Eliá WAL (bad magic)",
                cfg.path.display()
            )));
        }
        file.seek(SeekFrom::End(0)).map_err(io_err)?;
        Ok(Wal::with_file(file, cfg.policy))
    }

    fn with_file(file: File, policy: SyncPolicy) -> Wal {
        Wal {
            file,
            policy,
            state: Mutex::new(WalState::default()),
            synced: Condvar::new(),
        }
    }

    /// Append one commit's records and return once the policy's
    /// durability promise holds for them. Errors are sticky: after the
    /// first I/O failure every append fails, so callers can't commit
    /// past a dead disk.
    pub fn append(&self, update: &StateUpdate) -> Result<(), TxnError> {
        let mut payload = Vec::with_capacity(64);
        encode_update(&mut payload, update);
        let sum = fnv1a(&payload);

        let mut st = self.state.lock().unwrap();
        if let Some(m) = &st.failed {
            return Err(TxnError::Durability(m.clone()));
        }
        st.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        st.buf.extend_from_slice(&sum.to_le_bytes());
        st.buf.extend_from_slice(&payload);
        st.next_seq += 1;
        st.buffered += 1;
        let my_seq = st.next_seq;

        match self.policy {
            SyncPolicy::Os => self.write_buffered(st, false).map(|_| ()),
            SyncPolicy::Batch(n) => {
                if st.buffered >= n {
                    self.write_buffered(st, true).map(|_| ())
                } else {
                    Ok(())
                }
            }
            SyncPolicy::Always => self.group_commit(st, my_seq),
        }
    }

    /// Drain `buf` to the file under the mutex (the non-group-commit
    /// policies; no leader can be in flight). Optionally fsync.
    fn write_buffered(
        &self,
        mut st: MutexGuard<'_, WalState>,
        sync: bool,
    ) -> Result<u64, TxnError> {
        debug_assert!(!st.leader, "write_buffered raced a group-commit leader");
        let batch = std::mem::take(&mut st.buf);
        st.buffered = 0;
        let through = st.next_seq;
        let res = (&self.file)
            .write_all(&batch)
            .and_then(|()| if sync { self.file.sync_data() } else { Ok(()) });
        match res {
            Ok(()) => {
                st.synced_seq = st.synced_seq.max(through);
                Ok(through)
            }
            Err(e) => {
                let msg = e.to_string();
                st.failed = Some(msg.clone());
                Err(TxnError::Durability(msg))
            }
        }
    }

    /// Group commit: become the leader (write + fsync everything
    /// buffered) or wait until a leader's sync covers `my_seq`.
    fn group_commit(
        &self,
        mut st: MutexGuard<'_, WalState>,
        my_seq: u64,
    ) -> Result<(), TxnError> {
        loop {
            if let Some(m) = &st.failed {
                return Err(TxnError::Durability(m.clone()));
            }
            if st.synced_seq >= my_seq {
                return Ok(());
            }
            if st.leader {
                st = self.synced.wait(st).unwrap();
                continue;
            }
            st.leader = true;
            let batch = std::mem::take(&mut st.buf);
            st.buffered = 0;
            let through = st.next_seq;
            drop(st);
            let res = (&self.file).write_all(&batch).and_then(|()| self.file.sync_data());
            st = self.state.lock().unwrap();
            st.leader = false;
            match res {
                Ok(()) => st.synced_seq = st.synced_seq.max(through),
                Err(e) => st.failed = Some(e.to_string()),
            }
            self.synced.notify_all();
        }
    }

    /// Force everything appended so far to disk regardless of policy —
    /// the clean-shutdown path for [`SyncPolicy::Batch`]/[`SyncPolicy::Os`].
    pub fn flush(&self) -> Result<(), TxnError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = &st.failed {
                return Err(TxnError::Durability(m.clone()));
            }
            if !st.leader {
                break;
            }
            st = self.synced.wait(st).unwrap();
        }
        self.write_buffered(st, true).map(|_| ())
    }

    /// Records accepted by [`Wal::append`] so far.
    pub fn appended(&self) -> u64 {
        self.state.lock().unwrap().next_seq
    }

    /// Records covered by the policy's flush promise so far (equal to
    /// [`Wal::appended`] under [`SyncPolicy::Always`]; lags by the
    /// in-memory tail under [`SyncPolicy::Batch`]).
    pub fn durable(&self) -> u64 {
        self.state.lock().unwrap().synced_seq
    }
}

/// What [`recover_log`] found and did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RecoveryReport {
    /// Intact committed records decoded and returned for replay.
    pub replayed: usize,
    /// Torn-tail bytes discarded (0 for a cleanly closed log).
    pub truncated_bytes: u64,
    /// Bytes of intact log retained, including the magic header.
    pub valid_bytes: u64,
}

/// Read a WAL, verify record framing and checksums, truncate any torn
/// tail in place, and return the committed [`StateUpdate`]s in commit
/// order for replay.
///
/// A record whose frame runs past end-of-file or whose checksum does
/// not match its payload marks the torn tail: everything from it onward
/// is an unacknowledged partial write and is dropped (the file is
/// truncated so the next append starts at a clean boundary). A record
/// whose checksum *matches* but which does not decode is real
/// corruption, not a torn write, and is a hard error.
pub fn recover_log(path: &Path) -> Result<(Vec<StateUpdate>, RecoveryReport), TxnError> {
    let mut file = OpenOptions::new().read(true).write(true).open(path).map_err(io_err)?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes).map_err(io_err)?;
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return Err(TxnError::Durability(format!(
            "{}: not an Eliá WAL (bad magic)",
            path.display()
        )));
    }

    let mut pos = MAGIC.len();
    let mut updates = Vec::new();
    while bytes.len() - pos >= FRAME {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let sum = u64::from_le_bytes(bytes[pos + 4..pos + FRAME].try_into().unwrap());
        if bytes.len() - pos - FRAME < len {
            break; // frame promises more bytes than exist: torn tail
        }
        let payload = &bytes[pos + FRAME..pos + FRAME + len];
        if fnv1a(payload) != sum {
            break; // partially written payload: torn tail
        }
        let update = decode_update(payload).map_err(|e| {
            TxnError::Durability(format!("{}: corrupt record at byte {pos}: {e}", path.display()))
        })?;
        updates.push(update);
        pos += FRAME + len;
    }

    let truncated = (bytes.len() - pos) as u64;
    if truncated > 0 {
        file.set_len(pos as u64).map_err(io_err)?;
        file.sync_data().map_err(io_err)?;
    }
    let report = RecoveryReport {
        replayed: updates.len(),
        truncated_bytes: truncated,
        valid_bytes: pos as u64,
    };
    Ok((updates, report))
}

// ---- binary encoding -------------------------------------------------

const KIND_INSERT: u8 = 0;
const KIND_UPDATE: u8 = 1;
const KIND_DELETE: u8 = 2;
const OP_SET: u8 = 0;
const OP_ADD: u8 = 1;
const VAL_NULL: u8 = 0;
const VAL_INT: u8 = 1;
const VAL_FLOAT: u8 = 2;
const VAL_STR: u8 = 3;

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_value(buf: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Null => buf.push(VAL_NULL),
        Value::Int(i) => {
            buf.push(VAL_INT);
            buf.extend_from_slice(&i.to_le_bytes());
        }
        Value::Float(x) => {
            buf.push(VAL_FLOAT);
            buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        Value::Str(s) => {
            buf.push(VAL_STR);
            put_u32(buf, s.len() as u32);
            buf.extend_from_slice(s.as_bytes());
        }
    }
}

fn put_values(buf: &mut Vec<u8>, vs: &[Value]) {
    put_u32(buf, vs.len() as u32);
    for v in vs {
        put_value(buf, v);
    }
}

pub(crate) fn encode_update(buf: &mut Vec<u8>, u: &StateUpdate) {
    put_u32(buf, u.records.len() as u32);
    for rec in &u.records {
        match rec {
            WriteRecord::Insert { table, key, row } => {
                buf.push(KIND_INSERT);
                put_u32(buf, *table as u32);
                put_values(buf, &key.0);
                put_values(buf, row);
            }
            WriteRecord::Update { table, key, cols } => {
                buf.push(KIND_UPDATE);
                put_u32(buf, *table as u32);
                put_values(buf, &key.0);
                put_u32(buf, cols.len() as u32);
                for (ci, op) in cols {
                    put_u32(buf, *ci as u32);
                    match op {
                        ColOp::Set(v) => {
                            buf.push(OP_SET);
                            put_value(buf, v);
                        }
                        ColOp::Add(v) => {
                            buf.push(OP_ADD);
                            put_value(buf, v);
                        }
                    }
                }
            }
            WriteRecord::Delete { table, key } => {
                buf.push(KIND_DELETE);
                put_u32(buf, *table as u32);
                put_values(buf, &key.0);
            }
        }
    }
}

pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// A cursor over `buf` starting at offset 0 (the net frame codec
    /// reuses these primitives; inside this module the struct literal is
    /// used directly).
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A u32-length-prefixed UTF-8 string (the net codec's string form;
    /// WAL payloads encode strings only inside [`Value`]s).
    pub(crate) fn string(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|_| "invalid utf-8 in string".to_string())?;
        Ok(s.to_string())
    }

    /// Error unless the cursor consumed the whole buffer.
    pub(crate) fn done(&self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after message", self.buf.len() - self.pos))
        }
    }
}

impl<'a> Reader<'a> {
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() - self.pos < n {
            return Err("payload ends mid-field".into());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn value(&mut self) -> Result<Value, String> {
        match self.u8()? {
            VAL_NULL => Ok(Value::Null),
            VAL_INT => Ok(Value::Int(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))),
            VAL_FLOAT => {
                Ok(Value::Float(f64::from_bits(u64::from_le_bytes(
                    self.take(8)?.try_into().unwrap(),
                ))))
            }
            VAL_STR => {
                let n = self.u32()? as usize;
                let s = std::str::from_utf8(self.take(n)?)
                    .map_err(|_| "invalid utf-8 in string value".to_string())?;
                Ok(Value::Str(s.to_string()))
            }
            t => Err(format!("unknown value tag {t}")),
        }
    }

    pub(crate) fn values(&mut self) -> Result<Vec<Value>, String> {
        let n = self.u32()? as usize;
        // Cap the pre-allocation: `n` comes from disk.
        let mut out = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            out.push(self.value()?);
        }
        Ok(out)
    }
}

pub(crate) fn decode_update(payload: &[u8]) -> Result<StateUpdate, String> {
    let mut r = Reader { buf: payload, pos: 0 };
    let n = r.u32()? as usize;
    let mut update = StateUpdate::new();
    for _ in 0..n {
        let kind = r.u8()?;
        let table = r.u32()? as usize;
        let key = Key(r.values()?);
        let rec = match kind {
            KIND_INSERT => WriteRecord::Insert {
                table,
                key,
                row: std::sync::Arc::new(r.values()?),
            },
            KIND_UPDATE => {
                let nc = r.u32()? as usize;
                let mut cols = Vec::with_capacity(nc.min(1024));
                for _ in 0..nc {
                    let ci = r.u32()? as usize;
                    let op = match r.u8()? {
                        OP_SET => ColOp::Set(r.value()?),
                        OP_ADD => ColOp::Add(r.value()?),
                        t => return Err(format!("unknown column-op tag {t}")),
                    };
                    cols.push((ci, op));
                }
                WriteRecord::Update { table, key, cols }
            }
            KIND_DELETE => WriteRecord::Delete { table, key },
            t => return Err(format!("unknown record kind {t}")),
        };
        update.push(rec);
    }
    if r.pos != payload.len() {
        return Err(format!("{} trailing bytes after last record", payload.len() - r.pos));
    }
    Ok(update)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn sample() -> StateUpdate {
        let mut u = StateUpdate::new();
        u.push(WriteRecord::Insert {
            table: 3,
            key: Key(vec![Value::Int(42), Value::Str("ab".into())]),
            row: Arc::new(vec![
                Value::Int(-7),
                Value::Float(1.5),
                Value::Str("payload".into()),
                Value::Null,
            ]),
        });
        u.push(WriteRecord::Update {
            table: 0,
            key: Key::single(Value::Int(9)),
            cols: vec![(1, ColOp::Set(Value::Str("x".into()))), (2, ColOp::Add(Value::Int(-3)))],
        });
        u.push(WriteRecord::Delete { table: 1, key: Key::single(Value::Str("gone".into())) });
        u
    }

    #[test]
    fn encode_decode_roundtrips_all_record_kinds() {
        let u = sample();
        let mut buf = Vec::new();
        encode_update(&mut buf, &u);
        assert_eq!(decode_update(&buf).unwrap(), u);
    }

    #[test]
    fn empty_update_roundtrips() {
        let mut buf = Vec::new();
        encode_update(&mut buf, &StateUpdate::new());
        assert_eq!(decode_update(&buf).unwrap(), StateUpdate::new());
    }

    #[test]
    fn float_bits_survive_exactly() {
        let mut u = StateUpdate::new();
        u.push(WriteRecord::Update {
            table: 0,
            key: Key::single(Value::Int(1)),
            cols: vec![(0, ColOp::Set(Value::Float(0.1 + 0.2)))],
        });
        let mut buf = Vec::new();
        encode_update(&mut buf, &u);
        let back = decode_update(&buf).unwrap();
        match &back.records[0] {
            WriteRecord::Update { cols, .. } => match &cols[0].1 {
                ColOp::Set(Value::Float(x)) => {
                    assert_eq!(x.to_bits(), (0.1f64 + 0.2).to_bits())
                }
                other => panic!("unexpected op {other:?}"),
            },
            other => panic!("unexpected record {other:?}"),
        }
    }

    #[test]
    fn decode_rejects_truncated_and_trailing_payloads() {
        let u = sample();
        let mut buf = Vec::new();
        encode_update(&mut buf, &u);
        assert!(decode_update(&buf[..buf.len() - 1]).is_err(), "truncated must fail");
        let mut long = buf.clone();
        long.push(0);
        assert!(decode_update(&long).is_err(), "trailing bytes must fail");
    }

    #[test]
    fn checksum_flags_any_flipped_bit() {
        let u = sample();
        let mut buf = Vec::new();
        encode_update(&mut buf, &u);
        let clean = fnv1a(&buf);
        for i in (0..buf.len()).step_by(7) {
            buf[i] ^= 0x10;
            assert_ne!(fnv1a(&buf), clean, "flip at byte {i} must change the checksum");
            buf[i] ^= 0x10;
        }
        assert_eq!(fnv1a(&buf), clean);
    }

    #[test]
    fn sync_policy_parses_the_env_knob_forms() {
        assert_eq!(SyncPolicy::parse(None), SyncPolicy::Always);
        assert_eq!(SyncPolicy::parse(Some("1")), SyncPolicy::Always);
        assert_eq!(SyncPolicy::parse(Some("garbage")), SyncPolicy::Always);
        assert_eq!(SyncPolicy::parse(Some("64")), SyncPolicy::Batch(64));
        assert_eq!(SyncPolicy::parse(Some(" os ")), SyncPolicy::Os);
        assert_eq!(SyncPolicy::parse(Some("OS")), SyncPolicy::Os);
    }
}
