//! The database engine: storage, statement execution, commit/abort, and
//! state-update application (replication path).

use super::lockmgr::{LockManager, LockMode, LockTarget, TxnId};
use super::plan::{eval_pred, plan, AccessPath};
use super::txn::{IsolationLevel, TxnError, TxnState};
use super::update::{ColOp, StateUpdate, WriteRecord};
use super::value::{eval_scalar, Bindings, Key, Row, Value};
use crate::catalog::{Schema, TableSchema};
use crate::sqlir::{Delete, Insert, Select, SelectItem, Stmt, Update};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Result of executing one statement.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct QueryResult {
    /// Projected rows (SELECT only).
    pub rows: Vec<Vec<Value>>,
    /// Rows inserted/updated/deleted (DML only).
    pub affected: usize,
}

impl QueryResult {
    pub fn first(&self) -> Option<&Vec<Value>> {
        self.rows.first()
    }

    /// Convenience: the single scalar of a one-row/one-col result.
    pub fn scalar(&self) -> Option<&Value> {
        self.rows.first().and_then(|r| r.first())
    }
}

#[derive(Debug, Default)]
struct TableData {
    rows: HashMap<Key, Row>,
    /// Secondary hash indexes: column idx -> value -> set of PKs.
    indexes: HashMap<usize, HashMap<Value, HashSet<Key>>>,
}

impl TableData {
    fn new(schema: &TableSchema) -> Self {
        let mut t = TableData::default();
        for col in &schema.indexes {
            let ci = schema.col_index(col).expect("index column");
            t.indexes.insert(ci, HashMap::new());
        }
        t
    }

    fn index_insert(&mut self, key: &Key, row: &Row) {
        for (ci, bucket) in self.indexes.iter_mut() {
            bucket.entry(row[*ci].clone()).or_default().insert(key.clone());
        }
    }

    fn index_remove(&mut self, key: &Key, row: &Row) {
        for (ci, bucket) in self.indexes.iter_mut() {
            if let Some(set) = bucket.get_mut(&row[*ci]) {
                set.remove(key);
                if set.is_empty() {
                    bucket.remove(&row[*ci]);
                }
            }
        }
    }

    fn put(&mut self, key: Key, row: Row) {
        if let Some(old) = self.rows.get(&key).cloned() {
            self.index_remove(&key, &old);
        }
        self.index_insert(&key, &row);
        self.rows.insert(key, row);
    }

    fn remove(&mut self, key: &Key) {
        if let Some(old) = self.rows.remove(key) {
            self.index_remove(key, &old);
        }
    }
}


/// If `scalar` has the shape `col ± expr` where `expr` does not read any
/// row column, return the signed delta value of `expr` (None otherwise).
fn delta_of(
    scalar: &crate::sqlir::Scalar,
    target_col: &str,
    schema: &TableSchema,
    binds: &Bindings,
) -> Option<Value> {
    use crate::sqlir::Scalar as S;
    let (lhs, rhs, negate) = match scalar {
        S::Add(a, b) => (a, b, false),
        S::Sub(a, b) => (a, b, true),
        _ => return None,
    };
    match (&**lhs, &**rhs) {
        (S::Col(c), expr) if c.eq_ignore_ascii_case(target_col) => {
            let mut cols = Vec::new();
            expr.referenced_cols(&mut cols);
            if !cols.is_empty() {
                return None;
            }
            let v = eval_scalar(expr, None, &|c| schema.col_index(c), binds).ok()?;
            Some(match (v, negate) {
                (Value::Int(i), true) => Value::Int(-i),
                (Value::Float(x), true) => Value::Float(-x),
                (v, false) => v,
                _ => return None,
            })
        }
        _ => None,
    }
}

/// The embedded database: schema + storage + lock manager.
///
/// Thread-safe: statement execution takes logical 2PL locks (blocking)
/// and short physical `RwLock` sections per table; commits apply buffered
/// writes under physical write locks before releasing logical locks.
pub struct Db {
    schema: Schema,
    tables: Vec<RwLock<TableData>>,
    locks: LockManager,
    next_txn: AtomicU64,
    default_isolation: IsolationLevel,
    commits: AtomicU64,
    aborts: AtomicU64,
}

impl std::fmt::Debug for Db {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Db").field("tables", &self.schema.ntables()).finish()
    }
}

impl Db {
    pub fn new(schema: Schema) -> Self {
        let tables =
            schema.tables().iter().map(|t| RwLock::new(TableData::new(t))).collect();
        Db {
            schema,
            tables,
            locks: LockManager::default(),
            next_txn: AtomicU64::new(1),
            default_isolation: IsolationLevel::Serializable,
            commits: AtomicU64::new(0),
            aborts: AtomicU64::new(0),
        }
    }

    pub fn with_isolation(mut self, iso: IsolationLevel) -> Self {
        self.default_isolation = iso;
        self
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn commit_count(&self) -> u64 {
        self.commits.load(Ordering::Relaxed)
    }

    pub fn abort_count(&self) -> u64 {
        self.aborts.load(Ordering::Relaxed)
    }

    /// Begin a transaction at the database's default isolation level.
    pub fn begin(&self) -> TxnHandle<'_> {
        self.begin_with(self.default_isolation)
    }

    pub fn begin_with(&self, isolation: IsolationLevel) -> TxnHandle<'_> {
        let id = self.next_txn.fetch_add(1, Ordering::SeqCst);
        TxnHandle { db: self, id, isolation, state: TxnState::default(), done: false }
    }

    /// Execute a single auto-committed statement (loader convenience).
    pub fn exec_auto(&self, stmt: &Stmt, binds: &Bindings) -> Result<QueryResult, TxnError> {
        let mut txn = self.begin();
        let r = txn.exec(stmt, binds)?;
        txn.commit()?;
        Ok(r)
    }

    /// Apply a replicated [`StateUpdate`] (the Conveyor Belt `apply(u)`).
    ///
    /// Runs as an internal transaction: X row locks on every touched key
    /// so replication serializes against local operations, exactly as a
    /// DBMS transaction would.
    pub fn apply_update(&self, update: &StateUpdate) -> Result<(), TxnError> {
        loop {
            match self.try_apply_update(update) {
                Err(TxnError::Lock(_)) => {
                    // The token thread must win eventually; back off and retry.
                    std::thread::yield_now();
                }
                other => return other,
            }
        }
    }

    fn try_apply_update(&self, update: &StateUpdate) -> Result<(), TxnError> {
        let id = self.next_txn.fetch_add(1, Ordering::SeqCst);
        let res = (|| -> Result<(), TxnError> {
            for rec in &update.records {
                let t = rec.table();
                self.locks.acquire(id, LockTarget::Table(t), LockMode::IX)?;
                self.locks.acquire(id, LockTarget::Row(t, rec.key().clone()), LockMode::X)?;
            }
            for rec in &update.records {
                let mut table = self.tables[rec.table()].write().unwrap();
                match rec {
                    WriteRecord::Insert { key, row, .. } => {
                        table.put(key.clone(), row.clone());
                    }
                    WriteRecord::Update { key, cols, .. } => {
                        if let Some(mut row) = table.rows.get(key).cloned() {
                            for (ci, op) in cols {
                                row[*ci] = op.apply(&row[*ci]);
                            }
                            table.put(key.clone(), row);
                        }
                        // A missing row means the update raced a delete that
                        // this replica already applied — drop it silently,
                        // matching the paper's replay-in-order guarantee
                        // (this branch is unreachable under token ordering).
                    }
                    WriteRecord::Delete { key, .. } => {
                        table.remove(key);
                    }
                }
            }
            Ok(())
        })();
        self.locks.release_all(id);
        res
    }

    /// Deterministic hash of all committed data — used by tests to check
    /// replica convergence.
    pub fn content_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut acc: u64 = 0xcbf29ce484222325;
        for (ti, table) in self.tables.iter().enumerate() {
            let table = table.read().unwrap();
            // XOR of per-row hashes: order-independent, so no sort needed.
            let mut table_acc: u64 = 0;
            for (k, row) in &table.rows {
                let mut h = std::collections::hash_map::DefaultHasher::new();
                ti.hash(&mut h);
                k.hash(&mut h);
                row.hash(&mut h);
                table_acc ^= h.finish();
            }
            acc = acc.wrapping_mul(0x100000001b3) ^ table_acc;
        }
        acc
    }

    /// Number of committed rows in a table (tests / examples).
    pub fn row_count(&self, table: &str) -> usize {
        let ti = self.schema.table_id(table).expect("unknown table");
        self.tables[ti].read().unwrap().rows.len()
    }

    /// Read one committed row by primary key outside any transaction
    /// (tests / invariant checks; not part of the transactional API).
    pub fn peek(&self, table: &str, key: &Key) -> Option<Row> {
        let ti = self.schema.table_id(table)?;
        self.tables[ti].read().unwrap().rows.get(key).cloned()
    }
}

/// A live transaction. Dropping without commit aborts.
pub struct TxnHandle<'a> {
    db: &'a Db,
    id: TxnId,
    isolation: IsolationLevel,
    state: TxnState,
    done: bool,
}

impl<'a> TxnHandle<'a> {
    pub fn id(&self) -> TxnId {
        self.id
    }

    /// The state update accumulated so far (read-only view).
    pub fn pending_update(&self) -> &StateUpdate {
        &self.state.update
    }

    fn table_id(&self, name: &str) -> Result<usize, TxnError> {
        self.db
            .schema
            .table_id(name)
            .ok_or_else(|| TxnError::Sql(format!("unknown table {name}")))
    }

    fn lock(&self, target: LockTarget, mode: LockMode) -> Result<(), TxnError> {
        Ok(self.db.locks.acquire(self.id, target, mode)?)
    }

    /// Execute one statement within this transaction.
    pub fn exec(&mut self, stmt: &Stmt, binds: &Bindings) -> Result<QueryResult, TxnError> {
        if self.done {
            return Err(TxnError::Finished);
        }
        match stmt {
            Stmt::Select(s) => self.exec_select(s, binds),
            Stmt::Insert(s) => self.exec_insert(s, binds),
            Stmt::Update(s) => self.exec_update(s, binds),
            Stmt::Delete(s) => self.exec_delete(s, binds),
        }
    }

    /// Collect `(key, row)` pairs visible to this txn that match `pred`,
    /// taking the appropriate locks. `for_write` selects X/IX vs S/IS.
    fn select_rows(
        &mut self,
        ti: usize,
        pred: &crate::sqlir::Pred,
        binds: &Bindings,
        for_write: bool,
    ) -> Result<Vec<(Key, Row)>, TxnError> {
        let schema = self.db.schema.table(ti);
        let path = plan(pred, schema, binds);
        let serializable = self.isolation == IsolationLevel::Serializable;

        // --- Locking ---
        match (&path, for_write) {
            (AccessPath::Point(key), true) => {
                self.lock(LockTarget::Table(ti), LockMode::IX)?;
                self.lock(LockTarget::Row(ti, key.clone()), LockMode::X)?;
            }
            (AccessPath::Point(key), false) => {
                if serializable {
                    self.lock(LockTarget::Table(ti), LockMode::IS)?;
                    self.lock(LockTarget::Row(ti, key.clone()), LockMode::S)?;
                }
            }
            (_, true) => {
                // Scan-write: table X (covers phantom-safe multi-row update).
                self.lock(LockTarget::Table(ti), LockMode::X)?;
            }
            (_, false) => {
                if serializable {
                    // Scan-read: table S for phantom protection.
                    self.lock(LockTarget::Table(ti), LockMode::S)?;
                }
            }
        }

        // --- Row collection (short physical read section) ---
        let mut out = Vec::new();
        let table = self.db.tables[ti].read().unwrap();
        let consider = |key: &Key, committed: Option<&Row>, out: &mut Vec<(Key, Row)>| -> Result<(), TxnError> {
            if let Some(row) = self.state.visible(ti, key, committed) {
                if eval_pred(pred, row, schema, binds).map_err(TxnError::Sql)? {
                    out.push((key.clone(), row.clone()));
                }
            }
            Ok(())
        };
        match &path {
            AccessPath::Point(key) => {
                consider(key, table.rows.get(key), &mut out)?;
            }
            AccessPath::IndexEq { col, value } => {
                if let Some(keys) = table.indexes.get(col).and_then(|b| b.get(value)) {
                    for key in keys {
                        consider(key, table.rows.get(key), &mut out)?;
                    }
                }
                // Overlay-inserted rows are not in the committed index.
                for ((t, key), v) in &self.state.overlay {
                    if *t == ti && !table.rows.contains_key(key) {
                        if let Some(row) = v {
                            if row[*col] == *value {
                                if eval_pred(pred, row, schema, binds).map_err(TxnError::Sql)? {
                                    out.push((key.clone(), row.clone()));
                                }
                            }
                        }
                    }
                }
            }
            AccessPath::Scan => {
                for (key, committed) in &table.rows {
                    consider(key, Some(committed), &mut out)?;
                }
                for ((t, key), v) in &self.state.overlay {
                    if *t == ti && !table.rows.contains_key(key) {
                        if let Some(row) = v {
                            if eval_pred(pred, row, schema, binds).map_err(TxnError::Sql)? {
                                out.push((key.clone(), row.clone()));
                            }
                        }
                    }
                }
            }
        }
        drop(table);

        // Row locks for matched rows under non-point paths.
        if serializable || for_write {
            match &path {
                AccessPath::Point(_) => {}
                _ => {
                    let mode = if for_write { LockMode::X } else { LockMode::S };
                    for (key, _) in &out {
                        self.lock(LockTarget::Row(ti, key.clone()), mode)?;
                    }
                }
            }
        }
        Ok(out)
    }

    fn exec_select(&mut self, s: &Select, binds: &Bindings) -> Result<QueryResult, TxnError> {
        let ti = self.table_id(&s.table)?;
        let schema = self.db.schema.table(ti);
        let mut matched = self.select_rows(ti, &s.where_, binds, false)?;

        // ORDER BY before LIMIT.
        if let Some((col, desc)) = &s.order_by {
            let ci = schema
                .col_index(col)
                .ok_or_else(|| TxnError::Sql(format!("unknown ORDER BY column {col}")))?;
            matched.sort_by(|(_, a), (_, b)| {
                let ord = a[ci].total_cmp(&b[ci]);
                if *desc {
                    ord.reverse()
                } else {
                    ord
                }
            });
        } else {
            // Deterministic output independent of hash-map iteration order.
            matched.sort_by(|(a, _), (b, _)| {
                a.0.iter()
                    .zip(b.0.iter())
                    .map(|(x, y)| x.total_cmp(y))
                    .find(|o| !o.is_eq())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        if let Some(n) = s.limit {
            matched.truncate(n as usize);
        }

        // Projection / aggregation.
        let has_agg = s.items.iter().any(|i| i.is_aggregate());
        if has_agg {
            let mut row_out = Vec::with_capacity(s.items.len());
            for item in &s.items {
                let v = match item {
                    SelectItem::Count => Value::Int(matched.len() as i64),
                    SelectItem::Col(c) => {
                        // Non-aggregated column with aggregates: take first row
                        // (the subset of SQL our workloads need).
                        let ci = self.col_idx(schema, c)?;
                        matched.first().map(|(_, r)| r[ci].clone()).unwrap_or(Value::Null)
                    }
                    SelectItem::Max(c) | SelectItem::Min(c) => {
                        let ci = self.col_idx(schema, c)?;
                        let mut vals: Vec<&Value> =
                            matched.iter().map(|(_, r)| &r[ci]).filter(|v| !matches!(v, Value::Null)).collect();
                        vals.sort_by(|a, b| a.total_cmp(b));
                        let picked = if matches!(item, SelectItem::Max(_)) {
                            vals.last()
                        } else {
                            vals.first()
                        };
                        picked.cloned().cloned().unwrap_or(Value::Null)
                    }
                    SelectItem::Sum(c) => {
                        let ci = self.col_idx(schema, c)?;
                        let mut int_sum: i64 = 0;
                        let mut float_sum = 0.0;
                        let mut any_float = false;
                        let mut any = false;
                        for (_, r) in &matched {
                            match &r[ci] {
                                Value::Int(i) => {
                                    int_sum += i;
                                    any = true;
                                }
                                Value::Float(x) => {
                                    float_sum += x;
                                    any_float = true;
                                    any = true;
                                }
                                _ => {}
                            }
                        }
                        if !any {
                            Value::Null
                        } else if any_float {
                            Value::Float(float_sum + int_sum as f64)
                        } else {
                            Value::Int(int_sum)
                        }
                    }
                };
                row_out.push(v);
            }
            return Ok(QueryResult { rows: vec![row_out], affected: 0 });
        }

        let rows = if s.items.is_empty() {
            matched.into_iter().map(|(_, r)| r).collect()
        } else {
            let cis: Vec<usize> = s
                .items
                .iter()
                .map(|i| self.col_idx(schema, i.referenced_col().unwrap()))
                .collect::<Result<_, _>>()?;
            matched
                .into_iter()
                .map(|(_, r)| cis.iter().map(|&ci| r[ci].clone()).collect())
                .collect()
        };
        Ok(QueryResult { rows, affected: 0 })
    }

    fn col_idx(&self, schema: &TableSchema, c: &str) -> Result<usize, TxnError> {
        schema
            .col_index(c)
            .ok_or_else(|| TxnError::Sql(format!("unknown column {c} in {}", schema.name)))
    }

    fn exec_insert(&mut self, s: &Insert, binds: &Bindings) -> Result<QueryResult, TxnError> {
        let ti = self.table_id(&s.table)?;
        let schema = self.db.schema.table(ti);

        // Build the full row (unspecified columns are NULL).
        let mut row: Row = vec![Value::Null; schema.ncols()];
        for (col, scalar) in s.columns.iter().zip(&s.values) {
            let ci = self.col_idx(schema, col)?;
            let v = eval_scalar(scalar, None, &|c| schema.col_index(c), binds)
                .map_err(TxnError::Sql)?;
            row[ci] = v.coerce(schema.columns[ci].ty);
        }
        let key = Key(schema.pk_indices().iter().map(|&i| row[i].clone()).collect());
        if key.0.iter().any(|v| matches!(v, Value::Null)) {
            return Err(TxnError::Sql(format!("NULL primary key in INSERT into {}", s.table)));
        }

        self.lock(LockTarget::Table(ti), LockMode::IX)?;
        self.lock(LockTarget::Row(ti, key.clone()), LockMode::X)?;

        let exists = {
            let table = self.db.tables[ti].read().unwrap();
            self.state.visible(ti, &key, table.rows.get(&key)).is_some()
        };
        if exists {
            return Err(TxnError::DuplicateKey { table: s.table.clone(), key: key.to_string() });
        }
        self.state.overlay.insert((ti, key.clone()), Some(row.clone()));
        self.state.update.push(WriteRecord::Insert { table: ti, key, row });
        Ok(QueryResult { rows: vec![], affected: 1 })
    }

    fn exec_update(&mut self, s: &Update, binds: &Bindings) -> Result<QueryResult, TxnError> {
        let ti = self.table_id(&s.table)?;
        let schema = self.db.schema.table(ti);
        let pk = schema.pk_indices();
        let matched = self.select_rows(ti, &s.where_, binds, true)?;
        let schema = self.db.schema.table(ti); // reborrow after &mut self
        let mut affected = 0;
        for (key, old_row) in matched {
            let mut new_row = old_row.clone();
            let mut cols = Vec::with_capacity(s.sets.len());
            for (col, scalar) in &s.sets {
                let ci = self.col_idx(schema, col)?;
                if pk.contains(&ci) {
                    return Err(TxnError::Sql(format!(
                        "updates to primary-key column {col} are unsupported"
                    )));
                }
                let v = eval_scalar(scalar, Some(&old_row), &|c| schema.col_index(c), binds)
                    .map_err(TxnError::Sql)?
                    .coerce(schema.columns[ci].ty);
                new_row[ci] = v.clone();
                // Logical redo: `c = c ± expr` (with `expr` row-independent)
                // is recorded as a delta so replicated replay merges with
                // the replica's own value; everything else is an absolute
                // assignment (see db::update::ColOp).
                let op = delta_of(scalar, col, schema, binds)
                    .map(ColOp::Add)
                    .unwrap_or(ColOp::Set(v));
                cols.push((ci, op));
            }
            self.state.overlay.insert((ti, key.clone()), Some(new_row));
            self.state.update.push(WriteRecord::Update { table: ti, key, cols });
            affected += 1;
        }
        Ok(QueryResult { rows: vec![], affected })
    }

    fn exec_delete(&mut self, s: &Delete, binds: &Bindings) -> Result<QueryResult, TxnError> {
        let ti = self.table_id(&s.table)?;
        let matched = self.select_rows(ti, &s.where_, binds, true)?;
        let affected = matched.len();
        for (key, _) in matched {
            self.state.overlay.insert((ti, key.clone()), None);
            self.state.update.push(WriteRecord::Delete { table: ti, key });
        }
        Ok(QueryResult { rows: vec![], affected })
    }

    /// Commit: apply buffered writes to storage, then release locks.
    /// Returns the transaction's [`StateUpdate`].
    pub fn commit(self) -> Result<StateUpdate, TxnError> {
        self.commit_with(|_| ())
            .map(|(u, ())| u)
    }

    /// Commit and run `hook` *after* the writes are applied but *before*
    /// any lock is released. Under strict 2PL this means two conflicting
    /// transactions invoke their hooks in their serialization order —
    /// exactly the property Eliá's commit interception relies on to
    /// append state updates to the token queue in execution order
    /// (paper §5, "Tracing the sequential order of global operations").
    pub fn commit_with<R>(mut self, hook: impl FnOnce(&StateUpdate) -> R) -> Result<(StateUpdate, R), TxnError> {
        if self.done {
            return Err(TxnError::Finished);
        }
        self.done = true;

        // Apply per-table in table-id order under physical write locks.
        let mut touched: Vec<usize> = self.state.update.records.iter().map(|r| r.table()).collect();
        touched.sort_unstable();
        touched.dedup();
        for ti in touched {
            let mut table = self.db.tables[ti].write().unwrap();
            for rec in self.state.update.records.iter().filter(|r| r.table() == ti) {
                match rec {
                    WriteRecord::Insert { key, row, .. } => table.put(key.clone(), row.clone()),
                    WriteRecord::Update { key, cols, .. } => {
                        if let Some(mut row) = table.rows.get(key).cloned() {
                            for (ci, op) in cols {
                                row[*ci] = op.apply(&row[*ci]);
                            }
                            table.put(key.clone(), row);
                        }
                    }
                    WriteRecord::Delete { key, .. } => table.remove(key),
                }
            }
        }

        let update = std::mem::take(&mut self.state.update);
        let r = hook(&update);
        self.db.locks.release_all(self.id);
        self.db.commits.fetch_add(1, Ordering::Relaxed);
        Ok((update, r))
    }

    /// Abort: discard buffered writes and release locks.
    pub fn abort(mut self) {
        self.done = true;
        self.db.locks.release_all(self.id);
        self.db.aborts.fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for TxnHandle<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.db.locks.release_all(self.id);
            self.db.aborts.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{TableSchema, ValueType};
    use crate::sqlir::parse_statement;

    fn test_db() -> Db {
        Db::new(Schema::new(vec![
            TableSchema::new(
                "ITEMS",
                &[
                    ("ID", ValueType::Int),
                    ("TITLE", ValueType::Str),
                    ("STOCK", ValueType::Int),
                    ("COST", ValueType::Float),
                ],
                &["ID"],
            )
            .with_index("TITLE"),
            TableSchema::new(
                "SC",
                &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
                &["ID", "I_ID"],
            ),
        ]))
    }

    fn b(pairs: &[(&str, Value)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
    }

    fn seed_items(db: &Db, n: i64) {
        let ins = parse_statement(
            "INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)",
        )
        .unwrap();
        for i in 0..n {
            db.exec_auto(
                &ins,
                &b(&[
                    ("id", Value::Int(i)),
                    ("t", Value::Str(format!("book{i}"))),
                    ("s", Value::Int(100)),
                    ("c", Value::Float(9.5 + i as f64)),
                ]),
            )
            .unwrap();
        }
    }

    #[test]
    fn insert_select_roundtrip() {
        let db = test_db();
        seed_items(&db, 3);
        let q = parse_statement("SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id").unwrap();
        let r = db.exec_auto(&q, &b(&[("id", Value::Int(1))])).unwrap();
        assert_eq!(r.rows, vec![vec![Value::Str("book1".into()), Value::Int(100)]]);
    }

    #[test]
    fn update_with_arithmetic_and_state_update() {
        let db = test_db();
        seed_items(&db, 1);
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - ?q WHERE ID = ?id").unwrap();
        let mut txn = db.begin();
        let r = txn.exec(&u, &b(&[("q", Value::Int(30)), ("id", Value::Int(0))])).unwrap();
        assert_eq!(r.affected, 1);
        let update = txn.commit().unwrap();
        assert_eq!(update.len(), 1);
        match &update.records[0] {
            WriteRecord::Update { cols, .. } => {
                assert_eq!(cols, &vec![(2usize, ColOp::Add(Value::Int(-30)))])
            }
            other => panic!("{other:?}"),
        }
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(70)));
    }

    #[test]
    fn reads_see_own_writes_before_commit() {
        let db = test_db();
        let mut txn = db.begin();
        let ins = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (5, 'x', 1, 1.0)").unwrap();
        txn.exec(&ins, &Bindings::new()).unwrap();
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 5").unwrap();
        assert_eq!(txn.exec(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
        txn.abort();
        // After abort: nothing.
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().rows.len(), 0);
    }

    #[test]
    fn duplicate_pk_rejected() {
        let db = test_db();
        seed_items(&db, 1);
        let ins = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (0, 'dup', 1, 1.0)").unwrap();
        let err = db.exec_auto(&ins, &Bindings::new()).unwrap_err();
        assert!(matches!(err, TxnError::DuplicateKey { .. }));
    }

    #[test]
    fn delete_and_scan() {
        let db = test_db();
        seed_items(&db, 5);
        let d = parse_statement("DELETE FROM ITEMS WHERE ID >= 3").unwrap();
        let r = db.exec_auto(&d, &Bindings::new()).unwrap();
        assert_eq!(r.affected, 2);
        assert_eq!(db.row_count("ITEMS"), 3);
    }

    #[test]
    fn aggregates_and_order_by() {
        let db = test_db();
        seed_items(&db, 4);
        let q = parse_statement("SELECT COUNT(*) FROM ITEMS WHERE STOCK = 100").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(4)));
        let q = parse_statement("SELECT MAX(COST) FROM ITEMS").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Float(12.5)));
        let q = parse_statement("SELECT ID FROM ITEMS ORDER BY COST DESC LIMIT 2").unwrap();
        let r = db.exec_auto(&q, &Bindings::new()).unwrap();
        assert_eq!(r.rows, vec![vec![Value::Int(3)], vec![Value::Int(2)]]);
    }

    #[test]
    fn secondary_index_lookup() {
        let db = test_db();
        seed_items(&db, 10);
        let q = parse_statement("SELECT ID FROM ITEMS WHERE TITLE = ?t").unwrap();
        let r = db.exec_auto(&q, &b(&[("t", Value::Str("book7".into()))])).unwrap();
        assert_eq!(r.rows, vec![vec![Value::Int(7)]]);
        // Index stays correct across update of indexed column... (TITLE not
        // updated here; check delete maintenance instead.)
        let d = parse_statement("DELETE FROM ITEMS WHERE ID = 7").unwrap();
        db.exec_auto(&d, &Bindings::new()).unwrap();
        let r = db.exec_auto(&q, &b(&[("t", Value::Str("book7".into()))])).unwrap();
        assert!(r.rows.is_empty());
    }

    #[test]
    fn apply_update_replicates_state() {
        let db1 = test_db();
        let db2 = test_db();
        seed_items(&db1, 2);
        seed_items(&db2, 2);
        // Run a txn on db1, capture its update, apply on db2.
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 10 WHERE ID = 1").unwrap();
        let mut txn = db1.begin();
        txn.exec(&u, &Bindings::new()).unwrap();
        let update = txn.commit().unwrap();
        db2.apply_update(&update).unwrap();
        assert_eq!(db1.content_hash(), db2.content_hash());
    }

    #[test]
    fn commit_hook_runs_under_locks_in_commit_order() {
        // Two conflicting txns run concurrently; the hook order must match
        // the serialization (stock decrement) order.
        use std::sync::{Arc, Mutex};
        let db = Arc::new(test_db());
        seed_items(&db, 1);
        let order: Arc<Mutex<Vec<i64>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for tag in 0..4i64 {
            let db = Arc::clone(&db);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 1 WHERE ID = 0").unwrap();
                loop {
                    let mut txn = db.begin();
                    match txn.exec(&u, &Bindings::new()) {
                        Ok(_) => {
                            let stock_after = {
                                let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
                                txn.exec(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap()
                            };
                            txn.commit_with(|_| order.lock().unwrap().push(stock_after)).unwrap();
                            break;
                        }
                        Err(e) if e.is_retryable() => continue,
                        Err(e) => panic!("{e} (tag {tag})"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Hook order must be the strictly decreasing stock order 99,98,97,96.
        assert_eq!(*order.lock().unwrap(), vec![99, 98, 97, 96]);
    }

    #[test]
    fn read_committed_skips_read_locks() {
        let db = test_db();
        seed_items(&db, 1);
        // Writer holds X lock on row 0.
        let u = parse_statement("UPDATE ITEMS SET STOCK = 5 WHERE ID = 0").unwrap();
        let mut writer = db.begin();
        writer.exec(&u, &Bindings::new()).unwrap();
        // Read-committed reader proceeds (no S lock) and sees committed 100.
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let mut reader = db.begin_with(IsolationLevel::ReadCommitted);
        let r = reader.exec(&q, &Bindings::new()).unwrap();
        assert_eq!(r.scalar(), Some(&Value::Int(100)));
        reader.commit().unwrap();
        writer.commit().unwrap();
        let r = db.exec_auto(&q, &Bindings::new()).unwrap();
        assert_eq!(r.scalar(), Some(&Value::Int(5)));
    }

    #[test]
    fn serializable_blocks_conflicting_reader() {
        // Writer holds X; a younger serializable reader must wait-die.
        let db = test_db();
        seed_items(&db, 1);
        let u = parse_statement("UPDATE ITEMS SET STOCK = 5 WHERE ID = 0").unwrap();
        let mut writer = db.begin();
        writer.exec(&u, &Bindings::new()).unwrap();
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let mut reader = db.begin(); // younger
        let err = reader.exec(&q, &Bindings::new()).unwrap_err();
        assert!(err.is_retryable());
    }

    #[test]
    fn concurrent_stock_decrements_are_serializable() {
        use std::sync::Arc;
        let db = Arc::new(test_db());
        seed_items(&db, 1);
        let threads = 8;
        let per = 25;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let db = Arc::clone(&db);
            handles.push(std::thread::spawn(move || {
                let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 1 WHERE ID = 0").unwrap();
                for _ in 0..per {
                    loop {
                        let mut txn = db.begin();
                        match txn.exec(&u, &Bindings::new()).and_then(|_| txn.commit().map(|_| ())) {
                            Ok(()) => break,
                            Err(e) if e.is_retryable() => continue,
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let final_stock = db.exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        assert_eq!(final_stock, 100 - threads * per);
    }
}
