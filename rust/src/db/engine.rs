//! The database engine: storage, statement execution, commit/abort, and
//! state-update application (replication path).
//!
//! Execution is **prepared-first** (see [`super::prepared`]): statements
//! are compiled once against the schema — resolving column names to
//! indices, binding names to slots, and the access-path template — and
//! then executed many times with positional [`BindSlots`]. The
//! name-keyed [`TxnHandle::exec`] entry point is kept as a convenience
//! that compiles on the fly (tests, examples, ad-hoc statements).
//!
//! Storage shares rows via `Arc`: reads hand out refcounted handles and
//! never deep-copy a row; a write clones the row once when it builds the
//! new image (copy-on-write). Since this PR, reads also never clone a
//! `Value`: SELECTs return a borrowed [`ResultSet`] (row handles plus
//! the prepared projection, resolved lazily) instead of materializing
//! owned rows — see [`super::result`].

use super::lockmgr::{Acquired, LockManager, LockMode, LockTarget, TxnId};
use super::prepared::{
    eval_cpred, eval_cscalar, BindSlots, CItem, CPred, PDelete, PInsert, PSelect, PUpdate,
    PathTemplate, Prepared, PreparedKind, SetOp,
};
use super::result::ResultSet;
use super::txn::{IsolationLevel, TxnError, TxnState};
use super::update::{ColOp, StateUpdate, WriteRecord};
use super::value::{numeric_arith, ArithKind, Bindings, Key, Row, Value};
use super::wal::{self, DurabilityConfig, RecoveryReport, Wal};
use crate::catalog::{Schema, TableSchema};
use crate::sqlir::Stmt;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

#[derive(Debug, Default)]
struct TableData {
    rows: HashMap<Key, Arc<Row>>,
    /// Secondary hash indexes: column idx -> value -> set of PKs.
    indexes: HashMap<usize, HashMap<Value, HashSet<Key>>>,
}

impl TableData {
    fn new(schema: &TableSchema) -> Self {
        let mut t = TableData::default();
        for col in &schema.indexes {
            let ci = schema.col_index(col).expect("index column");
            t.indexes.insert(ci, HashMap::new());
        }
        t
    }

    fn index_insert(&mut self, key: &Key, row: &Row) {
        for (ci, bucket) in self.indexes.iter_mut() {
            bucket.entry(row[*ci].clone()).or_default().insert(key.clone());
        }
    }

    fn index_remove(&mut self, key: &Key, row: &Row) {
        for (ci, bucket) in self.indexes.iter_mut() {
            if let Some(set) = bucket.get_mut(&row[*ci]) {
                set.remove(key);
                if set.is_empty() {
                    bucket.remove(&row[*ci]);
                }
            }
        }
    }

    fn put(&mut self, key: Key, row: Arc<Row>) {
        if !self.indexes.is_empty() {
            if let Some(old) = self.rows.get(&key).map(Arc::clone) {
                self.index_remove(&key, &old);
            }
            self.index_insert(&key, &row);
        }
        self.rows.insert(key, row);
    }

    fn remove(&mut self, key: &Key) {
        if let Some(old) = self.rows.remove(key) {
            self.index_remove(key, &old);
        }
    }
}

/// The embedded database: schema + storage + lock manager.
///
/// Thread-safe: statement execution takes logical 2PL locks (blocking)
/// and short physical `RwLock` sections per table; commits apply buffered
/// writes under physical write locks before releasing logical locks.
pub struct Db {
    schema: Schema,
    tables: Vec<RwLock<TableData>>,
    locks: LockManager,
    next_txn: AtomicU64,
    default_isolation: IsolationLevel,
    commits: AtomicU64,
    aborts: AtomicU64,
    /// Write-ahead log; `None` (the default) keeps the engine purely
    /// in-memory and byte-identical to the pre-WAL hot path.
    wal: Option<Wal>,
}

impl std::fmt::Debug for Db {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Db").field("tables", &self.schema.ntables()).finish()
    }
}

impl Db {
    /// Create an empty database for `schema` (default isolation:
    /// serializable).
    pub fn new(schema: Schema) -> Self {
        let tables =
            schema.tables().iter().map(|t| RwLock::new(TableData::new(t))).collect();
        Db {
            schema,
            tables,
            locks: LockManager::default(),
            next_txn: AtomicU64::new(1),
            default_isolation: IsolationLevel::Serializable,
            commits: AtomicU64::new(0),
            aborts: AtomicU64::new(0),
            wal: None,
        }
    }

    /// Attach a fresh write-ahead log: the file at `cfg.path` is
    /// created (or truncated) and every commit from here on appends its
    /// [`StateUpdate`] before acknowledging, per `cfg.policy`. Use
    /// [`Db::recover`] instead when the file may hold a previous run's
    /// committed state.
    pub fn with_durability(mut self, cfg: &DurabilityConfig) -> Result<Self, TxnError> {
        self.wal = Some(Wal::create(cfg)?);
        Ok(self)
    }

    /// Recover a database from its write-ahead log: build an empty
    /// database for `schema`, run `seed` to restore the snapshot the
    /// log was started over (the same loader the original run used —
    /// seeded data precedes every logged commit), replay the log's
    /// committed records in commit order, truncate any torn tail, and
    /// re-attach the log for appending. If no log file exists yet this
    /// is [`Db::with_durability`] with an empty report.
    pub fn recover(
        schema: Schema,
        cfg: &DurabilityConfig,
        seed: impl FnOnce(&Db),
    ) -> Result<(Db, RecoveryReport), TxnError> {
        let mut db = Db::new(schema);
        seed(&db);
        if !cfg.path.exists() {
            db.wal = Some(Wal::create(cfg)?);
            return Ok((db, RecoveryReport::default()));
        }
        let (updates, report) = wal::recover_log(&cfg.path)?;
        for u in &updates {
            db.apply_update(u)?;
        }
        db.wal = Some(Wal::open_append(cfg)?);
        Ok((db, report))
    }

    /// The attached write-ahead log, if any (tests and shutdown hooks;
    /// e.g. [`Wal::flush`] before a clean exit under a batched policy).
    pub fn wal(&self) -> Option<&Wal> {
        self.wal.as_ref()
    }

    /// Set the default isolation level handed to [`begin`](Self::begin).
    pub fn with_isolation(mut self, iso: IsolationLevel) -> Self {
        self.default_isolation = iso;
        self
    }

    /// The schema this database was created with.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// Number of committed transactions so far.
    pub fn commit_count(&self) -> u64 {
        self.commits.load(Ordering::Relaxed)
    }

    /// Number of aborted transactions so far.
    pub fn abort_count(&self) -> u64 {
        self.aborts.load(Ordering::Relaxed)
    }

    /// Compile a statement against this database's schema (prepare once,
    /// execute many via [`TxnHandle::exec_prepared`]).
    pub fn prepare(&self, stmt: &Stmt) -> Result<Prepared, TxnError> {
        Prepared::compile(stmt, &self.schema).map_err(TxnError::Sql)
    }

    /// Parse + compile convenience.
    pub fn prepare_sql(&self, sql: &str) -> Result<Prepared, TxnError> {
        let stmt =
            crate::sqlir::parse_statement(sql).map_err(|e| TxnError::Sql(e.to_string()))?;
        self.prepare(&stmt)
    }

    /// Begin a transaction at the database's default isolation level.
    pub fn begin(&self) -> TxnHandle<'_> {
        self.begin_with(self.default_isolation)
    }

    /// Begin a transaction at an explicit isolation level.
    pub fn begin_with(&self, isolation: IsolationLevel) -> TxnHandle<'_> {
        let id = self.next_txn.fetch_add(1, Ordering::SeqCst);
        TxnHandle {
            db: self,
            id,
            isolation,
            state: TxnState::default(),
            locks_held: Vec::new(),
            lock_overflow: false,
            done: false,
        }
    }

    /// Execute a single auto-committed statement (loader convenience).
    /// The returned [`ResultSet`] holds `Arc` handles into the committed
    /// snapshot, so it stays valid after the internal commit.
    pub fn exec_auto(&self, stmt: &Stmt, binds: &Bindings) -> Result<ResultSet, TxnError> {
        let mut txn = self.begin();
        let r = txn.exec(stmt, binds)?;
        txn.commit()?;
        Ok(r)
    }

    /// Execute a single auto-committed prepared statement.
    pub fn exec_auto_prepared(
        &self,
        p: &Prepared,
        slots: &BindSlots,
    ) -> Result<ResultSet, TxnError> {
        let mut txn = self.begin();
        let r = txn.exec_prepared(p, slots)?;
        txn.commit()?;
        Ok(r)
    }

    /// Apply a replicated [`StateUpdate`] (the Conveyor Belt `apply(u)`).
    ///
    /// Runs as an internal transaction: X row locks on every touched key
    /// so replication serializes against local operations, exactly as a
    /// DBMS transaction would.
    pub fn apply_update(&self, update: &StateUpdate) -> Result<(), TxnError> {
        loop {
            match self.try_apply_update(update) {
                Err(TxnError::Lock(_)) => {
                    // The token thread must win eventually; back off and retry.
                    std::thread::yield_now();
                }
                other => return other,
            }
        }
    }

    fn try_apply_update(&self, update: &StateUpdate) -> Result<(), TxnError> {
        let id = self.next_txn.fetch_add(1, Ordering::SeqCst);
        let mut held: Vec<LockTarget> = Vec::with_capacity(update.records.len() * 2);
        let res = (|| -> Result<(), TxnError> {
            for rec in &update.records {
                let t = rec.table();
                let table_target = LockTarget::Table(t);
                if self.locks.acquire(id, table_target, LockMode::IX)? == Acquired::Fresh {
                    held.push(table_target);
                }
                let row_target = LockTarget::row(t, rec.key());
                if self.locks.acquire(id, row_target, LockMode::X)? == Acquired::Fresh {
                    held.push(row_target);
                }
            }
            for rec in &update.records {
                let mut table = self.tables[rec.table()].write().unwrap();
                match rec {
                    WriteRecord::Insert { key, row, .. } => {
                        table.put(key.clone(), Arc::clone(row));
                    }
                    WriteRecord::Update { key, cols, .. } => {
                        if let Some(mut row) = table.rows.get(key).map(|r| (**r).clone()) {
                            let schema = self.schema.table(rec.table());
                            for (ci, op) in cols {
                                // Coerce so a mixed-type delta (e.g. a Float
                                // Add on an Int column) leaves storage in the
                                // declared column type, matching the image
                                // the originating txn computed.
                                row[*ci] =
                                    op.apply(&row[*ci]).coerce(schema.columns[*ci].ty);
                            }
                            table.put(key.clone(), Arc::new(row));
                        }
                        // A missing row means the update raced a delete that
                        // this replica already applied — drop it silently,
                        // matching the paper's replay-in-order guarantee
                        // (this branch is unreachable under token ordering).
                    }
                    WriteRecord::Delete { key, .. } => {
                        table.remove(key);
                    }
                }
            }
            Ok(())
        })();
        // Replicated updates are part of this server's durable history
        // too: log them while the X locks are still held so the WAL
        // order stays a serialization order across local commits and
        // replayed remote ones.
        let res = match (res, &self.wal) {
            (Ok(()), Some(w)) if !update.is_empty() => w.append(update),
            (res, _) => res,
        };
        self.locks.release(id, &held);
        res
    }

    /// Deterministic hash of all committed data — used by tests to check
    /// replica convergence.
    pub fn content_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut acc: u64 = 0xcbf29ce484222325;
        for (ti, table) in self.tables.iter().enumerate() {
            let table = table.read().unwrap();
            // XOR of per-row hashes: order-independent, so no sort needed.
            let mut table_acc: u64 = 0;
            for (k, row) in &table.rows {
                let mut h = std::collections::hash_map::DefaultHasher::new();
                ti.hash(&mut h);
                k.hash(&mut h);
                row.hash(&mut h);
                table_acc ^= h.finish();
            }
            acc = acc.wrapping_mul(0x100000001b3) ^ table_acc;
        }
        acc
    }

    /// Deterministic hash of one table's committed data — replica
    /// convergence checks over the *replicated* subset of the schema
    /// (the tables global/confluent operations write), where the full
    /// [`Db::content_hash`] would legitimately diverge across servers on
    /// locally-partitioned tables.
    pub fn table_hash(&self, table: &str) -> u64 {
        use std::hash::{Hash, Hasher};
        let ti = self.schema.table_id(table).expect("unknown table");
        let t = self.tables[ti].read().unwrap();
        let mut table_acc: u64 = 0;
        for (k, row) in &t.rows {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            ti.hash(&mut h);
            k.hash(&mut h);
            row.hash(&mut h);
            table_acc ^= h.finish();
        }
        table_acc
    }

    /// Number of committed rows in a table (tests / examples).
    pub fn row_count(&self, table: &str) -> usize {
        let ti = self.schema.table_id(table).expect("unknown table");
        self.tables[ti].read().unwrap().rows.len()
    }

    /// Read one committed row by primary key outside any transaction
    /// (tests / invariant checks; not part of the transactional API).
    pub fn peek(&self, table: &str, key: &Key) -> Option<Row> {
        let ti = self.schema.table_id(table)?;
        self.tables[ti].read().unwrap().rows.get(key).map(|r| (**r).clone())
    }
}

/// Past this many tracked lock targets a transaction falls back to the
/// all-shards release sweep (a long multi-statement transaction can
/// accumulate hundreds of point targets; releasing each individually
/// would cost more than the sweep).
const LOCK_TRACK_MAX: usize = 128;

/// A live transaction. Dropping without commit aborts.
pub struct TxnHandle<'a> {
    db: &'a Db,
    id: TxnId,
    isolation: IsolationLevel,
    state: TxnState,
    /// Targets acquired so far — released individually at commit/abort so
    /// short transactions do not sweep every lock shard.
    locks_held: Vec<LockTarget>,
    lock_overflow: bool,
    done: bool,
}

impl<'a> TxnHandle<'a> {
    /// The transaction id (also its wait-die timestamp).
    pub fn id(&self) -> TxnId {
        self.id
    }

    /// The state update accumulated so far (read-only view).
    pub fn pending_update(&self) -> &StateUpdate {
        &self.state.update
    }

    fn lock(&mut self, target: LockTarget, mode: LockMode) -> Result<(), TxnError> {
        // Track only first-time holds: re-entrant hits and in-place
        // upgrades share the entry already recorded, so multi-statement
        // transactions stay under LOCK_TRACK_MAX.
        if self.db.locks.acquire(self.id, target, mode)? == Acquired::Fresh {
            if self.locks_held.len() < LOCK_TRACK_MAX {
                self.locks_held.push(target);
            } else {
                self.lock_overflow = true;
            }
        }
        Ok(())
    }

    fn release_locks(&mut self) {
        if self.lock_overflow {
            self.db.locks.release_all(self.id);
        } else {
            self.db.locks.release(self.id, &self.locks_held);
        }
    }

    /// Execute one statement within this transaction, compiling it on
    /// the fly (convenience path — the simulators and benches prepare
    /// once and use [`Self::exec_prepared`]).
    pub fn exec(&mut self, stmt: &Stmt, binds: &Bindings) -> Result<ResultSet, TxnError> {
        if self.done {
            return Err(TxnError::Finished);
        }
        let p = Prepared::compile(stmt, &self.db.schema).map_err(TxnError::Sql)?;
        let slots = p.bind(binds).map_err(TxnError::Sql)?;
        self.exec_prepared(&p, &slots)
    }

    /// Execute a prepared statement with positional bindings. SELECTs
    /// return a borrowed [`ResultSet`] — `Arc` row handles plus the
    /// statement's projection, no value clones; the set stays a valid
    /// snapshot across this transaction's later writes and its commit.
    pub fn exec_prepared(
        &mut self,
        p: &Prepared,
        slots: &BindSlots,
    ) -> Result<ResultSet, TxnError> {
        if self.done {
            return Err(TxnError::Finished);
        }
        // A Prepared carries raw table/column indices: it must have been
        // compiled against this database's schema (an identical clone is
        // fine — the conveyor replicas share one compilation).
        debug_assert!(
            p.table() < self.db.schema.ntables(),
            "prepared statement compiled against a different schema"
        );
        match &p.kind {
            PreparedKind::Select(s) => self.exec_select(s, slots),
            PreparedKind::Insert(i) => self.exec_insert(i, slots),
            PreparedKind::Update(u) => self.exec_update(u, slots),
            PreparedKind::Delete(d) => self.exec_delete(d, slots),
        }
    }

    /// Collect `(key, row)` pairs visible to this txn that match `pred`,
    /// taking X/IX write locks — the UPDATE/DELETE side, which needs
    /// owned keys for the overlay and the redo records.
    fn select_rows(
        &mut self,
        ti: usize,
        pred: &CPred,
        path: &PathTemplate,
        slots: &BindSlots,
    ) -> Result<Vec<(Key, Arc<Row>)>, TxnError> {
        self.collect_rows(ti, pred, path, slots, true, |key, row| {
            (key.clone(), Arc::clone(row))
        })
    }

    /// Collect the row handles visible to this txn that match `pred`,
    /// taking S/IS read locks when serializable. The read path: no `Key`
    /// and no `Value` is ever cloned — a match costs one `Arc` bump.
    fn select_rows_ro(
        &mut self,
        ti: usize,
        pred: &CPred,
        path: &PathTemplate,
        slots: &BindSlots,
    ) -> Result<Vec<Arc<Row>>, TxnError> {
        self.collect_rows(ti, pred, path, slots, false, |_, row| Arc::clone(row))
    }

    /// Shared row-collection core of [`select_rows`](Self::select_rows) /
    /// [`select_rows_ro`](Self::select_rows_ro): locking prelude and the
    /// three access paths (point / index-eq / scan) with overlay
    /// visibility. `make` builds one output entry per match while the
    /// key is still borrowed from storage.
    fn collect_rows<O>(
        &mut self,
        ti: usize,
        pred: &CPred,
        path: &PathTemplate,
        slots: &BindSlots,
        for_write: bool,
        mut make: impl FnMut(&Key, &Arc<Row>) -> O,
    ) -> Result<Vec<O>, TxnError> {
        let db = self.db;
        let serializable = self.isolation == IsolationLevel::Serializable;

        // The point key (if any) is built once per execution; only its
        // values come from the slots — the plan shape was fixed at
        // prepare time.
        let point_key = match path {
            PathTemplate::Point(srcs) => {
                Some(PathTemplate::point_key(srcs, slots).map_err(TxnError::Sql)?)
            }
            _ => None,
        };

        // --- Locking ---
        match (&point_key, for_write) {
            (Some(key), true) => {
                self.lock(LockTarget::Table(ti), LockMode::IX)?;
                self.lock(LockTarget::row(ti, key), LockMode::X)?;
            }
            (Some(key), false) => {
                if serializable {
                    self.lock(LockTarget::Table(ti), LockMode::IS)?;
                    self.lock(LockTarget::row(ti, key), LockMode::S)?;
                }
            }
            (None, true) => {
                // Scan-write: table X (covers phantom-safe multi-row update).
                self.lock(LockTarget::Table(ti), LockMode::X)?;
            }
            (None, false) => {
                if serializable {
                    // Scan-read: table S for phantom protection.
                    self.lock(LockTarget::Table(ti), LockMode::S)?;
                }
            }
        }

        // No per-matched-row locks on the non-point paths: every case
        // that used to take them already holds a *covering* table-level
        // lock from the prelude above — scan/index writes hold table X
        // (subsumes every row X), serializable non-point reads hold
        // table S (conflicts with any writer's IX/X, so rows cannot
        // change under the reader) — making per-row locks pure overhead,
        // O(matched rows) shard-mutex work on the path this module keeps
        // allocation-free. Multi-granularity coverage is exactly what
        // table locks are for (see `lockmgr::LockMode::covers`).

        // --- Row collection (short physical read section) ---
        let mut out: Vec<O> = Vec::new();
        {
            let table = db.tables[ti].read().unwrap();
            let state = &self.state;
            let mut consider = |key: &Key,
                                committed: Option<&Arc<Row>>,
                                out: &mut Vec<O>|
             -> Result<(), TxnError> {
                if let Some(row) = state.visible(ti, key, committed) {
                    if eval_cpred(pred, row.as_ref(), slots).map_err(TxnError::Sql)? {
                        out.push(make(key, row));
                    }
                }
                Ok(())
            };
            match path {
                PathTemplate::Point(_) => {
                    let key = point_key.as_ref().expect("point key built above");
                    consider(key, table.rows.get(key), &mut out)?;
                }
                PathTemplate::IndexEq { col, src } => {
                    let value = src.value(slots).map_err(TxnError::Sql)?;
                    let bucket = table.indexes.get(col).and_then(|b| b.get(&value));
                    if let Some(keys) = bucket {
                        for key in keys {
                            consider(key, table.rows.get(key), &mut out)?;
                        }
                    }
                    // Overlay rows unreachable through the committed
                    // index: fresh inserts AND committed rows whose
                    // indexed column was updated inside this transaction.
                    if let Some(ov) = state.overlay_table(ti) {
                        for (key, v) in ov {
                            if bucket.is_some_and(|b| b.contains(key)) {
                                continue; // already considered via the index
                            }
                            if let Some(row) = v {
                                if row[*col] == value
                                    && eval_cpred(pred, row.as_ref(), slots)
                                        .map_err(TxnError::Sql)?
                                {
                                    out.push(make(key, row));
                                }
                            }
                        }
                    }
                }
                PathTemplate::Scan => {
                    for (key, committed) in &table.rows {
                        consider(key, Some(committed), &mut out)?;
                    }
                    if let Some(ov) = state.overlay_table(ti) {
                        for (key, v) in ov {
                            if table.rows.contains_key(key) {
                                continue; // already considered via storage
                            }
                            if let Some(row) = v {
                                if eval_cpred(pred, row.as_ref(), slots)
                                    .map_err(TxnError::Sql)?
                                {
                                    out.push(make(key, row));
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    fn exec_select(&mut self, s: &PSelect, slots: &BindSlots) -> Result<ResultSet, TxnError> {
        let mut matched = self.select_rows_ro(s.ti, &s.where_, &s.path, slots)?;

        // ORDER BY before LIMIT.
        if let Some((ci, desc)) = s.order_by {
            matched.sort_by(|a, b| {
                let ord = a[ci].total_cmp(&b[ci]);
                if desc {
                    ord.reverse()
                } else {
                    ord
                }
            });
        } else {
            // Deterministic output independent of hash-map iteration
            // order: sort by primary-key value, read from the rows
            // themselves (the result carries no keys).
            matched.sort_by(|a, b| {
                s.pk.iter()
                    .map(|&i| a[i].total_cmp(&b[i]))
                    .find(|o| !o.is_eq())
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
        }
        if let Some(n) = s.limit {
            matched.truncate(n as usize);
        }

        // Aggregation computes its single row; plain projections stay
        // borrowed (handles + the prepared statement's index list).
        if s.has_agg {
            let mut row_out = Vec::with_capacity(s.items.len());
            for item in &s.items {
                let v = match item {
                    CItem::Count => Value::Int(matched.len() as i64),
                    CItem::Col(ci) => {
                        // Non-aggregated column with aggregates: take first row
                        // (the subset of SQL our workloads need).
                        matched.first().map(|r| r[*ci].clone()).unwrap_or(Value::Null)
                    }
                    CItem::Max(ci) | CItem::Min(ci) => {
                        let mut vals: Vec<&Value> = matched
                            .iter()
                            .map(|r| &r[*ci])
                            .filter(|v| !matches!(v, Value::Null))
                            .collect();
                        vals.sort_by(|a, b| a.total_cmp(b));
                        let picked = if matches!(item, CItem::Max(_)) {
                            vals.last()
                        } else {
                            vals.first()
                        };
                        picked.cloned().cloned().unwrap_or(Value::Null)
                    }
                    CItem::Sum(ci) => {
                        let mut int_sum: i64 = 0;
                        let mut float_sum = 0.0;
                        let mut any_float = false;
                        let mut any = false;
                        for r in &matched {
                            match &r[*ci] {
                                Value::Int(i) => {
                                    int_sum += i;
                                    any = true;
                                }
                                Value::Float(x) => {
                                    float_sum += x;
                                    any_float = true;
                                    any = true;
                                }
                                _ => {}
                            }
                        }
                        if !any {
                            Value::Null
                        } else if any_float {
                            Value::Float(float_sum + int_sum as f64)
                        } else {
                            Value::Int(int_sum)
                        }
                    }
                };
                row_out.push(v);
            }
            return Ok(ResultSet::computed(row_out));
        }

        Ok(ResultSet::rows(matched, s.proj.clone()))
    }

    fn exec_insert(&mut self, p: &PInsert, slots: &BindSlots) -> Result<ResultSet, TxnError> {
        let db = self.db;
        let ti = p.ti;
        let schema = db.schema.table(ti);

        // Build the full row (unspecified columns are NULL).
        let mut row: Row = vec![Value::Null; schema.ncols()];
        for (ci, expr) in &p.sets {
            let v = eval_cscalar(expr, None, slots).map_err(TxnError::Sql)?;
            row[*ci] = v.coerce(schema.columns[*ci].ty);
        }
        let key = Key(p.pk.iter().map(|&i| row[i].clone()).collect());
        if key.0.iter().any(|v| matches!(v, Value::Null)) {
            return Err(TxnError::Sql(format!(
                "NULL primary key in INSERT into {}",
                schema.name
            )));
        }

        self.lock(LockTarget::Table(ti), LockMode::IX)?;
        self.lock(LockTarget::row(ti, &key), LockMode::X)?;

        let exists = {
            let table = db.tables[ti].read().unwrap();
            self.state.visible(ti, &key, table.rows.get(&key)).is_some()
        };
        if exists {
            return Err(TxnError::DuplicateKey {
                table: schema.name.clone(),
                key: key.to_string(),
            });
        }
        let row = Arc::new(row);
        self.state.overlay_put(ti, key.clone(), Some(Arc::clone(&row)));
        self.state.update.push(WriteRecord::Insert { table: ti, key, row });
        Ok(ResultSet::write(1))
    }

    fn exec_update(&mut self, p: &PUpdate, slots: &BindSlots) -> Result<ResultSet, TxnError> {
        let db = self.db;
        let matched = self.select_rows(p.ti, &p.where_, &p.path, slots)?;
        let schema = db.schema.table(p.ti);
        let mut affected = 0;
        for (key, old_row) in matched {
            // Copy-on-write: the one deep clone on the write path.
            let mut new_row: Row = (*old_row).clone();
            let mut cols = Vec::with_capacity(p.sets.len());
            for (ci, op) in &p.sets {
                let ty = schema.columns[*ci].ty;
                match op {
                    SetOp::Assign(expr) => {
                        let v = eval_cscalar(expr, Some(old_row.as_ref()), slots)
                            .map_err(TxnError::Sql)?
                            .coerce(ty);
                        new_row[*ci] = v.clone();
                        cols.push((*ci, ColOp::Set(v)));
                    }
                    SetOp::Delta { expr, negate } => {
                        // Logical redo: the delta shape was detected at
                        // prepare time; replicated replay merges the delta
                        // with the replica's own value (db::update::ColOp).
                        let d = eval_cscalar(expr, None, slots).map_err(TxnError::Sql)?;
                        let kind = if *negate { ArithKind::Sub } else { ArithKind::Add };
                        let v = numeric_arith(kind, &old_row[*ci], &d)
                            .map_err(TxnError::Sql)?
                            .coerce(ty);
                        let colop = if *negate {
                            match &d {
                                Value::Int(i) => ColOp::Add(Value::Int(-*i)),
                                Value::Float(x) => ColOp::Add(Value::Float(-*x)),
                                // Non-negatable delta (NULL): degrade to an
                                // absolute assignment of the computed value.
                                _ => ColOp::Set(v.clone()),
                            }
                        } else {
                            ColOp::Add(d)
                        };
                        new_row[*ci] = v;
                        cols.push((*ci, colop));
                    }
                }
                // Bounded apply: a declared NonNegative invariant is
                // validated against the post-image before the write
                // buffers. Confluent operations rely on this local check
                // instead of coordinating — a violating decrement aborts
                // here (semantic, non-retryable), never replicates.
                if schema.nonneg(*ci) {
                    let neg = match &new_row[*ci] {
                        Value::Int(i) => *i < 0,
                        Value::Float(x) => *x < 0.0,
                        _ => false,
                    };
                    if neg {
                        return Err(TxnError::Invariant {
                            table: schema.name.clone(),
                            column: schema.columns[*ci].name.clone(),
                            value: format!("{:?}", new_row[*ci]),
                        });
                    }
                }
            }
            self.state.overlay_put(p.ti, key.clone(), Some(Arc::new(new_row)));
            self.state.update.push(WriteRecord::Update { table: p.ti, key, cols });
            affected += 1;
        }
        Ok(ResultSet::write(affected))
    }

    fn exec_delete(&mut self, p: &PDelete, slots: &BindSlots) -> Result<ResultSet, TxnError> {
        let matched = self.select_rows(p.ti, &p.where_, &p.path, slots)?;
        let affected = matched.len();
        for (key, _) in matched {
            self.state.overlay_put(p.ti, key.clone(), None);
            self.state.update.push(WriteRecord::Delete { table: p.ti, key });
        }
        Ok(ResultSet::write(affected))
    }

    /// Commit: apply buffered writes to storage, then release locks.
    /// Returns the transaction's [`StateUpdate`].
    pub fn commit(self) -> Result<StateUpdate, TxnError> {
        self.commit_with(|_| ())
            .map(|(u, ())| u)
    }

    /// Commit and run `hook` *after* the writes are applied but *before*
    /// any lock is released. Under strict 2PL this means two conflicting
    /// transactions invoke their hooks in their serialization order —
    /// exactly the property Eliá's commit interception relies on to
    /// append state updates to the token queue in execution order
    /// (paper §5, "Tracing the sequential order of global operations").
    pub fn commit_with<R>(mut self, hook: impl FnOnce(&StateUpdate) -> R) -> Result<(StateUpdate, R), TxnError> {
        if self.done {
            return Err(TxnError::Finished);
        }
        self.done = true;

        // Durability first: the commit acknowledges only after its redo
        // records reach the log (group-committed per the sync policy).
        // All 2PL locks are still held, so — by the same argument as the
        // token hook below — the WAL order is a serialization order, and
        // an append failure aborts cleanly before storage is touched.
        if !self.state.update.is_empty() {
            if let Some(w) = &self.db.wal {
                if let Err(e) = w.append(&self.state.update) {
                    self.release_locks();
                    self.db.aborts.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            }
        }

        // Apply per-table in table-id order under physical write locks.
        let mut touched: Vec<usize> = self.state.update.records.iter().map(|r| r.table()).collect();
        touched.sort_unstable();
        touched.dedup();
        for ti in touched {
            let mut table = self.db.tables[ti].write().unwrap();
            for rec in self.state.update.records.iter().filter(|r| r.table() == ti) {
                match rec {
                    WriteRecord::Insert { key, row, .. } => {
                        table.put(key.clone(), Arc::clone(row))
                    }
                    WriteRecord::Update { key, cols, .. } => {
                        if let Some(mut row) = table.rows.get(key).map(|r| (**r).clone()) {
                            let schema = self.db.schema.table(ti);
                            for (ci, op) in cols {
                                // Same coercion as apply_update: committed
                                // state must equal the overlay image the
                                // statement computed (typed deltas included).
                                row[*ci] =
                                    op.apply(&row[*ci]).coerce(schema.columns[*ci].ty);
                            }
                            table.put(key.clone(), Arc::new(row));
                        }
                    }
                    WriteRecord::Delete { key, .. } => table.remove(key),
                }
            }
        }

        let update = std::mem::take(&mut self.state.update);
        let r = hook(&update);
        self.release_locks();
        self.db.commits.fetch_add(1, Ordering::Relaxed);
        Ok((update, r))
    }

    /// Abort: discard buffered writes and release locks.
    pub fn abort(mut self) {
        self.done = true;
        self.release_locks();
        self.db.aborts.fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for TxnHandle<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.release_locks();
            self.db.aborts.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{TableSchema, ValueType};
    use crate::sqlir::parse_statement;

    fn test_db() -> Db {
        Db::new(Schema::new(vec![
            TableSchema::new(
                "ITEMS",
                &[
                    ("ID", ValueType::Int),
                    ("TITLE", ValueType::Str),
                    ("STOCK", ValueType::Int),
                    ("COST", ValueType::Float),
                ],
                &["ID"],
            )
            .with_index("TITLE"),
            TableSchema::new(
                "SC",
                &[("ID", ValueType::Int), ("I_ID", ValueType::Int), ("QTY", ValueType::Int)],
                &["ID", "I_ID"],
            ),
        ]))
    }

    fn b(pairs: &[(&str, Value)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
    }

    fn seed_items(db: &Db, n: i64) {
        let ins = db
            .prepare_sql("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (?id, ?t, ?s, ?c)")
            .unwrap();
        for i in 0..n {
            db.exec_auto_prepared(
                &ins,
                &ins.bind_pairs(&[
                    ("id", Value::Int(i)),
                    ("t", Value::Str(format!("book{i}"))),
                    ("s", Value::Int(100)),
                    ("c", Value::Float(9.5 + i as f64)),
                ])
                .unwrap(),
            )
            .unwrap();
        }
    }

    #[test]
    fn insert_select_roundtrip() {
        let db = test_db();
        seed_items(&db, 3);
        let q = parse_statement("SELECT TITLE, STOCK FROM ITEMS WHERE ID = ?id").unwrap();
        let r = db.exec_auto(&q, &b(&[("id", Value::Int(1))])).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Str("book1".into()), Value::Int(100)]]);
    }

    #[test]
    fn prepared_reuse_across_executions() {
        let db = test_db();
        seed_items(&db, 5);
        let q = db.prepare_sql("SELECT STOCK FROM ITEMS WHERE ID = ?id").unwrap();
        for i in 0..5i64 {
            let r = db
                .exec_auto_prepared(&q, &BindSlots(vec![Value::Int(i)]))
                .unwrap();
            assert_eq!(r.scalar(), Some(&Value::Int(100)), "id {i}");
        }
        // Missing key: empty result, same prepared statement.
        let r = db.exec_auto_prepared(&q, &BindSlots(vec![Value::Int(99)])).unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn update_with_arithmetic_and_state_update() {
        let db = test_db();
        seed_items(&db, 1);
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - ?q WHERE ID = ?id").unwrap();
        let mut txn = db.begin();
        let r = txn.exec(&u, &b(&[("q", Value::Int(30)), ("id", Value::Int(0))])).unwrap();
        assert_eq!(r.affected, 1);
        let update = txn.commit().unwrap();
        assert_eq!(update.len(), 1);
        match &update.records[0] {
            WriteRecord::Update { cols, .. } => {
                assert_eq!(cols, &vec![(2usize, ColOp::Add(Value::Int(-30)))])
            }
            other => panic!("{other:?}"),
        }
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(70)));
    }

    #[test]
    fn typed_delta_commits_in_column_type() {
        // A Float delta on an Int column: committed state must equal the
        // overlay image the statement computed (coerced to the column
        // type), at the origin and at a replica replaying the update.
        let db = test_db();
        seed_items(&db, 1);
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + ?d WHERE ID = 0").unwrap();
        let mut txn = db.begin();
        txn.exec(&u, &b(&[("d", Value::Float(1.5))])).unwrap();
        let update = txn.commit().unwrap();
        // 100 + 1.5 = 101.5, coerced into the Int column as 102.
        let row = db.peek("ITEMS", &Key::single(Value::Int(0))).unwrap();
        assert_eq!(row[2], Value::Int(102));
        let db2 = test_db();
        seed_items(&db2, 1);
        db2.apply_update(&update).unwrap();
        assert_eq!(db2.content_hash(), db.content_hash());
    }

    #[test]
    fn reads_see_own_writes_before_commit() {
        let db = test_db();
        let mut txn = db.begin();
        let ins = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (5, 'x', 1, 1.0)").unwrap();
        txn.exec(&ins, &Bindings::new()).unwrap();
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 5").unwrap();
        assert_eq!(txn.exec(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(1)));
        txn.abort();
        // After abort: nothing.
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().len(), 0);
    }

    #[test]
    fn duplicate_pk_rejected() {
        let db = test_db();
        seed_items(&db, 1);
        let ins = parse_statement("INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (0, 'dup', 1, 1.0)").unwrap();
        let err = db.exec_auto(&ins, &Bindings::new()).unwrap_err();
        assert!(matches!(err, TxnError::DuplicateKey { .. }));
    }

    #[test]
    fn delete_and_scan() {
        let db = test_db();
        seed_items(&db, 5);
        let d = parse_statement("DELETE FROM ITEMS WHERE ID >= 3").unwrap();
        let r = db.exec_auto(&d, &Bindings::new()).unwrap();
        assert_eq!(r.affected, 2);
        assert_eq!(db.row_count("ITEMS"), 3);
    }

    #[test]
    fn aggregates_and_order_by() {
        let db = test_db();
        seed_items(&db, 4);
        let q = parse_statement("SELECT COUNT(*) FROM ITEMS WHERE STOCK = 100").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Int(4)));
        let q = parse_statement("SELECT MAX(COST) FROM ITEMS").unwrap();
        assert_eq!(db.exec_auto(&q, &Bindings::new()).unwrap().scalar(), Some(&Value::Float(12.5)));
        let q = parse_statement("SELECT ID FROM ITEMS ORDER BY COST DESC LIMIT 2").unwrap();
        let r = db.exec_auto(&q, &Bindings::new()).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Int(3)], vec![Value::Int(2)]]);
    }

    #[test]
    fn secondary_index_lookup() {
        let db = test_db();
        seed_items(&db, 10);
        let q = parse_statement("SELECT ID FROM ITEMS WHERE TITLE = ?t").unwrap();
        let r = db.exec_auto(&q, &b(&[("t", Value::Str("book7".into()))])).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Int(7)]]);
        let d = parse_statement("DELETE FROM ITEMS WHERE ID = 7").unwrap();
        db.exec_auto(&d, &Bindings::new()).unwrap();
        let r = db.exec_auto(&q, &b(&[("t", Value::Str("book7".into()))])).unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn index_eq_sees_in_txn_update_of_indexed_column() {
        // Regression: a committed row whose indexed column is updated
        // *within* the transaction must be visible to an index-equality
        // read on the new value (it is not in the committed index bucket),
        // and invisible on the old value.
        let db = test_db();
        seed_items(&db, 3);
        let u = parse_statement("UPDATE ITEMS SET TITLE = ?t WHERE ID = 1").unwrap();
        let q = parse_statement("SELECT ID FROM ITEMS WHERE TITLE = ?t").unwrap();

        let mut txn = db.begin();
        txn.exec(&u, &b(&[("t", Value::Str("renamed".into()))])).unwrap();
        let r = txn.exec(&q, &b(&[("t", Value::Str("renamed".into()))])).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Int(1)]], "new value must be visible in-txn");
        let r = txn.exec(&q, &b(&[("t", Value::Str("book1".into()))])).unwrap();
        assert!(r.is_empty(), "old value must no longer match in-txn");
        txn.commit().unwrap();

        // After commit the committed index agrees.
        let r = db.exec_auto(&q, &b(&[("t", Value::Str("renamed".into()))])).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Int(1)]]);
    }

    #[test]
    fn index_eq_sees_in_txn_inserts() {
        let db = test_db();
        seed_items(&db, 2);
        let ins = parse_statement(
            "INSERT INTO ITEMS (ID, TITLE, STOCK, COST) VALUES (7, 'fresh', 1, 1.0)",
        )
        .unwrap();
        let q = parse_statement("SELECT ID FROM ITEMS WHERE TITLE = 'fresh'").unwrap();
        let mut txn = db.begin();
        txn.exec(&ins, &Bindings::new()).unwrap();
        let r = txn.exec(&q, &Bindings::new()).unwrap();
        assert_eq!(r.to_owned(), vec![vec![Value::Int(7)]]);
        txn.commit().unwrap();
    }

    #[test]
    fn select_star_is_borrowed_and_full_width() {
        let db = test_db();
        seed_items(&db, 3);
        let q = parse_statement("SELECT * FROM ITEMS WHERE ID = 1").unwrap();
        let r = db.exec_auto(&q, &Bindings::new()).unwrap();
        assert_eq!(r.len(), 1);
        let row = r.row(0);
        assert_eq!(row.len(), 4, "SELECT * projects every storage column");
        assert_eq!(row[1], Value::Str("book1".into()));
        assert_eq!(row[2], Value::Int(100));
    }

    #[test]
    fn result_set_outlives_txn_as_a_snapshot() {
        // A held ResultSet keeps reading the values it matched, across
        // later writes in the same transaction (copy-on-write overlay)
        // and across the commit (storage swaps in new Arcs).
        let db = test_db();
        seed_items(&db, 1);
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 40 WHERE ID = 0").unwrap();
        let mut txn = db.begin();
        let before = txn.exec(&q, &Bindings::new()).unwrap();
        txn.exec(&u, &Bindings::new()).unwrap();
        let after = txn.exec(&q, &Bindings::new()).unwrap();
        txn.commit().unwrap();
        assert_eq!(before.scalar(), Some(&Value::Int(100)), "snapshot preserved");
        assert_eq!(after.scalar(), Some(&Value::Int(60)), "overlay image visible");
        assert_eq!(
            db.exec_auto(&q, &Bindings::new()).unwrap().scalar(),
            Some(&Value::Int(60))
        );
        // Both handles still read their respective snapshots post-commit.
        assert_eq!(before.scalar(), Some(&Value::Int(100)));
        assert_eq!(after.scalar(), Some(&Value::Int(60)));
    }

    #[test]
    fn apply_update_replicates_state() {
        let db1 = test_db();
        let db2 = test_db();
        seed_items(&db1, 2);
        seed_items(&db2, 2);
        // Run a txn on db1, capture its update, apply on db2.
        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 10 WHERE ID = 1").unwrap();
        let mut txn = db1.begin();
        txn.exec(&u, &Bindings::new()).unwrap();
        let update = txn.commit().unwrap();
        db2.apply_update(&update).unwrap();
        assert_eq!(db1.content_hash(), db2.content_hash());
    }

    #[test]
    fn null_plus_delta_replays_identically_on_replicas() {
        // Regression for the ColOp::Add NULL bug: a delta over a NULL
        // cell must produce NULL on the primary's commit path *and* on
        // the replica's replay path — it used to degrade to Set(delta)
        // on replay, diverging the replica.
        let db1 = test_db();
        let db2 = test_db();
        // Seed the row with a NULL STOCK through the replication path
        // (the SQL loader has no NULL literal), identically on both.
        let null_row = StateUpdate {
            records: vec![WriteRecord::Insert {
                table: 0,
                key: Key::single(Value::Int(1)),
                row: Arc::new(vec![
                    Value::Int(1),
                    Value::Str("b".into()),
                    Value::Null,
                    Value::Float(1.0),
                ]),
            }],
        };
        db1.apply_update(&null_row).unwrap();
        db2.apply_update(&null_row).unwrap();

        let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK + 5 WHERE ID = 1").unwrap();
        let mut txn = db1.begin();
        txn.exec(&u, &Bindings::new()).unwrap();
        let update = txn.commit().unwrap();
        assert_eq!(
            db1.peek("ITEMS", &Key::single(Value::Int(1))).unwrap()[2],
            Value::Null,
            "primary: NULL + 5 must stay NULL"
        );
        db2.apply_update(&update).unwrap();
        assert_eq!(
            db1.content_hash(),
            db2.content_hash(),
            "replica must not diverge on a NULL + delta replay"
        );
    }

    #[test]
    fn commit_hook_runs_under_locks_in_commit_order() {
        // Two conflicting txns run concurrently; the hook order must match
        // the serialization (stock decrement) order.
        use std::sync::Mutex;
        let db = Arc::new(test_db());
        seed_items(&db, 1);
        let order: Arc<Mutex<Vec<i64>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for tag in 0..4i64 {
            let db = Arc::clone(&db);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let u = parse_statement("UPDATE ITEMS SET STOCK = STOCK - 1 WHERE ID = 0").unwrap();
                loop {
                    let mut txn = db.begin();
                    match txn.exec(&u, &Bindings::new()) {
                        Ok(_) => {
                            let stock_after = {
                                let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
                                txn.exec(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap()
                            };
                            txn.commit_with(|_| order.lock().unwrap().push(stock_after)).unwrap();
                            break;
                        }
                        Err(e) if e.is_retryable() => continue,
                        Err(e) => panic!("{e} (tag {tag})"),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Hook order must be the strictly decreasing stock order 99,98,97,96.
        assert_eq!(*order.lock().unwrap(), vec![99, 98, 97, 96]);
    }

    #[test]
    fn read_committed_skips_read_locks() {
        let db = test_db();
        seed_items(&db, 1);
        // Writer holds X lock on row 0.
        let u = parse_statement("UPDATE ITEMS SET STOCK = 5 WHERE ID = 0").unwrap();
        let mut writer = db.begin();
        writer.exec(&u, &Bindings::new()).unwrap();
        // Read-committed reader proceeds (no S lock) and sees committed 100.
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let mut reader = db.begin_with(IsolationLevel::ReadCommitted);
        let r = reader.exec(&q, &Bindings::new()).unwrap();
        assert_eq!(r.scalar(), Some(&Value::Int(100)));
        reader.commit().unwrap();
        writer.commit().unwrap();
        let r = db.exec_auto(&q, &Bindings::new()).unwrap();
        assert_eq!(r.scalar(), Some(&Value::Int(5)));
    }

    #[test]
    fn serializable_blocks_conflicting_reader() {
        // Writer holds X; a younger serializable reader must wait-die.
        let db = test_db();
        seed_items(&db, 1);
        let u = parse_statement("UPDATE ITEMS SET STOCK = 5 WHERE ID = 0").unwrap();
        let mut writer = db.begin();
        writer.exec(&u, &Bindings::new()).unwrap();
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let mut reader = db.begin(); // younger
        let err = reader.exec(&q, &Bindings::new()).unwrap_err();
        assert!(err.is_retryable());
    }

    #[test]
    fn concurrent_stock_decrements_are_serializable() {
        let db = Arc::new(test_db());
        seed_items(&db, 1);
        let threads = 8;
        let per = 25;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let db = Arc::clone(&db);
            handles.push(std::thread::spawn(move || {
                let u = db
                    .prepare_sql("UPDATE ITEMS SET STOCK = STOCK - 1 WHERE ID = ?id")
                    .unwrap();
                let slots = BindSlots(vec![Value::Int(0)]);
                for _ in 0..per {
                    loop {
                        let mut txn = db.begin();
                        match txn
                            .exec_prepared(&u, &slots)
                            .and_then(|_| txn.commit().map(|_| ()))
                        {
                            Ok(()) => break,
                            Err(e) if e.is_retryable() => continue,
                            Err(e) => panic!("{e}"),
                        }
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let q = parse_statement("SELECT STOCK FROM ITEMS WHERE ID = 0").unwrap();
        let final_stock = db.exec_auto(&q, &Bindings::new()).unwrap().scalar().unwrap().as_int().unwrap();
        assert_eq!(final_stock, 100 - threads * per);
    }

    #[test]
    fn composite_pk_point_access() {
        let db = test_db();
        let ins = db
            .prepare_sql("INSERT INTO SC (ID, I_ID, QTY) VALUES (?s, ?i, ?q)")
            .unwrap();
        for s in 0..3i64 {
            for i in 0..3i64 {
                db.exec_auto_prepared(
                    &ins,
                    &ins.bind_pairs(&[
                        ("s", Value::Int(s)),
                        ("i", Value::Int(i)),
                        ("q", Value::Int(s * 10 + i)),
                    ])
                    .unwrap(),
                )
                .unwrap();
            }
        }
        let q = db.prepare_sql("SELECT QTY FROM SC WHERE ID = ?s AND I_ID = ?i").unwrap();
        let r = db
            .exec_auto_prepared(&q, &q.bind_pairs(&[("s", Value::Int(2)), ("i", Value::Int(1))]).unwrap())
            .unwrap();
        assert_eq!(r.scalar(), Some(&Value::Int(21)));
    }
}
