//! State updates: the ordered sequence of mutations a transaction
//! performed, in replayable (logical redo) form.
//!
//! This is what the paper's Eliá extracts by intercepting JDBC: "the
//! sequence of SQL statements in the operation object represents the
//! sequence of state mutations that can be executed by other servers to
//! reproduce the operation". We capture *post-image* logical records
//! rather than SQL text — replay is deterministic regardless of the
//! remote replica's state of non-written columns, which is exactly the
//! passive-replication property §4 relies on.

use super::value::{Key, Row, Value};
use std::fmt;
use std::sync::Arc;

/// How one column changes in a logical update record.
///
/// `Add` keeps the record *logical* rather than a post-image: replaying
/// `I_NB_BIDS = I_NB_BIDS + 1` at a replica adds to the replica's own
/// value, so replicated counter updates merge with the replica's local
/// (non-replicated) writes — exactly the semantics of Eliá's SQL-replay
/// replication ("the sequence of SQL statements ... that can be executed
/// by other servers to reproduce the operation", paper §5).
#[derive(Debug, Clone, PartialEq)]
pub enum ColOp {
    /// Absolute assignment.
    Set(Value),
    /// Numeric delta (from `SET c = c + expr` / `c - expr` forms).
    Add(Value),
}

impl ColOp {
    /// Apply to the current value.
    ///
    /// `Add` mirrors [`crate::db::value::numeric_arith`] exactly — the
    /// origin server computes the post-image through `numeric_arith`
    /// while replicas re-derive it here from their own current value,
    /// so any semantic gap between the two diverges replicas from the
    /// primary:
    /// * NULL propagates (SQL three-valued arithmetic) instead of the
    ///   delta silently degrading to a `Set`;
    /// * integer deltas saturate on overflow (with a debug assertion)
    ///   instead of wrapping, so an overflowing replicated counter
    ///   pins at the bound identically everywhere;
    /// * a delta over a non-numeric non-NULL value (unreachable through
    ///   the typed SQL path) leaves the current value untouched.
    pub fn apply(&self, current: &Value) -> Value {
        match self {
            ColOp::Set(v) => v.clone(),
            ColOp::Add(d) => match (current, d) {
                (Value::Null, _) | (_, Value::Null) => Value::Null,
                (Value::Int(a), Value::Int(b)) => {
                    debug_assert!(
                        a.checked_add(*b).is_some(),
                        "replicated integer delta overflows: {a} + {b} (saturating in release)"
                    );
                    Value::Int(a.saturating_add(*b))
                }
                (a, b) => match (a.as_f64(), b.as_f64()) {
                    (Some(x), Some(y)) => Value::Float(x + y),
                    _ => current.clone(),
                },
            },
        }
    }
}

/// One logical mutation. Inserted rows are `Arc`-shared with the
/// transaction overlay and committed storage, so buffering, commit and
/// replicated replay never deep-copy the row (and cloning a
/// [`StateUpdate`] as a token payload is refcount-cheap).
#[derive(Debug, Clone, PartialEq)]
pub enum WriteRecord {
    /// Insert a full row into `table`.
    Insert {
        /// Table index.
        table: usize,
        /// Primary key of the new row.
        key: Key,
        /// The inserted row, shared with overlay/storage.
        row: Arc<Row>,
    },
    /// Change columns `(col_idx, op)` of the row at `key`.
    Update {
        /// Table index.
        table: usize,
        /// Primary key of the updated row.
        key: Key,
        /// Per-column logical operations.
        cols: Vec<(usize, ColOp)>,
    },
    /// Delete the row at `key`.
    Delete {
        /// Table index.
        table: usize,
        /// Primary key of the deleted row.
        key: Key,
    },
}

impl WriteRecord {
    /// The table this record touches.
    pub fn table(&self) -> usize {
        match self {
            WriteRecord::Insert { table, .. }
            | WriteRecord::Update { table, .. }
            | WriteRecord::Delete { table, .. } => *table,
        }
    }

    /// The primary key this record touches.
    pub fn key(&self) -> &Key {
        match self {
            WriteRecord::Insert { key, .. }
            | WriteRecord::Update { key, .. }
            | WriteRecord::Delete { key, .. } => key,
        }
    }
}

/// The replayable effect of one committed transaction, in execution
/// order. Cheap to clone (used as token payload); typical transactions
/// write a handful of rows.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StateUpdate {
    /// The mutations, in execution order.
    pub records: Vec<WriteRecord>,
}

impl StateUpdate {
    /// An empty update.
    pub fn new() -> Self {
        StateUpdate { records: Vec::new() }
    }

    /// Append one record (execution order).
    pub fn push(&mut self, rec: WriteRecord) {
        self.records.push(rec);
    }

    /// True when the transaction wrote nothing.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Number of write records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Rough wire size in bytes, used by the simulator to charge
    /// token-transfer time proportionally to payload.
    pub fn wire_size(&self) -> usize {
        let mut sz = 8;
        for r in &self.records {
            sz += 16;
            let vals: Box<dyn Iterator<Item = &Value>> = match r {
                WriteRecord::Insert { row, key, .. } => {
                    Box::new(key.0.iter().chain(row.iter()))
                }
                WriteRecord::Update { key, cols, .. } => {
                    Box::new(key.0.iter().chain(cols.iter().map(|(_, op)| match op {
                        ColOp::Set(v) | ColOp::Add(v) => v,
                    })))
                }
                WriteRecord::Delete { key, .. } => Box::new(key.0.iter()),
            };
            for v in vals {
                sz += match v {
                    Value::Str(s) => 8 + s.len(),
                    _ => 8,
                };
            }
        }
        sz
    }
}

impl fmt::Display for StateUpdate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "StateUpdate[{} records]", self.records.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_preserved() {
        let mut u = StateUpdate::new();
        u.push(WriteRecord::Insert {
            table: 0,
            key: Key::single(Value::Int(1)),
            row: Arc::new(vec![Value::Int(1)]),
        });
        u.push(WriteRecord::Delete { table: 0, key: Key::single(Value::Int(1)) });
        assert_eq!(u.len(), 2);
        assert!(matches!(u.records[0], WriteRecord::Insert { .. }));
        assert!(matches!(u.records[1], WriteRecord::Delete { .. }));
    }

    #[test]
    fn add_over_null_propagates_null_like_sql() {
        // Regression: this used to return the delta (a silent Set),
        // diverging replicas from the primary's NULL post-image.
        let op = ColOp::Add(Value::Int(5));
        assert_eq!(op.apply(&Value::Null), Value::Null);
        let null_delta = ColOp::Add(Value::Null);
        assert_eq!(null_delta.apply(&Value::Int(7)), Value::Null);
        // The same pair through the origin-side evaluator must agree.
        use crate::db::value::{numeric_arith, ArithKind};
        assert_eq!(
            numeric_arith(ArithKind::Add, &Value::Null, &Value::Int(5)).unwrap(),
            op.apply(&Value::Null)
        );
    }

    #[test]
    fn add_over_non_numeric_keeps_current_value() {
        // Regression: this used to degrade to Set(delta), replacing a
        // string cell with the numeric delta on replay.
        let op = ColOp::Add(Value::Int(5));
        let cur = Value::Str("not a number".into());
        assert_eq!(op.apply(&cur), cur);
    }

    #[test]
    fn add_saturates_instead_of_wrapping() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let op = ColOp::Add(Value::Int(1));
        let r = catch_unwind(AssertUnwindSafe(|| op.apply(&Value::Int(i64::MAX))));
        if cfg!(debug_assertions) {
            // Debug builds surface the overflow loudly.
            assert!(r.is_err(), "overflow must trip the debug assertion");
        } else {
            // Release builds pin at the bound — identically on every
            // replica — instead of wrapping to i64::MIN.
            assert_eq!(r.unwrap(), Value::Int(i64::MAX));
        }
        // Non-overflowing adds are untouched by the guard.
        assert_eq!(op.apply(&Value::Int(41)), Value::Int(42));
    }

    #[test]
    fn wire_size_scales_with_payload() {
        let small = StateUpdate {
            records: vec![WriteRecord::Delete { table: 0, key: Key::single(Value::Int(1)) }],
        };
        let big = StateUpdate {
            records: vec![WriteRecord::Insert {
                table: 0,
                key: Key::single(Value::Int(1)),
                row: Arc::new(vec![Value::Str("x".repeat(100))]),
            }],
        };
        assert!(big.wire_size() > small.wire_size() + 90);
    }
}
